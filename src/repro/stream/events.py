"""Event-heap continuous-time MEC stream simulator — same physics as MECEnv.

Where :class:`repro.env.mecenv.MECEnv` advances one frame at a time with
every UE deciding synchronously, this simulator advances an EVENT HEAP in
continuous time: tasks arrive per UE as Poisson (or deterministic)
processes, each carries a per-class deadline, and a dispatcher is asked
for a decision ``{split, channel[, route], power}`` the moment a task
reaches the head of its UE's queue. Service is NON-PREEMPTIVE and its
duration is the Eq. 7/8 closed form (``core.overhead.task_latency_energy``
— the same shared helper ``env.task_overhead`` uses), over rates computed
by the env's own ``_rates`` (interference, per-server path loss and
channels) and processor-shared edge seconds from the env's ``t_edge``
table.

Quasi-static freeze: a task's rate, edge load, and therefore its service
time are FROZEN at service start — later starts/completions do not
retro-adjust in-flight durations. This is the continuous-time analog of
the frame env fixing each frame's rates at its start (paper Eq. 5 "rates
constant within a frame"); an in-service offloading task occupies its
(server, channel) slot and counts toward its server's processor-sharing
load for its whole service window, mirroring the frame env's
"offloads-this-frame" interference semantics.

Deadlines are handled LAZILY: a queued task whose deadline has already
passed when it reaches the head is dropped (never served); an in-service
task always runs to completion (non-preemptive) and a late finish counts
as a deadline MISS but not a drop. The conservation ledger

    arrivals == completed + dropped + queued + in_flight

holds after every event (``ledger()``; property-tested in
``tests/test_stream.py`` mirroring ``test_churn_properties.py``).

Determinism: the heap is keyed ``(time, seq)`` with a monotone sequence
breaking ties, and every random draw comes from per-UE
``numpy.random.default_rng([seed, ue])`` streams — event order and all
results are a pure function of (env, dispatcher, params, seed), never of
wall clock. The per-UE streams are what lets the asyncio daemon
(``dispatcher.py``) reproduce the exact same arrival processes from
independent UE coroutines.

The state + bookkeeping half lives in :class:`StreamCore` so the heap
loop here and the virtual-time asyncio daemon drive the SAME start/finish
logic — the two runtimes cannot drift.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.overhead import task_latency_energy
from repro.env.mecenv import MECEnv
from repro.stream.qos import QoSMonitor, TaskRecord


@dataclasses.dataclass(frozen=True)
class StreamParams:
    """One streaming scenario. ``rate`` is the per-UE mean arrival rate
    (tasks/s); arrivals stop at ``horizon`` and the sim drains the
    backlog. ``classes`` is the task-class mix as (weight, relative
    deadline seconds) pairs — each task draws a class at arrival and its
    absolute deadline is ``t_arrive + deadline``. ``deterministic``
    replaces the Poisson gaps with fixed ``1/rate`` spacing (per-UE phase
    offsets avoid synchronized arrivals). ``d_eval`` pins every UE at a
    fixed distance like the env's eval mode; ``None`` draws distances
    uniformly from the env's [d_low, d_high)."""
    rate: float = 4.0
    horizon: float = 30.0
    classes: tuple = ((0.75, 1.0), (0.25, 0.4))
    deterministic: bool = False
    d_eval: float = 50.0


class StreamPhysics:
    """The MECEnv physics surface the stream needs, frozen once: numpy
    views of the split tables and a jitted wrapper around the env's own
    ``_rates`` so interference (and the per-server path loss / channel
    layout of an edge pool) is computed by the SAME code as ``env.step``.
    Static pool geometry only — a resampled-geometry episode is a
    frame-training construct, not a serve-time one."""

    def __init__(self, env: MECEnv):
        self.env = env
        prm = env.params
        self.l_new = np.asarray(prm.l_new, np.float64)
        self.n_new = np.asarray(prm.n_new, np.float64)
        self.p_compute = np.asarray(prm.p_compute, np.float64)
        self.t_edge = None if prm.t_edge is None \
            else np.asarray(prm.t_edge, np.float64)
        if env.multi_server:
            self._rfn = jax.jit(
                lambda d, c, p, e, tx: env._rates(d, c, p, e, tx))
        else:
            self._rfn = jax.jit(
                lambda d, c, p, e, tx: env._rates(d, c, p, None, tx))

    def rates(self, d, chan, power, route, tx):
        """(N,) uplink rates under the CURRENT transmitting set — the
        env's interference model verbatim."""
        return np.asarray(self._rfn(
            jnp.asarray(d, jnp.float32), jnp.asarray(chan, jnp.int32),
            jnp.asarray(power, jnp.float32), jnp.asarray(route, jnp.int32),
            jnp.asarray(tx, bool)), np.float64)

    def service(self, ue, b, rate, power, *, server_load=1, route=0):
        """Frozen-at-start service seconds + UE energy of one task: the
        Eq. 7/8 closed form, with the processor-shared edge tail
        ``t_edge[ue, b, route] * max(load, 1)`` exactly as
        ``env._edge_seconds`` charges it."""
        te = None
        if self.t_edge is not None:
            te = self.t_edge[ue, b, route] * max(server_load, 1)
        t, e = task_latency_energy(self.l_new[ue, b], self.n_new[ue, b],
                                   rate, self.p_compute[ue], power, te)
        return float(t), float(e)


class StreamCore:
    """Queues, occupancy, and frozen-service bookkeeping — everything
    about the stream EXCEPT who advances time. :class:`StreamSim` drives
    it from an event heap; the asyncio daemon drives it from a virtual
    clock. ``now`` is owned by the driver.

    Dispatchers (``adapter.py``) receive this object: ``queues``,
    ``serving``, ``tx``/``chan``/``route``/``power`` occupancy vectors,
    ``d``, ``now``, and ``in_flight_remainder`` are their observable
    state."""

    def __init__(self, env: MECEnv, sp: StreamParams, seed: int = 0):
        self.env = env
        self.sp = sp
        self.phys = StreamPhysics(env)
        n = env.params.n_ue
        if sp.d_eval is not None:
            self.d = np.full((n,), float(sp.d_eval))
        else:
            self.d = np.random.default_rng([seed, n]).uniform(
                float(env.params.d_low), float(env.params.d_high), n)
        self.now = 0.0
        self.queues = [collections.deque() for _ in range(n)]
        self.serving = [None] * n            # in-service TaskRecord per UE
        self.tx = np.zeros((n,), bool)       # offloading in-service
        self.chan = np.zeros((n,), np.int32)
        self.route = np.zeros((n,), np.int32)
        self.power = np.full((n,), 1e-4)
        self.monitor = QoSMonitor()
        self.arrivals = 0
        self.completed = 0
        self.dropped = 0
        # per-UE RNG streams: the heap sim and the asyncio daemon draw the
        # identical arrival processes from these, whatever order events
        # interleave globally
        self.rngs = [np.random.default_rng([seed, ue]) for ue in range(n)]
        self._tid = itertools.count()
        self._start_seq = itertools.count()
        w = np.asarray([c[0] for c in sp.classes], np.float64)
        self._cls_p = w / w.sum()
        self._cls_dl = np.asarray([c[1] for c in sp.classes], np.float64)

    # ------------------------------------------------------------ arrivals
    def first_arrival(self, ue):
        """Absolute time of ue's first arrival (deterministic mode phases
        the fleet across one period; Poisson draws an exponential gap)."""
        if self.sp.deterministic:
            n = self.env.params.n_ue
            return (ue + 1) / (n * self.sp.rate)
        return float(self.rngs[ue].exponential(1.0 / self.sp.rate))

    def next_gap(self, ue):
        if self.sp.deterministic:
            return 1.0 / self.sp.rate
        return float(self.rngs[ue].exponential(1.0 / self.sp.rate))

    def new_task(self, ue):
        """Draw a task arriving NOW for ue (class, absolute deadline) and
        admit it to the UE's queue."""
        cls = int(self.rngs[ue].choice(len(self._cls_p), p=self._cls_p))
        task = TaskRecord(tid=next(self._tid), ue=ue, cls=cls,
                          t_arrive=self.now,
                          deadline=self.now + float(self._cls_dl[cls]))
        self.arrivals += 1
        self.queues[ue].append(task)
        return task

    # ------------------------------------------------------------- service
    def next_task(self, ue):
        """Head-of-queue task to serve next, after lazily dropping every
        queued task whose deadline already passed. None if the UE is busy
        or its queue is empty."""
        if self.serving[ue] is not None:
            return None
        q = self.queues[ue]
        while q:
            task = q.popleft()
            if self.now >= task.deadline:
                task.dropped = True
                task.t_done = self.now
                self.dropped += 1
                self.monitor.add(task)
                continue
            return task
        return None

    def start(self, task: TaskRecord, action) -> float:
        """Commit a dispatch decision: freeze occupancy, rate, edge load
        and the Eq. 7/8 service terms. Returns the service seconds; the
        driver schedules the completion. Rates are computed WITH this
        task's own occupancy committed, so simultaneous offloaders
        interfere mutually exactly as in ``env.step``."""
        ue = task.ue
        b = int(action["split"])
        c = int(action["channel"])
        e = int(action.get("route", 0))
        p = float(action["power"])
        offl = self.n_new_of(ue, b) > 0
        self.serving[ue] = task
        self.chan[ue] = c
        self.route[ue] = e
        self.power[ue] = p
        self.tx[ue] = offl
        load = 1
        if self.env.multi_server:
            load = int(sum(1 for u in range(len(self.serving))
                           if self.tx[u] and int(self.route[u]) == e))
        r = float(self.phys.rates(self.d, self.chan, self.power,
                                  self.route, self.tx)[ue])
        t_svc, energy = self.phys.service(ue, b, r, p, server_load=load,
                                          route=e)
        task.t_start = self.now
        task.start_seq = next(self._start_seq)
        task.b, task.channel, task.server, task.power = b, c, e, p
        task.rate, task.t_service, task.energy = r, t_svc, energy
        return t_svc

    def finish(self, task: TaskRecord):
        """Service completion: release occupancy, record the task."""
        ue = task.ue
        task.t_done = self.now
        self.serving[ue] = None
        self.tx[ue] = False
        self.completed += 1
        self.monitor.add(task)

    def n_new_of(self, ue, b):
        return float(self.phys.n_new[ue, b])

    def in_flight_remainder(self, ue):
        """(local seconds, offload bits) left of ue's in-service task at
        ``now`` under its frozen rate — the continuous-time analog of the
        frame env's carry-over ``(l, n)``. The edge tail is not
        represented, matching the frame state (which only tracks UE-side
        work of a boundary task)."""
        task = self.serving[ue]
        if task is None:
            return 0.0, 0.0
        el = self.now - task.t_start
        l_b = self.phys.l_new[ue, task.b]
        n_b = self.phys.n_new[ue, task.b]
        l_rem = max(l_b - el, 0.0)
        n_rem = max(n_b - max(el - l_b, 0.0) * task.rate, 0.0)
        return l_rem, n_rem

    # ------------------------------------------------------------- reports
    def ledger(self):
        """Task-conservation counts; ``arrivals == completed + dropped +
        queued + in_flight`` after every event."""
        return {"arrivals": self.arrivals, "completed": self.completed,
                "dropped": self.dropped,
                "queued": sum(len(q) for q in self.queues),
                "in_flight": sum(t is not None for t in self.serving)}

    def report(self):
        rep = self.monitor.report(horizon=self.sp.horizon)
        rep["arrivals"] = self.arrivals
        return rep


class StreamSim(StreamCore):
    """The event-heap driver: ``run()`` processes arrival / completion
    events in ``(time, seq)`` order until the stream has fully drained
    (arrivals stop at ``sp.horizon``; queued work then completes or is
    dropped). ``dispatch`` is any callable ``(core, ue) -> action dict``
    — see ``adapter.py`` for the policy and baseline dispatchers."""

    def __init__(self, env: MECEnv, dispatch, sp: StreamParams = None,
                 seed: int = 0):
        super().__init__(env, sp or StreamParams(), seed)
        self.dispatch = dispatch
        self._seq = itertools.count()
        self.heap = []
        for ue in range(env.params.n_ue):
            t0 = self.first_arrival(ue)
            if t0 < self.sp.horizon:
                self._push(t0, "arrive", ue)

    def _push(self, t, kind, payload):
        heapq.heappush(self.heap, (t, next(self._seq), kind, payload))

    def _try_start(self, ue):
        task = self.next_task(ue)
        if task is None:
            return
        t_svc = self.start(task, self.dispatch(self, ue))
        self._push(self.now + t_svc, "done", task)

    def step(self) -> bool:
        """Process ONE event; False once the heap is empty (stream fully
        drained). Exposed so property tests can check the conservation
        ledger between every pair of events."""
        if not self.heap:
            return False
        t, _, kind, payload = heapq.heappop(self.heap)
        self.now = t
        if kind == "arrive":
            ue = payload
            self.new_task(ue)
            nxt = t + self.next_gap(ue)
            if nxt < self.sp.horizon:
                self._push(nxt, "arrive", ue)
            self._try_start(ue)
        else:                                        # "done"
            task = payload
            self.finish(task)
            self._try_start(task.ue)
        return True

    def run(self):
        while self.step():
            pass
        return self.report()
