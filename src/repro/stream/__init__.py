"""Event-driven streaming serve runtime (ROADMAP item 3).

The frame-synchronous :mod:`repro.env.mecenv` MDP decides once per UE per
frame and scores mean overhead; real edge serving is a *stream* —
asynchronous task arrivals mid-service, per-task deadlines, and p99 tails
a mean never sees. This package is the continuous-time counterpart, built
on the SAME physics (``MECEnv._rates`` interference, the Eq. 7/8 closed
form in ``core.overhead.task_latency_energy``, processor-shared edge
service):

* :mod:`repro.stream.events` — event-heap simulator: per-UE Poisson (or
  deterministic) arrivals, per-class deadlines, non-preemptive service
  with explicit queues, lazy drops on deadline miss.
* :mod:`repro.stream.qos` — per-task records, throughput / miss-rate /
  p50-p95-p99 sojourn tail stats, and the deadline+tail reward the
  streaming fine-tune (``rl.streaming``) optimizes.
* :mod:`repro.stream.adapter` — renders stream state as an ``EnvState``
  so the frozen frame-trained entity policy dispatches ZERO-SHOT, plus
  greedy / nearest-server / full-local stream baselines.
* :mod:`repro.stream.dispatcher` — deterministic virtual-time asyncio
  daemon: mock UE and server processes exchange task messages through
  mailboxes and the policy runs as the live dispatcher
  (``examples/streaming_serve.py``).
"""
from repro.stream.events import StreamParams, StreamSim  # noqa: F401
from repro.stream.qos import (QoSMonitor, StreamRewardConfig,  # noqa: F401
                              TaskRecord, stream_reward, tail_stats)
