"""Policy-as-dispatcher asyncio daemon over a deterministic virtual clock.

The Master/Worker decomposition of the AnteronGitHub sparse_framework
exemplar (SNIPPETS.md), in-process: mock UE coroutines generate tasks and
send them through mailboxes to a dispatcher daemon; the daemon asks its
policy (any ``adapter.py`` dispatcher — the trained entity agent in the
demo) for a decision, commits it through the SAME :class:`StreamCore`
bookkeeping the event-heap simulator uses, and hands the task to the
routed server coroutine, which "executes" it for the frozen Eq. 7/8
service duration and reports completion back.

Time is VIRTUAL: every ``sleep`` goes through :class:`VirtualClock`, a
``(time, seq)``-keyed timer heap advanced only when the coroutine world
has fully settled (no runnable coroutine, no undelivered message). Event
order is therefore a pure function of (env, policy, params, seed) — two
runs with the same seed produce byte-identical QoS reports regardless of
wall clock, scheduler jitter, or machine. UE coroutines draw their
arrival processes from the same per-UE ``default_rng([seed, ue])``
streams as :class:`~repro.stream.events.StreamSim`, so a
state-independent policy (e.g. the full-local dispatcher) reproduces the
heap simulator's records EXACTLY — the cross-runtime agreement test in
``tests/test_stream.py``.
"""
from __future__ import annotations

import asyncio
import collections
import heapq
import itertools

from repro.env.mecenv import MECEnv
from repro.stream.events import StreamCore, StreamParams


class VirtualClock:
    """Deterministic discrete-event time for asyncio: ``sleep(dt)``
    parks the caller on a ``(now + dt, seq)`` heap entry and ``run()``
    advances to the earliest timer only once every coroutine has gone
    idle. ``_activity`` counts state changes (timer pushes, mailbox
    puts); the settle loop yields until it stops moving, which bounds
    the event-loop passes deterministically (no wall-clock waits)."""

    def __init__(self):
        self.now = 0.0
        self._timers = []
        self._seq = itertools.count()
        self._activity = 0

    def sleep(self, dt):
        fut = asyncio.get_running_loop().create_future()
        heapq.heappush(self._timers, (self.now + dt, next(self._seq), fut))
        self._activity += 1
        return fut

    async def _settle(self):
        idle, last = 0, -1
        while idle < 3:
            if self._activity == last:
                idle += 1
            else:
                idle, last = 0, self._activity
            await asyncio.sleep(0)

    async def run(self):
        """Advance until no timers remain: pop one timer, move ``now``,
        wake its sleeper, let the world settle, repeat."""
        await self._settle()
        while self._timers:
            t, _, fut = heapq.heappop(self._timers)
            self.now = t
            if not fut.cancelled():
                fut.set_result(None)
            await self._settle()


class Mailbox:
    """A deterministic in-process message queue: ``put`` never blocks and
    bumps the clock's activity counter so the settle loop knows a message
    is still undelivered."""

    def __init__(self, clock: VirtualClock):
        self._q = collections.deque()
        self._clock = clock
        self._waiter = None

    def put(self, msg):
        self._q.append(msg)
        self._clock._activity += 1
        if self._waiter is not None and not self._waiter.done():
            self._waiter.set_result(None)

    async def get(self):
        while not self._q:
            self._waiter = asyncio.get_running_loop().create_future()
            await self._waiter
        return self._q.popleft()


async def _ue_process(core: StreamCore, clock: VirtualClock,
                      to_daemon: Mailbox, ue: int):
    """Mock UE: sleeps out its (seeded, per-UE-stream) arrival gaps and
    mails each new task to the dispatcher. Draw order per UE matches
    StreamSim's arrival handling exactly, so the processes coincide."""
    t_next = core.first_arrival(ue)
    while t_next < core.sp.horizon:
        await clock.sleep(t_next - clock.now)
        core.now = clock.now
        task = core.new_task(ue)
        to_daemon.put(("task", task))
        t_next = clock.now + core.next_gap(ue)


async def _server_process(clock: VirtualClock, inbox: Mailbox,
                          to_daemon: Mailbox, log=None):
    """Mock edge server: "executes" each assigned task for its frozen
    service duration, then reports completion. The physics (including
    this server's processor-sharing load) were already committed by the
    daemon's ``core.start``; the worker's job is to own the passage of
    service time. Each task runs in its OWN sub-coroutine — tasks from
    different UEs genuinely execute concurrently on one server (that is
    the processor-sharing model), they must not serialize through the
    mailbox."""
    async def execute(task, t_svc):
        await clock.sleep(t_svc)
        if log is not None:
            log.append((task.tid, task.server, clock.now))
        to_daemon.put(("done", task))

    running = []
    while True:
        kind, task, t_svc = await inbox.get()
        if kind == "stop":
            await asyncio.gather(*running)   # all done once the clock dried
            return
        running.append(asyncio.ensure_future(execute(task, t_svc)))


async def _daemon(core: StreamCore, clock: VirtualClock, policy,
                  inbox: Mailbox, servers):
    """The dispatcher daemon: admits arriving tasks, asks the policy for
    a decision whenever a UE goes idle with queued work, and routes the
    committed task to its server's mailbox. Lazy deadline drops happen
    in ``core.next_task`` exactly as in the heap simulator. Runs forever
    — ``run_daemon`` cancels it once the virtual clock runs dry, at
    which point every task has completed or been dropped (enforced by
    the ledger check)."""
    while True:
        kind, task = await inbox.get()
        core.now = clock.now
        if kind == "done":
            core.finish(task)
        ue = task.ue
        nxt = core.next_task(ue)
        if nxt is not None:
            t_svc = core.start(nxt, policy(core, ue))
            servers[nxt.server].put(("serve", nxt, t_svc))


def run_daemon(env: MECEnv, policy, sp: StreamParams = None, *, seed=0,
               server_log=None):
    """Run one streaming episode through the asyncio daemon; returns
    (QoS report dict, StreamCore). Deterministic in ``seed``: virtual
    time only, per-UE arrival streams, (time, seq) tie-breaks."""
    sp = sp or StreamParams()
    core = StreamCore(env, sp, seed)

    async def main():
        clock = VirtualClock()
        to_daemon = Mailbox(clock)
        n_srv = env.n_servers
        server_in = [Mailbox(clock) for _ in range(n_srv)]
        for ue in range(env.params.n_ue):
            asyncio.ensure_future(_ue_process(core, clock, to_daemon, ue))
        servers = [asyncio.ensure_future(
            _server_process(clock, server_in[e], to_daemon, server_log))
            for e in range(n_srv)]
        daemon = asyncio.ensure_future(
            _daemon(core, clock, policy, to_daemon, server_in))
        await clock.run()
        for e in range(n_srv):
            server_in[e].put(("stop", None, 0.0))
        await asyncio.gather(*servers)
        daemon.cancel()
        await asyncio.gather(daemon, return_exceptions=True)

    asyncio.run(main())
    led = core.ledger()
    if led["queued"] or led["in_flight"] or \
            led["arrivals"] != led["completed"] + led["dropped"]:
        raise RuntimeError(f"daemon ended with an unbalanced ledger: {led}")
    return core.report(), core
