"""Stream state -> frame policy bridge, and the baseline dispatchers.

A dispatcher is any callable ``(core, ue) -> {"split", "channel"
[, "route"], "power"}`` returning PHYSICAL actions (watts, not pre-squash
u) for the one UE whose task is being started. The star of the show is
:class:`EntityDispatcher`: it renders the stream's live state as an
``EnvState`` snapshot (:func:`stream_env_state`), runs the FROZEN
frame-trained entity policy through the exact ``evaluate_policy`` act
path (``observe_entities`` -> ``entity_actor_forward`` -> masked
``mode``/``sample`` -> ``execute``), and takes the deciding UE's slice —
zero-shot: no streaming gradient ever touched the weights.

The baselines mirror ``rl.heuristics`` / ``rl.baselines`` in stream
form: full-local, interference-oblivious greedy over the clean-channel
cost table, and nearest-server (all load onto the closest server — the
baseline the streaming bench gates the entity policy against on p99 and
miss rate).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.env.mecenv import EnvState, MECEnv
from repro.rl import nets
from repro.rl.heuristics import _clean_cost_table


def stream_env_state(core) -> EnvState:
    """Render the stream's live state as the frame env's ``EnvState``:
    ``k`` counts each UE's queued + in-flight tasks, ``(l, n)`` is the
    in-service task's remaining UE-side work under its frozen rate (the
    frame carry-over analog), distances are the stream's. All UEs are
    active and the PRNG key is a constant — the policy forward pass never
    consumes it, so snapshots stay pure functions of stream state."""
    n = core.env.params.n_ue
    k = np.empty((n,), np.float32)
    l = np.empty((n,), np.float32)
    nb = np.empty((n,), np.float32)
    for u in range(n):
        k[u] = len(core.queues[u]) + (core.serving[u] is not None)
        l[u], nb[u] = core.in_flight_remainder(u)
    return EnvState(k=jnp.asarray(k), l=jnp.asarray(l), n=jnp.asarray(nb),
                    d=jnp.asarray(core.d, jnp.float32),
                    t=jnp.zeros((), jnp.int32), key=jax.random.PRNGKey(0),
                    active=jnp.ones((n,), bool), geom=None)


class EntityDispatcher:
    """The frozen frame-trained entity policy as a live stream dispatcher.

    ``deterministic=False`` samples instead of argmax-ing — the streaming
    deployment mode: the frame observation cannot carry live channel or
    server occupancy, so on occupancy-aliased states the distilled policy
    (``rl.streaming``) holds a load-spreading *distribution* and sampling
    realizes it. ``live_channel=True`` additionally overrides the channel
    head with :func:`least_loaded_channel` on the chosen server — the
    same live-state peek the greedy/nearest baselines already take at
    dispatch time (a dispatcher property, not a policy one: the policy
    still owns split/power/route, which is everything the baselines don't
    read from the runtime). With ``record=True`` every decision's
    (EnvState snapshot, raw pre-squash actions, deciding UE) is kept for
    post-hoc analysis."""

    def __init__(self, env: MECEnv, agent, *, deterministic=True, seed=0,
                 live_channel=False):
        if "entity_actor" not in agent:
            raise ValueError("EntityDispatcher needs an entity agent "
                             "({'entity_actor': ...}); train with "
                             "MAHPPOConfig(entity_policy=True)")
        self.env = env
        self.agent = agent
        self.live_channel = live_channel
        self.b_local = env.n_actions_b - 1
        self.record = False
        self.decisions = []          # (EnvState, raw actions dict, ue)
        self._key = jax.random.PRNGKey(seed)
        space = env.action_space
        n_ue = env.params.n_ue

        def act(agent, s, key):
            masks = space.broadcast_masks(env.action_masks(s), n_ue)
            dist = nets.entity_actor_forward(agent["entity_actor"], space,
                                             env.observe_entities(s), masks)
            if deterministic:
                raw = jax.vmap(space.mode)(dist, masks)
            else:
                raw = jax.vmap(space.sample)(jax.random.split(key, n_ue),
                                             dist, masks)
            return raw, space.execute(raw)

        self._act = jax.jit(act)

    def __call__(self, core, ue):
        s = stream_env_state(core)
        self._key, k = jax.random.split(self._key)
        raw, phys = self._act(self.agent, s, k)
        if self.record:
            self.decisions.append((s, raw, ue))
        act = {name: np.asarray(v)[ue].item() for name, v in phys.items()}
        if self.live_channel and act["split"] < self.b_local:
            act["channel"] = least_loaded_channel(core, act.get("route", 0))
        return act


class TrunkDispatcher:
    """The distilled (optionally int8-quantized) flat trunk as the live
    dispatcher — the serve-small deployment endpoint of ``rl/distill.py``.

    Same bridge as :class:`EntityDispatcher` (EnvState snapshot ->
    policy forward -> masked mode/sample -> execute, deciding UE's
    slice), but the policy forward is ONE fused MLP pass over
    ``observe_per_ue`` rows — no entity encoders, no pair scorer — and a
    quantized trunk ({"qlayers": ..., "bits": n}) routes through the
    fused int8 dequant-matmul kernel (``kernels.ops.flat_trunk``).
    Defaults are the deployment mode the teacher was streaming-tuned
    under: SAMPLED actions (the student learns the teacher's
    load-spreading marginals on occupancy-aliased states; sampling
    realizes them) plus the ``least_loaded_channel`` dispatch-time
    override every baseline also takes. The trunk is closed over, not
    passed per call: deployment weights are frozen constants, and the
    quantized form's static ``bits`` must not become a tracer."""

    def __init__(self, env: MECEnv, trunk, *, deterministic=False, seed=0,
                 live_channel=True):
        if "layers" not in trunk and "qlayers" not in trunk:
            raise ValueError("TrunkDispatcher needs flat-trunk params "
                             "(rl.distill.distill_entity_policy) or their "
                             "quantized form (quantize_flat_trunk)")
        self.env = env
        self.live_channel = live_channel
        self.b_local = env.n_actions_b - 1
        self._key = jax.random.PRNGKey(seed)
        space = env.action_space
        n_ue = env.params.n_ue

        def act(s, key):
            masks = space.broadcast_masks(env.action_masks(s), n_ue)
            dist = nets.flat_trunk_forward(trunk, space,
                                           env.observe_per_ue(s), masks)
            if deterministic:
                raw = jax.vmap(space.mode)(dist, masks)
            else:
                raw = jax.vmap(space.sample)(jax.random.split(key, n_ue),
                                             dist, masks)
            return space.execute(raw)

        self._act = jax.jit(act)

    def __call__(self, core, ue):
        s = stream_env_state(core)
        self._key, k = jax.random.split(self._key)
        phys = self._act(s, k)
        act = {name: np.asarray(v)[ue].item() for name, v in phys.items()}
        if self.live_channel and act["split"] < self.b_local:
            act["channel"] = least_loaded_channel(core, act.get("route", 0))
        return act


def least_loaded_channel(core, server):
    """The channel of ``server`` with the fewest in-service transmitters
    right now (first minimum — deterministic)."""
    counts = [0] * core.env.n_channels
    for u in range(core.env.params.n_ue):
        if core.tx[u] and int(core.route[u]) == server:
            counts[int(core.chan[u])] += 1
    return int(np.argmin(counts))


class LocalDispatcher:
    """Everything runs on-device: the always-feasible full-local split,
    no transmission (power pinned at the head's floor)."""

    def __init__(self, env: MECEnv):
        self.b_local = env.n_actions_b - 1
        self.p_min = env.action_space.head("power").low

    def __call__(self, core, ue):
        return {"split": self.b_local, "channel": 0, "route": 0,
                "power": self.p_min}


class GreedyDispatcher:
    """Stream form of ``heuristics.greedy_eval``: each dispatch picks the
    UE's own argmin clean-channel (split[, server]) cell at max power —
    interference-oblivious — plus the least-loaded channel on the chosen
    server at dispatch time (the one bit of live state a per-UE greedy
    would realistically use)."""

    def __init__(self, env: MECEnv, d=50.0):
        self.env = env
        self.cost = _clean_cost_table(env, d)   # (N, B+2[, E])
        self.p_max = float(env.params.p_max)

    def _pick(self, ue):
        if self.env.multi_server:
            flat = int(np.argmin(self.cost[ue].reshape(-1)))
            return flat // self.env.n_servers, flat % self.env.n_servers
        return int(np.argmin(self.cost[ue])), 0

    def __call__(self, core, ue):
        b, e = self._pick(ue)
        return {"split": b, "channel": least_loaded_channel(core, e),
                "route": e, "power": self.p_max}


class NearestServerDispatcher(GreedyDispatcher):
    """Stream form of ``baselines.nearest_server_eval``: every task goes
    to the CLOSEST server (min distance scale), best clean-channel split
    there — the whole fleet piles onto one server's channels and its
    processor-sharing queue, which is exactly the tail-latency failure
    mode the entity dispatcher is gated against."""

    def __init__(self, env: MECEnv, d=50.0):
        super().__init__(env, d)
        sd = np.asarray(env.params.server_dist) if env.multi_server \
            else np.zeros((1,))
        self.nearest = int(np.argmin(sd))

    def _pick(self, ue):
        if not self.env.multi_server:
            return int(np.argmin(self.cost[ue])), 0
        return int(np.argmin(self.cost[ue, :, self.nearest])), self.nearest


class StreamOracleDispatcher:
    """Occupancy-AWARE one-step cost minimizer — the distillation teacher
    of ``rl.streaming.finetune_streaming``, and the strongest
    non-learned stream baseline.

    Where :class:`GreedyDispatcher` argmins a clean-channel cost table
    frozen at init, the oracle sweeps, PER DISPATCH, every feasible
    (split, channel, server) and a small power grid, computing the
    candidate's ACTUAL uplink rate under the live transmitting set
    (committing the candidate occupancy exactly as ``core.start`` will)
    and its Eq. 7/8 service time under the live processor-sharing load.
    It minimizes the service-time + energy cost the fine-tune credits
    (``TaskRecord.task_cost`` without the miss outcome), so it
    automatically avoids busy channels and loaded servers. The price is
    a full candidate sweep per dispatch — the policy the fine-tune
    distills it into amortizes that into one forward pass."""

    def __init__(self, env: MECEnv, *, tail_weight=1.0, energy_weight=0.1,
                 powers=(0.5, 0.75, 0.98)):
        self.env = env
        self.t0 = float(env.params.t0)
        self.tail_weight = tail_weight
        self.energy_weight = energy_weight
        self.p_grid = [float(f * env.params.p_max) for f in powers]
        self.p_min = env.action_space.head("power").low
        self.feasible = np.asarray(env.params.feasible, bool)
        self.b_local = env.n_actions_b - 1

    def _cost(self, t_svc, energy):
        return self.tail_weight * t_svc / self.t0 \
            + self.energy_weight * energy

    def __call__(self, core, ue):
        env, phys = self.env, core.phys
        n_srv = env.n_servers if env.multi_server else 1
        offl_bs = [b for b in range(env.n_actions_b)
                   if self.feasible[ue, b] and core.n_new_of(ue, b) > 0]
        # full-local is always a candidate (no tx, no load, floor power)
        t_loc, e_loc = phys.service(ue, self.b_local, 1.0, self.p_min)
        best = (self._cost(t_loc, e_loc),
                {"split": self.b_local, "channel": 0, "route": 0,
                 "power": self.p_min})
        saved = (bool(core.tx[ue]), int(core.chan[ue]),
                 int(core.route[ue]), float(core.power[ue]))
        core.tx[ue] = True
        for e in range(n_srv):
            core.route[ue] = e
            load = int(sum(1 for u in range(len(core.serving))
                           if core.tx[u] and int(core.route[u]) == e))
            for c in range(env.n_channels):
                core.chan[ue] = c
                for p in self.p_grid:
                    core.power[ue] = p
                    # the rate is split-independent: one eval covers
                    # every candidate b on this (channel, server, power)
                    r = float(phys.rates(core.d, core.chan, core.power,
                                         core.route, core.tx)[ue])
                    for b in offl_bs:
                        t_svc, en = phys.service(ue, b, r, p,
                                                 server_load=load, route=e)
                        cost = self._cost(t_svc, en)
                        if cost < best[0]:
                            best = (cost, {"split": b, "channel": c,
                                           "route": e, "power": p})
        core.tx[ue], core.chan[ue] = saved[0], saved[1]
        core.route[ue], core.power[ue] = saved[2], saved[3]
        return best[1]
