"""Streaming QoS: per-task records, tail statistics, and the SLO reward.

The frame env's Eq. 12 reward scores mean per-frame overhead; a serving
system is judged on its *distribution*: throughput, deadline-miss rate,
and tail (p95/p99) sojourn latency. This module owns those metrics — the
stream simulator (``events.py``) and the asyncio daemon
(``dispatcher.py``) both feed :class:`QoSMonitor`, and
``benchmarks/_timing.py`` re-exports :func:`tail_stats` so bench reports
quote the same percentiles as the runtime.
"""
from __future__ import annotations

import dataclasses

import numpy as np


def tail_stats(samples, percentiles=(50, 95, 99)):
    """``{"p50": ..., "p95": ..., "p99": ...}`` over a 1-D sample array
    (numpy linear-interpolated percentiles). Empty input yields NaNs so a
    report of a fully-dropped stream stays well-formed instead of
    raising."""
    arr = np.asarray(list(samples), np.float64)
    if arr.size == 0:
        return {f"p{q:g}": float("nan") for q in percentiles}
    vals = np.percentile(arr, percentiles)
    return {f"p{q:g}": float(v) for q, v in zip(percentiles, vals)}


@dataclasses.dataclass
class TaskRecord:
    """One streamed task, from arrival to completion (or drop). The
    dispatch decision and its frozen-at-start physics (rate, service
    time) ride along so reports can be sliced by split/server/class."""
    tid: int
    ue: int
    cls: int
    t_arrive: float
    deadline: float             # ABSOLUTE deadline (arrival + class SLO)
    t_start: float = -1.0
    t_done: float = -1.0
    dropped: bool = False
    energy: float = 0.0
    # frozen dispatch decision (set at service start; -1 = never served)
    b: int = -1
    channel: int = -1
    server: int = 0
    power: float = 0.0
    rate: float = 0.0
    t_service: float = 0.0
    # order of this task among the core's start() calls (-1 = never
    # served): pairs each dispatch decision with the outcome of exactly
    # the task it dispatched, which is what rl.streaming reinforces
    start_seq: int = -1

    def task_cost(self, cfg, t0=0.5):
        """Per-task QoS cost (lower is better) of the DISPATCH DECISION:
        service seconds in frame-length units + the miss penalty + the
        energy term. Deliberately the service time, not the sojourn — the
        queue wait is fixed before the decision is made, so charging it
        would only add variance to the credit (the miss outcome still
        folds the deadline pressure in)."""
        return (cfg.tail_weight * self.t_service / t0
                + cfg.miss_penalty * float(self.missed)
                + cfg.energy_weight * self.energy)

    @property
    def sojourn(self) -> float:
        """Arrival-to-completion seconds (queueing + service)."""
        return self.t_done - self.t_arrive

    @property
    def missed(self) -> bool:
        """Dropped, or completed past its deadline (non-preemptive
        service runs to completion; a late finish still missed its SLO)."""
        return self.dropped or self.t_done > self.deadline


class QoSMonitor:
    """Accumulates finished :class:`TaskRecord`\\ s into a QoS report —
    the stream analog of the frame env's eval dict."""

    def __init__(self):
        self.records = []

    def add(self, rec: TaskRecord):
        self.records.append(rec)

    def report(self, horizon=None):
        recs = self.records
        done = [r for r in recs if not r.dropped]
        n = max(len(recs), 1)
        soj = [r.sojourn for r in done]
        rep = {
            "tasks": len(recs),
            "completed": len(done),
            "dropped": len(recs) - len(done),
            "drop_rate": (len(recs) - len(done)) / n,
            "miss_rate": sum(1 for r in recs if r.missed) / n,
            "sojourn_mean": float(np.mean(soj)) if done else float("nan"),
            "energy_task": float(np.mean([r.energy for r in done]))
            if done else float("nan"),
        }
        rep.update({f"sojourn_{k}": v for k, v in tail_stats(soj).items()})
        if horizon:
            rep["throughput"] = len(done) / horizon
        return rep


@dataclasses.dataclass(frozen=True)
class StreamRewardConfig:
    """Weights of the episode-level streaming reward: miss rate is the
    primary SLO term, the p99 sojourn (in units of the frame length t0)
    penalizes the tail even while misses are rare, and a small energy
    term keeps the paper's latency/energy trade-off alive."""
    miss_penalty: float = 4.0
    tail_weight: float = 1.0
    energy_weight: float = 0.1


def stream_reward(report, cfg: StreamRewardConfig = StreamRewardConfig(),
                  *, t0=0.5):
    """Scalar episode reward from a :meth:`QoSMonitor.report` dict —
    what ``rl.streaming`` fine-tunes against. Higher is better; a fully
    dropped stream (NaN tails) scores only its miss penalty."""
    r = -cfg.miss_penalty * report["miss_rate"]
    p99 = report.get("sojourn_p99", float("nan"))
    if p99 == p99:                                   # not NaN
        r -= cfg.tail_weight * p99 / t0
    e = report.get("energy_task", float("nan"))
    if e == e:
        r -= cfg.energy_weight * e
    return float(r)
