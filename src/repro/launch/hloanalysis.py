"""Trip-count-aware analysis of post-SPMD optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop (lax.scan) body ONCE,
so for scan-over-layers models both FLOPs and collective bytes are
undercounted by ~n_layers. This module parses the HLO text, resolves each
computation's execution multiplier (product of enclosing while trip counts,
taken from the loop's ``known_trip_count`` backend config) and reports:

  * collective bytes by type, weighted by multiplier
  * dot FLOPs, weighted  (the remat/redundancy-aware "HLO_FLOPs")
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2,
                "u16": 2, "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4,
                "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16}

_COMP_RE = re.compile(r"^(ENTRY )?%?([\w.\-]+) \(.*\)(?: -> .*)? \{\s*$")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\), condition=%?([\w.\-]+), body=%?([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_SHAPE_RE = re.compile(
    r"(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)"
    r"\[([\d,]*)\]")
_ASSIGN_RE = re.compile(
    r"^\s*(?:ROOT )?%([\w.\-]+) = "
    r"((?:pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)"
    r"\[[\d,]*\])")
_COLL_RE = re.compile(
    r"=\s*(\([^=]*?\)|[\w\[\],{}]+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
# operands may be typed ("dot(f32[128,128]{1,0} %lhs, ...)") or bare
# ("dot(%lhs, ...)") depending on the XLA version
_DOT_RE = re.compile(
    r"=\s*[\w]+\[([\d,]*)\][^=]*?\bdot\("
    r"\s*(?:[\w]+\[[\d,]*\](?:\{[\d,]*\})?\s+)?%([\w.\-]+),")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dims(s):
    return [int(d) for d in s.split(",") if d]


def _nbytes(dt, dims):
    n = 1
    for d in dims:
        n *= d
    return n * _DTYPE_BYTES.get(dt, 4)


def split_computations(text: str):
    """{name: [lines]}; also returns entry computation name."""
    comps, entry = {}, None
    cur, buf = None, []
    for line in text.splitlines():
        stripped = line.rstrip()
        m = _COMP_RE.match(stripped)
        if m:
            cur = m.group(2)
            if m.group(1):
                entry = cur
            buf = []
            comps[cur] = buf
        elif stripped == "}":
            cur = None
        elif cur is not None:
            buf.append(stripped)
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def computation_multipliers(text: str):
    """{computation_name: times executed} via DFS from the entry."""
    comps, entry = split_computations(text)
    mult = defaultdict(float)

    def visit(name, m):
        if name not in comps or m == 0:
            return
        mult[name] += m
        for ln in comps[name]:
            wm = _WHILE_RE.search(ln)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                tm = _TRIP_RE.search(ln)
                trips = int(tm.group(1)) if tm else 1
                visit(cond, m * (trips + 1))
                visit(body, m * trips)
                continue
            bm = _BRANCH_RE.search(ln)
            if bm:
                for callee in re.findall(r"[\w.\-]+", bm.group(1)):
                    visit(callee, m)
                continue
            for cm in _CALL_RE.finditer(ln):
                visit(cm.group(1), m)

    visit(entry, 1.0)
    return comps, dict(mult)


def _group_size(ln):
    g = _GROUP_RE.search(ln)
    if g:
        return max(int(g.group(2)), 1)
    g = _GROUP_LIST_RE.search(ln)
    if g:
        return max(len(g.group(1).split(",")), 1)
    return 2


def _moved_bytes(kind, result_bytes, n):
    """Ring-algorithm bytes actually moved per device, from result bytes."""
    f = (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * result_bytes * f
    if kind == "all-gather":
        return result_bytes * f
    if kind == "reduce-scatter":
        return result_bytes * (n - 1)
    if kind == "all-to-all":
        return result_bytes * f
    return result_bytes          # collective-permute


def weighted_collectives(text: str):
    comps, mult = computation_multipliers(text)
    out = defaultdict(float)
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if not m:
            continue
        for ln in lines:
            cm = _COLL_RE.search(ln)
            if not cm:
                continue
            kind = cm.group(2)
            nbytes = sum(_nbytes(dt, _dims(dims))
                         for dt, dims in _SHAPE_RE.findall(cm.group(1)))
            out[kind] += nbytes * m
            out[kind + "_count"] += m
            out["moved_bytes"] += _moved_bytes(kind, nbytes,
                                               _group_size(ln)) * m
    return dict(out)


def weighted_dot_flops(text: str):
    comps, mult = computation_multipliers(text)
    total = 0.0
    for name, lines in comps.items():
        m = mult.get(name, 0)
        if not m:
            continue
        # symbol table: var -> dims (array results only)
        sym = {}
        for ln in lines:
            am = _ASSIGN_RE.match(ln)
            if am:
                sm = _SHAPE_RE.search(am.group(2))
                if sm:
                    sym[am.group(1)] = _dims(sm.group(2))
        # parameters: "%p = (..) parameter(i)" handled above only for arrays;
        # tuple params feed get-tuple-element lines which carry shapes anyway.
        for ln in lines:
            dm = _DOT_RE.search(ln)
            if not dm:
                continue
            out_dims = _dims(dm.group(1))
            lhs = sym.get(dm.group(2))
            cm = _LHS_CONTRACT_RE.search(ln)
            contract = 1
            if lhs is not None and cm and cm.group(1):
                for c in _dims(cm.group(1)):
                    if c < len(lhs):
                        contract *= lhs[c]
            n_out = 1
            for d in out_dims:
                n_out *= d
            total += 2.0 * n_out * contract * m
    return total


def analyze(text: str):
    return {"collectives": weighted_collectives(text),
            "hlo_dot_flops": weighted_dot_flops(text)}


def cost_analysis_dict(compiled):
    """``compiled.cost_analysis()`` normalized across jax versions (older
    releases return a one-element list of dicts), numeric entries only."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: v for k, v in ca.items() if isinstance(v, (int, float))}


def compiled_costs(fn, *args):
    """Lower + compile ``fn`` on the current backend (args may be
    ShapeDtypeStructs — nothing is materialized or executed) and return
    {flops, bytes_accessed, hlo_dot_flops}: the backend's cost analysis
    with the trip-count-weighted dot FLOPs alongside. ``flops`` falls back
    to the HLO dot count when the backend reports none. Note convolutions
    lower to ``convolution(`` not ``dot(``, so for CNNs the backend count
    is the authoritative one."""
    import jax
    compiled = jax.jit(fn).lower(*args).compile()
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    byt = float(ca.get("bytes accessed", 0.0))
    hlo = weighted_dot_flops(compiled.as_text())
    if flops <= 0.0:
        flops = hlo
    return {"flops": flops, "bytes_accessed": byt, "hlo_dot_flops": hlo}
