"""Step builders: train_step / prefill_step / serve_step closures over a
ModelConfig, plus ShapeDtypeStruct input specs for dry-run lowering."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.models import cache as cache_lib
from repro.models import model as model_lib
from repro.models.layers import dtype_of
from repro.optim import (cosine_schedule, global_norm, make_optimizer)
from repro.optim.optimizers import opt_state_pspec


# ------------------------------------------------------------------ steps
def make_train_step(cfg: ModelConfig, *, base_lr=3e-4, warmup=200,
                    total=10000, clip=1.0):
    opt_init, opt_update = make_optimizer(cfg.optimizer)
    lr_fn = cosine_schedule(base_lr, warmup, total)

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model_lib.loss_fn, has_aux=True)(params, cfg, batch)
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype),
                                       grads)
        lr = lr_fn(opt_state["step"])
        params, opt_state = opt_update(grads, opt_state, params, lr)
        metrics = dict(metrics, loss=loss, grad_norm=gn, lr=lr)
        return params, opt_state, metrics

    return train_step, opt_init


def make_prefill_step(cfg: ModelConfig, attn_len: int):
    def prefill_step(params, tokens, aux_embeds=None):
        return model_lib.prefill(params, cfg, tokens, attn_len=attn_len,
                                 aux_embeds=aux_embeds)
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, token, idx):
        return model_lib.decode_step(params, cfg, cache, token, idx)
    return serve_step


# ------------------------------------------------------------- input specs
def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def params_spec(cfg: ModelConfig, key=None):
    key = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(lambda k: model_lib.init_params(cfg, k), key)


def attn_len_for(cfg: ModelConfig, shape) -> int:
    """Allocated KV length for full-attention layers under this shape."""
    if shape.name == "long_500k":
        return cfg.long_context_window
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of the step that
    this input shape lowers (train_step / prefill_step / serve_step)."""
    shape = INPUT_SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    cdt = dtype_of(cfg.compute_dtype)
    if shape.kind == "train":
        batch = {"tokens": sds((b, s), jnp.int32),
                 "labels": sds((b, s), jnp.int32)}
        if cfg.n_aux_tokens:
            batch["aux_embeds"] = sds((b, cfg.n_aux_tokens, cfg.d_model), cdt)
        return {"batch": batch}
    if shape.kind == "prefill":
        out = {"tokens": sds((b, s), jnp.int32)}
        if cfg.n_aux_tokens:
            out["aux_embeds"] = sds((b, cfg.n_aux_tokens, cfg.d_model), cdt)
        return out
    # decode
    cache = cache_lib.make_cache(cfg, b, attn_len_for(cfg, shape),
                                 leaf_fn=lambda sh, dt: sds(sh, dt))
    return {"cache": cache, "token": sds((b, 1), jnp.int32),
            "idx": sds((), jnp.int32)}


def long_context_applicable(cfg: ModelConfig) -> bool:
    """long_500k needs sub-quadratic decode state. All archs qualify here:
    SSM/hybrid natively; attention archs via the sliding-window cache variant
    (cfg.long_context_window) — see DESIGN.md."""
    return True
