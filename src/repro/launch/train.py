"""Training launcher: ``--arch <id>`` selects an assigned architecture;
reduced-scale flags allow CPU runs; on a real TPU fleet the production mesh
from mesh.py and the sharding rules from models/sharding.py apply unchanged.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
      --reduce --steps 100 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from repro.ckpt import save_checkpoint
from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.data.synthetic import TokenPipelineConfig, token_batch_stream
from repro.launch.steps import make_train_step
from repro.models import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ARCH_IDS)
    ap.add_argument("--reduce", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--out", default="artifacts/train")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg, n_layers=4, d_model=256)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"[train] arch={args.arch} params={n/1e6:.1f}M "
          f"optimizer={cfg.optimizer}")

    train_step, opt_init = make_train_step(
        cfg, base_lr=args.lr, warmup=min(20, args.steps // 5),
        total=args.steps)
    opt = opt_init(params)
    step_fn = jax.jit(train_step)
    stream = token_batch_stream(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch=args.batch))

    os.makedirs(args.out, exist_ok=True)
    logf = open(os.path.join(args.out, f"{args.arch}.jsonl"), "w")
    t0 = time.time()
    for step in range(1, args.steps + 1):
        batch = next(stream)
        if cfg.n_aux_tokens:
            import jax.numpy as jnp
            batch = dict(batch, aux_embeds=jnp.zeros(
                (args.batch, cfg.n_aux_tokens, cfg.d_model)))
        params, opt, m = step_fn(params, opt, batch)
        if step % 10 == 0 or step == 1:
            rec = {"step": step, "loss": float(m["loss"]),
                   "grad_norm": float(m["grad_norm"]),
                   "elapsed_s": round(time.time() - t0, 1)}
            print(f"[train] {rec}")
            logf.write(json.dumps(rec) + "\n")
            logf.flush()
        if args.ckpt_every and step % args.ckpt_every == 0:
            save_checkpoint(os.path.join(args.out, f"{args.arch}_{step}"),
                            params, step=step)
    save_checkpoint(os.path.join(args.out, f"{args.arch}_final"), params,
                    step=args.steps)
    print(f"[train] done; checkpoints + logs in {args.out}/")


if __name__ == "__main__":
    main()
