"""Serving launcher: batched prefill + decode loop for an assigned arch
(reduced on CPU), reporting per-phase timings and cache sizes — the edge
half of the paper's collaborative-inference pipeline.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
      --reduce --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ARCH_IDS)
    ap.add_argument("--reduce", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="shrink the config for CPU (--no-reduce for "
                         "the full-size arch)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = reduced(cfg, n_layers=4, d_model=256)
    params = init_params(cfg, jax.random.PRNGKey(0))

    attn_len = args.prompt_len + args.gen
    prefill_step = jax.jit(make_prefill_step(cfg, attn_len))
    serve_step = jax.jit(make_serve_step(cfg))

    toks = jax.random.randint(jax.random.PRNGKey(1),
                              (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)
    aux = None
    if cfg.n_aux_tokens:
        aux = jnp.zeros((args.batch, cfg.n_aux_tokens, cfg.d_model))

    t0 = time.time()
    if aux is not None:
        logits, cache = prefill_step(params, toks, aux)
    else:
        logits, cache = prefill_step(params, toks)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    cache_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree_util.tree_leaves(cache))
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: "
          f"{1e3*t_prefill:.1f} ms, cache {cache_bytes/1e6:.1f} MB")

    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    outs = [tok]                 # the prefill argmax is generated token 0
    t0 = time.time()
    n_steps = max(args.gen - 1, 0)
    for i in range(n_steps):
        logits, cache = serve_step(params, cache, tok,
                                   jnp.int32(args.prompt_len + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    print(f"[serve] decoded {args.gen} tokens/seq "
          f"({n_steps} decode steps): "
          f"{1e3*dt/max(n_steps, 1):.1f} ms/token (batch {args.batch})")
    gen = jnp.concatenate(outs, axis=1)
    print(f"[serve] sample continuation (seq 0): {gen[0][:16].tolist()}")


if __name__ == "__main__":
    main()
