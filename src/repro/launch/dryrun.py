import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402  (XLA_FLAGS must precede any jax-importing module)
"""Multi-pod dry-run: lower + compile every (arch x input-shape) combination
on the production meshes, record memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import INPUT_SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (attn_len_for, input_specs, make_prefill_step,
                                make_serve_step, make_train_step, params_spec)
from repro.models import sharding as shd
from repro.optim.optimizers import opt_state_pspec

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")


def collective_bytes(hlo_text: str):
    """Sum result-shape bytes of collective ops in post-SPMD HLO, by type."""
    out = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(2)
        total = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES.get(dt, 4)
        out[kind] = out.get(kind, 0) + total
        out.setdefault(kind + "_count", 0)
        out[kind + "_count"] += 1
    return out


def _metrics_shardings(mesh, struct):
    return jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), struct)


def build_lowered(arch: str, shape_name: str, *, multi_pod: bool):
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    from repro.models import meshctx
    meshctx.set_mesh(mesh)  # enables EP shard_map + activation pinning
    shape = INPUT_SHAPES[shape_name]
    specs = input_specs(cfg, shape_name)
    pstruct = params_spec(cfg)
    ppspecs = shd.params_pspecs(mesh, pstruct, cfg)
    psh = shd.wrap(mesh, ppspecs)
    sizes = {"param_bytes_per_device": shd.bytes_per_device(pstruct, psh)}

    if shape.kind == "train":
        train_step, opt_init = make_train_step(cfg)
        ostruct = jax.eval_shape(opt_init, pstruct)
        ospecs = opt_state_pspec(cfg.optimizer, ppspecs)
        osh = shd.wrap(mesh, ospecs)
        bsh = shd.batch_shardings(mesh, specs["batch"])
        _, _, mstruct = jax.eval_shape(
            train_step, pstruct, ostruct, specs["batch"])
        msh = _metrics_shardings(mesh, mstruct)
        fn = jax.jit(train_step, in_shardings=(psh, osh, bsh),
                     out_shardings=(psh, osh, msh))
        args = (pstruct, ostruct, specs["batch"])
        sizes["opt_bytes_per_device"] = shd.bytes_per_device(ostruct, osh)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, attn_len_for(cfg, shape))
        tok_sh = shd.batch_shardings(mesh, {"t": specs["tokens"]})["t"]
        in_sh = [psh, tok_sh]
        args = [pstruct, specs["tokens"]]
        if "aux_embeds" in specs:
            in_sh.append(shd.batch_shardings(
                mesh, {"a": specs["aux_embeds"]})["a"])
            args.append(specs["aux_embeds"])
        lstruct, cstruct = jax.eval_shape(step, *args)
        csh = shd.cache_shardings(mesh, cstruct, cfg)
        lsh = shd.batch_shardings(mesh, {"l": lstruct})["l"]
        fn = jax.jit(step, in_shardings=tuple(in_sh),
                     out_shardings=(lsh, csh))
        args = tuple(args)
        sizes["cache_bytes_per_device"] = shd.bytes_per_device(cstruct, csh)
    else:  # decode
        step = make_serve_step(cfg)
        csh = shd.cache_shardings(mesh, specs["cache"], cfg)
        tok_sh = shd.batch_shardings(mesh, {"t": specs["token"]})["t"]
        idx_sh = NamedSharding(mesh, P())
        lstruct, _ = jax.eval_shape(step, pstruct, specs["cache"],
                                    specs["token"], specs["idx"])
        lsh = shd.batch_shardings(mesh, {"l": lstruct})["l"]
        fn = jax.jit(step, in_shardings=(psh, csh, tok_sh, idx_sh),
                     out_shardings=(lsh, csh))
        args = (pstruct, specs["cache"], specs["token"], specs["idx"])
        sizes["cache_bytes_per_device"] = shd.bytes_per_device(
            specs["cache"], csh)

    return fn, args, mesh, sizes


def run_one(arch: str, shape_name: str, *, multi_pod: bool,
            keep_text: bool = False):
    t0 = time.time()
    fn, args, mesh, sizes = build_lowered(arch, shape_name,
                                          multi_pod=multi_pod)
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "n_devices": mesh.size,
           "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2)}
    rec.update(sizes)
    try:
        from repro.launch import hloanalysis
        rec["cost_analysis"] = hloanalysis.cost_analysis_dict(compiled)
    except Exception as e:  # pragma: no cover
        rec["cost_analysis_error"] = str(e)
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: getattr(ma, k) for k in
            ("generated_code_size_in_bytes", "argument_size_in_bytes",
             "output_size_in_bytes", "temp_size_in_bytes",
             "alias_size_in_bytes", "peak_memory_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        rec["memory_analysis_error"] = str(e)
    text = compiled.as_text()
    rec["collectives"] = collective_bytes(text)  # unweighted (reference)
    try:
        from repro.launch import hloanalysis
        w = hloanalysis.analyze(text)
        rec["collectives_weighted"] = w["collectives"]
        rec["hlo_dot_flops"] = w["hlo_dot_flops"]
    except Exception as e:  # pragma: no cover
        rec["hlo_analysis_error"] = str(e)
    rec["hlo_bytes"] = len(text)
    if keep_text:
        rec["hlo_text"] = text
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = list(ARCH_IDS) if (args.all or not args.arch) else [args.arch]
    shapes = (list(INPUT_SHAPES) if (args.all or not args.shape)
              else [args.shape])
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'pod2' if mp else 'pod1'}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = run_one(arch, shape, multi_pod=mp)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    ca = rec.get("cost_analysis", {})
                    print(f"[ok] {tag} compile={rec['compile_s']}s "
                          f"flops={ca.get('flops', 0):.3e} "
                          f"coll={sum(v for k, v in rec['collectives'].items() if not k.endswith('_count')):.3e}B",
                          flush=True)
                except Exception:
                    failures += 1
                    with open(path + ".err", "w") as f:
                        f.write(traceback.format_exc())
                    print(f"[FAIL] {tag}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} dry-run combinations failed")
    print("all dry-run combinations compiled OK")


if __name__ == "__main__":
    main()
