"""Production mesh definitions (TPU v5e target).

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over however many (CPU) devices exist — used by tests."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))


# v5e hardware constants used by the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
