"""Pytree checkpointing: .npz leaves + JSON treedef, atomic writes.

No external deps (orbax unavailable offline). Handles arbitrary nested
dict/list/tuple pytrees of jnp/np arrays and python scalars.
"""
from __future__ import annotations

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str, tree, *, step: int = 0, extra: dict = None):
    """Atomically save a pytree to <path>.npz + <path>.json."""
    leaves, treedef = _flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    meta = {"treedef": str(treedef), "n_leaves": len(leaves), "step": step,
            "extra": extra or {}}
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)))
    os.close(fd)
    np.savez(tmp + ".npz", **arrays)
    os.replace(tmp + ".npz", path + ".npz")
    with open(tmp, "w") as f:
        json.dump(meta, f)
    os.replace(tmp, path + ".json")


def load_checkpoint(path: str, like):
    """Restore into the structure of `like` (shapes/dtypes preserved from
    disk). Returns (tree, meta)."""
    with open(path + ".json") as f:
        meta = json.load(f)
    data = np.load(path + ".npz")
    leaves = [jnp.asarray(data[f"leaf_{i}"]) for i in range(meta["n_leaves"])]
    _, treedef = jax.tree_util.tree_flatten(like)
    return jax.tree_util.tree_unflatten(treedef, leaves), meta
