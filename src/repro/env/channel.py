"""Wireless uplink model (paper §3.3, Eq. 5).

Urban cellular: channel gain g_n = d_n^-l (path-loss exponent l=3), static
channels with bandwidth omega and background noise sigma. The uplink rate of
UE n under policy-induced interference is

  r_n = omega_c * log2(1 + p_n g_n / (sigma_c + sum_{i != n, c_i = c_n,
                                       i offloading} p_i g_i))
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def channel_gain(d, pathloss=3.0):
    return jnp.power(jnp.maximum(d, 1.0), -pathloss)


def uplink_rates(p, c, g, transmitting, *, omega, sigma):
    """p, g: (N,) watts/gains; c: (N,) int channel ids;
    transmitting: (N,) bool (offloading AND has work).
    omega, sigma: (C,) per-channel bandwidth (Hz) and noise (W).
    Returns (N,) bits/s."""
    pg = p * g * transmitting
    n_ch = omega.shape[0]
    onehot = jax.nn.one_hot(c, n_ch, dtype=pg.dtype)    # (N, C)
    per_channel = onehot.T @ pg                          # (C,) total power
    interference = per_channel[c] - pg                   # exclude self
    sinr = (p * g) / (sigma[c] + interference)
    return omega[c] * jnp.log2(1.0 + sinr)
