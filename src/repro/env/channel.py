"""Wireless uplink model (paper §3.3, Eq. 5), extended to multi-server
edge pools.

Urban cellular: channel gain g_n = d_n^-l (path-loss exponent l=3), static
channels with bandwidth omega and background noise sigma. The uplink rate of
UE n under policy-induced interference is

  r_n = omega_c * log2(1 + p_n g_n / (sigma_c + sum_{i != n, c_i = c_n,
                                       i offloading} p_i g_i))

With an edge POOL every server operates its own set of C channels:
omega/sigma become (E, C) and each UE's `route` e_n selects the server.
Interference then couples only UEs sharing the same (server, channel)
slot — routing load across servers is how a policy buys itself clean
spectrum. A single server (route=None, 1-D omega/sigma) is exactly the
paper's model, computed by the identical graph.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def channel_gain(d, pathloss=3.0):
    return jnp.power(jnp.maximum(d, 1.0), -pathloss)


def uplink_rates(p, c, g, transmitting, *, omega, sigma, route=None):
    """p, g: (N,) watts/gains (g already includes the UE->server path);
    c: (N,) int channel ids; transmitting: (N,) bool (offloading AND has
    work). omega, sigma: per-channel bandwidth (Hz) and noise (W) — (C,)
    for a single server, or (E, C) with `route` (N,) int server ids.
    Returns (N,) bits/s."""
    pg = p * g * transmitting
    if route is None:
        slot, n_slots = c, omega.shape[0]
        om, sg = omega[c], sigma[c]
    else:
        n_ch = omega.shape[1]
        slot, n_slots = route * n_ch + c, omega.size
        om, sg = omega[route, c], sigma[route, c]
    onehot = jax.nn.one_hot(slot, n_slots, dtype=pg.dtype)   # (N, E*C)
    per_slot = onehot.T @ pg                                 # total power
    interference = per_slot[slot] - pg                       # exclude self
    sinr = (p * g) / (sg + interference)
    return om * jnp.log2(1.0 + sinr)
