from repro.env.mecenv import (EnvParams, EnvState, MECEnv, make_env_params,
                              per_ue)
