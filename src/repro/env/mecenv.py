"""Multi-agent collaborative-inference MEC environment (paper §3–4).

State s_t = {k_t, l_t, n_t, d} (remaining tasks, remaining local seconds of
the half-completed task, remaining offload bits, UE distances). Actions are
a flat dict pytree keyed by the env's declarative
:class:`~repro.rl.actionspace.HybridActionSpace` (``env.action_space``):

    {"split": b, "channel": c, "power": p}            single server
    {"split": b, "channel": c, "route": e, "power": p}  edge pool

Reward (Eq. 12):

    r_t = -T0 / K_t - beta * E_t / K_t

Frame dynamics are computed *analytically* (no inner loop): with the frame's
rates fixed (Eq. 5 interference, per the paper), each frame runs three
phases per UE, with EXACT work carry-over across frame boundaries:

  phase 1  resume the in-flight task where the previous frame left it:
           burn its remaining local seconds ``l``, then its remaining
           offload bits ``n`` at this frame's rate. If the frame ends
           first, the unfinished remainder ``(l1, n1)`` IS the next
           state's ``(l, n)`` — the task resumes next frame, never
           restarts (a UE holds at most one in-flight task, and an open
           carry-over leaves ``t_rem == 0``, so phases 2/3 are inert).
  phase 2  run floor(t_rem / t_task) whole tasks at the new split b.
  phase 3  start one partial task at b; its remainder becomes the next
           state's ``(l, n)`` when no carry-over is open.

Work is conserved across frames (Eq. 7/8): a task needing m > 1 frames
completes after exactly its closed-form latency, paying exactly its
closed-form energy, regardless of how many frame boundaries it spans —
only the per-frame *rates* (interference, routing) may change under it.
The single non-conservative term is TX_EPS_BITS: a transmit remainder
below one bit is treated as complete (absorbing float residue from
``n - (n/r)*r``), and every bit absorbed is reported in
``info["eps_bits"]`` so conservation ledgers can account for it
explicitly. Fully vectorized over UEs and vmappable over parallel envs.

UEs may be heterogeneous: the overhead tables l_new/n_new/feasible are
(N, B_max+2) — one row per UE, built from a core.split.FleetPlan mixing
backbones and device tiers — and p_compute is a (N,) vector. A single
SplitPlan broadcasts to N identical rows, reproducing the seed scenario.

Fleets may also be DYNAMIC: with `churn_rate` > 0 and/or `leave_rate` > 0
(EnvParams), UEs join from a standby pool (Poisson arrivals per standby
slot: join prob 1 - exp(-churn_rate) per frame) and depart (geometric
session length: leave prob `leave_rate` per frame). `EnvState.active` is a
(N,) bool mask — N stays the static *maximum* fleet size, so every shape
is fixed and the env stays jit/vmap-clean; membership is data, not
structure. Inactive UEs contribute no interference, energy, completions,
or reward; a re-joining UE draws a fresh task queue and distance. With
both rates at 0.0 the dynamic machinery is compiled out entirely and the
env is bit-for-bit identical to the static one (same PRNG key stream).

The EDGE side may be a POOL: a ``core.fleets.EdgePool`` of E servers with
distinct compute tiers, positions (per-server distance scaling of the
path loss), and per-server uplink channels (omega/sigma become (E, C)).
The action space then grows a discrete ``route`` head: interference
couples only UEs on the same (server, channel) slot, and each offloaded
task pays an edge-service time t_edge[n, b, e] * (number of UEs sharing
server e) — a processor-sharing model of the server's compute, resolved
analytically within the frame. Phase-1/3 boundary tasks only track their
UE-side seconds and bits (their edge tail is pipelined across frames);
the edge term rate-limits the whole-task throughput of phase 2, which
dominates whenever queues are deep. A pool of ONE paper-default server
compiles all of this out: `self.multi_server` is a Python-level flag, so
the single-server env is bit-for-bit the seed env, PRNG stream included.

Pool GEOMETRY may be resampled per episode (PR 5): an env built with
``pool_ranges`` supports ``reset(key, randomize=True)``, which draws
every server's [dist_scale, bw_scale, slowness] uniformly from the
ranges and stores it as ``EnvState.geom``; physics and the entity-set
observation (``observe_entities`` — per-UE rows, per-server rows, and
UE x server edge features for the shared per-server route scorer) then
follow the drawn geometry, and each auto-reset redraws it. Whether a
state carries geometry is a pytree-structure (trace-time) property, so
static-pool envs compile exactly the pre-PR5 graph.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import overhead as oh
from repro.core.fleets import (BITS_NORM, DIST_NORM, EDGE_SLOW_NORM,
                               RATE_NORM, EdgePool, pool_aggregate_features,
                               pool_geometry, ue_edge_work,
                               ue_table_features)
from repro.core.split import FleetPlan, SplitPlan
from repro.env.channel import channel_gain, uplink_rates
from repro.rl.actionspace import (ContinuousHead, DiscreteHead,
                                  HybridActionSpace)


class EnvParams(NamedTuple):
    l_new: jnp.ndarray      # (N, B_max+2) local+compression seconds per split
    n_new: jnp.ndarray      # (N, B_max+2) offload bits per split
    feasible: jnp.ndarray   # (N, B_max+2) bool; False on padded actions
    p_compute: jnp.ndarray  # (N,) per-UE compute power (W)
    t0: jnp.ndarray         # frame seconds
    beta: jnp.ndarray
    omega: jnp.ndarray      # (C,) single server, (E, C) edge pool
    sigma: jnp.ndarray      # (C,) / (E, C)
    p_max: jnp.ndarray
    lam_tasks: jnp.ndarray  # Poisson mean of K_n
    d_low: jnp.ndarray
    d_high: jnp.ndarray
    n_ue: int
    pathloss: jnp.ndarray
    churn_rate: jnp.ndarray = jnp.float32(0.0)  # Poisson joins / standby slot
    leave_rate: jnp.ndarray = jnp.float32(0.0)  # per-frame departure prob
    server_dist: Optional[jnp.ndarray] = None   # (E,) distance scale per server
    t_edge: Optional[jnp.ndarray] = None        # (N, B_max+2, E) edge seconds
    # entity-set observation / geometry-resampling support (PR 5). All are
    # derivable constants: the default paths above stay bit-for-bit theirs.
    pool_geom: Optional[jnp.ndarray] = None     # (E, 3) [dist, bw, slowness]
    omega_cell: Optional[jnp.ndarray] = None    # (C,) base channel bandwidth
    edge_work: Optional[jnp.ndarray] = None     # (N, B_max+2) edge-tail FLOPs
    pool_low: Optional[jnp.ndarray] = None      # (E, 3) resample range low
    pool_high: Optional[jnp.ndarray] = None     # (E, 3) resample range high


# per-UE featurized observation layout (see MECEnv.observe_per_ue): the
# dimension is a CONSTANT — independent of fleet size N, action width
# B_max+2, and pool size E — so one weight-shared policy transfers across
# fleet sizes, device mixes, and server-pool layouts with zero retraining.
OBS_UE_OWN = 5              # own queue/task/channel state (zeroed standby)
OBS_UE_ACT = 1              # activity flag
OBS_UE_DEVICE = 5           # static device/table descriptor (fleets.py)
OBS_UE_POOL = 4             # static edge-pool aggregate (fleets.py)
OBS_UE_FLEET = 4            # mean-field fleet aggregates
OBS_UE_DIM = OBS_UE_OWN + OBS_UE_ACT + OBS_UE_DEVICE + OBS_UE_POOL \
    + OBS_UE_FLEET

# entity-set observation layout (see MECEnv.observe_entities): per-UE rows
# drop the flattened pool aggregate (servers are first-class entities now),
# servers carry their geometry + occupancy, and UE x server edges carry the
# pairwise physics a route scorer needs. Every dimension is a CONSTANT —
# independent of N AND E — so one shared per-server scorer transfers across
# fleet sizes, pool layouts, and pool SIZES.
OBS_ENT_UE = OBS_UE_OWN + OBS_UE_ACT + OBS_UE_DEVICE + OBS_UE_FLEET
OBS_ENT_SRV = 4             # dist scale, bw scale, slowness, UEs per slot
OBS_ENT_EDGE = 3            # distance, clean-rate proxy, edge-service time


# Transmit-bit epsilon: a remaining-offload count below this many bits is
# treated as transmission complete. It exists to absorb float32 residue
# (``n - (n/r) * r`` can leave O(n * eps_f32) ~ 0.1 bits on a 1e6-bit
# feature map) — NOT to model physics: a real sub-bit payload can't be
# sent. Each frame reports the bits it absorbed in ``info["eps_bits"]``,
# so work-conservation ledgers balance exactly instead of silently losing
# up to TX_EPS_BITS per task completion.
TX_EPS_BITS = 1.0


def per_ue(table: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Gather each UE's own table entry: table (N, B+2), b (N,) -> (N,).
    vmap-friendly (no dynamic shapes)."""
    return jnp.take_along_axis(table, b[:, None], axis=1)[:, 0]


def _ue_tables(plan, n_ue):
    """(t_local, feasible, peak_flops) per UE as numpy, for the edge-side
    service-time table (t_edge ~ remaining FLOPs / server speed, with
    remaining FLOPs ~ (t_local_full - t_local_b) * ue_peak)."""
    if isinstance(plan, FleetPlan):
        t_loc = np.asarray(plan.t_local, np.float64)
        feas = np.asarray(plan.feasible, bool)
        peaks = np.array([pr.device.peak_flops for pr in plan.profiles])
    else:
        t_loc = np.tile(np.asarray(plan.t_local, np.float64)[None],
                        (n_ue, 1))
        feas = np.tile(np.asarray(plan.feasible, bool)[None], (n_ue, 1))
        dev = oh.UE_TIERS.get(plan.device, oh.JETSON_NANO) \
            if plan.device else oh.JETSON_NANO
        peaks = np.full((n_ue,), dev.peak_flops)
    return t_loc, feas, peaks


def make_env_params(plan: Union[SplitPlan, FleetPlan], *, n_ue=5,
                    n_channels=2, t0=0.5, beta=0.47, p_compute=None,
                    omega=1e6, sigma=1e-9, p_max=0.5, lam_tasks=200.0,
                    d_low=1.0, d_high=100.0, pathloss=3.0,
                    churn_rate=0.0, leave_rate=0.0,
                    pool: Optional[EdgePool] = None,
                    pool_ranges=None) -> EnvParams:
    """A single SplitPlan is broadcast to n_ue identical UEs (the seed
    homogeneous scenario); a FleetPlan supplies per-UE tables and device
    power draws (n_ue/p_compute then come from the fleet). Nonzero
    churn_rate/leave_rate make the fleet dynamic, and an EdgePool of more
    than one server (or one non-default server) makes the edge side
    heterogeneous with a routed action space (see module docstring). A
    pool of one paper-default server builds EXACTLY the single-server
    params, bit-for-bit.

    ``pool_ranges`` — a ``(low, high)`` pair of (E, 3) geometry bounds
    (see ``core.fleets.random_pool_ranges``) — makes the pool geometry
    RESAMPLABLE: ``env.reset(key, randomize=True)`` draws each server's
    [dist_scale, bw_scale, slowness] uniformly from the ranges and the
    episode's physics and entity observations follow the drawn geometry.
    Requires a multi-server pool; the default (non-randomized) reset and
    every existing code path are unaffected."""
    if isinstance(plan, FleetPlan):
        n_ue = plan.n_ue
        l_new = jnp.asarray(plan.t_local + plan.t_comp, jnp.float32)
        n_new = jnp.asarray(plan.f_bits, jnp.float32)
        feasible = jnp.asarray(plan.feasible)
        p_vec = jnp.asarray(plan.p_compute if p_compute is None
                            else np.full((n_ue,), p_compute), jnp.float32)
    else:
        l_new = jnp.tile(jnp.asarray(plan.t_local + plan.t_comp,
                                     jnp.float32)[None], (n_ue, 1))
        n_new = jnp.tile(jnp.asarray(plan.f_bits, jnp.float32)[None],
                         (n_ue, 1))
        feasible = jnp.tile(jnp.asarray(plan.feasible)[None], (n_ue, 1))
        p_vec = jnp.full((n_ue,), 2.1 if p_compute is None else p_compute,
                         jnp.float32)

    t_loc, feas_np, peaks = _ue_tables(plan, n_ue)
    work = ue_edge_work(t_loc, feas_np, peaks)       # (N, B+2) float64
    if pool is None or pool.is_single_paper_server:
        if pool_ranges is not None:
            raise ValueError("pool_ranges needs a multi-server EdgePool")
        omega_t = jnp.full((n_channels,), omega, jnp.float32)
        sigma_t = jnp.full((n_channels,), sigma, jnp.float32)
        server_dist = t_edge = None
    else:
        bw = np.array([s.bw_scale for s in pool.servers])      # (E,)
        omega_t = jnp.asarray(bw[:, None] * np.full((n_channels,), omega),
                              jnp.float32)
        sigma_t = jnp.full((pool.n_servers, n_channels), sigma, jnp.float32)
        server_dist = jnp.asarray([s.dist_scale for s in pool.servers],
                                  jnp.float32)
        speed = np.array([s.edge_speed for s in pool.servers])
        te = work[:, :, None] / np.where(speed > 0, speed, np.inf)
        t_edge = jnp.asarray(te, jnp.float32)

    pool_low = pool_high = None
    if pool_ranges is not None:
        lo, hi = pool_ranges
        shape = (pool.n_servers, 3)
        if np.asarray(lo).shape != shape or np.asarray(hi).shape != shape:
            raise ValueError(f"pool_ranges must be (low, high) {shape} "
                             f"arrays, got {np.asarray(lo).shape}")
        pool_low = jnp.asarray(lo, jnp.float32)
        pool_high = jnp.asarray(hi, jnp.float32)

    return EnvParams(
        l_new=l_new, n_new=n_new, feasible=feasible, p_compute=p_vec,
        t0=jnp.float32(t0), beta=jnp.float32(beta),
        omega=omega_t, sigma=sigma_t,
        p_max=jnp.float32(p_max), lam_tasks=jnp.float32(lam_tasks),
        d_low=jnp.float32(d_low), d_high=jnp.float32(d_high),
        n_ue=n_ue, pathloss=jnp.float32(pathloss),
        churn_rate=jnp.float32(churn_rate),
        leave_rate=jnp.float32(leave_rate),
        server_dist=server_dist, t_edge=t_edge,
        pool_geom=jnp.asarray(pool_geometry(pool)),
        omega_cell=jnp.full((n_channels,), omega, jnp.float32),
        edge_work=jnp.asarray(work, jnp.float32),
        pool_low=pool_low, pool_high=pool_high)


class EnvState(NamedTuple):
    k: jnp.ndarray          # (N,) remaining tasks (incl. in-flight)
    l: jnp.ndarray          # (N,) remaining local seconds of current task
    n: jnp.ndarray          # (N,) remaining offload bits of current task
    d: jnp.ndarray          # (N,) distances
    t: jnp.ndarray          # frame counter
    key: jnp.ndarray
    active: jnp.ndarray = None  # (N,) bool membership mask (all True static)
    # (E, 3) resampled pool geometry, or None on the static-pool path.
    # Geometry is DATA like the churn mask: shapes stay fixed, and whether
    # a state carries it is a trace-time (pytree-structure) property, so
    # the default envs compile exactly the pre-PR5 graph.
    geom: jnp.ndarray = None


class MECEnv:
    """Functional env; all methods are jit/vmap friendly.

    `self.dynamic` and `self.multi_server` are Python-level flags fixed at
    construction: when both churn rates are 0.0 every churn branch below
    is skipped at trace time, and with a single paper-default server every
    routing branch is too — the compiled single-server static env is
    exactly the seed one (identical computation graph AND identical PRNG
    key stream).

    `self.action_space` declares the hybrid action heads; `step` consumes
    the matching actions dict. Per-actor feasibility lives on the space
    (`action_masks` adds the state-dependent restriction for dynamic
    fleets)."""

    def __init__(self, params: EnvParams):
        self.params = params
        self.n_actions_b = int(params.l_new.shape[1])
        self.n_channels = int(params.omega.shape[-1])
        self.multi_server = params.omega.ndim == 2
        self.n_servers = int(params.omega.shape[0]) if self.multi_server \
            else 1
        self.dynamic = bool(float(params.churn_rate) > 0.0
                            or float(params.leave_rate) > 0.0)
        # dynamic fleets append an activity flag + fleet-size feature per UE
        self.obs_dim = (6 if self.dynamic else 4) * params.n_ue
        # static rows of the per-UE featurized observation (computed once
        # in numpy; observe_per_ue closes over them as constants)
        self.ue_feat_dim = OBS_UE_DIM
        self._ue_static = jnp.asarray(ue_table_features(
            params.l_new, params.n_new, params.feasible, params.p_compute,
            params.t0))
        self._pool_static = jnp.asarray(pool_aggregate_features(
            params.server_dist, params.omega, params.t_edge,
            params.feasible, params.t0))
        self._min_dist_scale = 1.0 if params.server_dist is None \
            else float(np.asarray(params.server_dist).min())
        # entity-set observation support: server geometry (static default),
        # per-UE mean feasible edge-tail work, and resampling ranges
        self.randomizable = params.pool_low is not None
        self.entity_dims = {"ue": OBS_ENT_UE, "server": OBS_ENT_SRV,
                            "edge": OBS_ENT_EDGE}
        work = np.asarray(params.edge_work, np.float64)
        offl_feas = np.asarray(params.feasible, bool)[:, :-1]
        cnt = np.maximum(offl_feas.sum(axis=1), 1)
        self._ue_work_mean = jnp.asarray(
            (work[:, :-1] * offl_feas).sum(axis=1) / cnt, jnp.float32)
        # physics constants for the fused pair-scorer kernel (layout in
        # kernels/pair_scorer.py) — the kernel package stays env-free
        n_srv = int(params.pool_geom.shape[0])
        self._scorer_consts = jnp.asarray([
            params.pathloss, params.p_max, params.sigma.mean(),
            params.omega_cell.mean() / RATE_NORM, params.t0,
            n_srv * self.n_channels, DIST_NORM, 1.0 / EDGE_SLOW_NORM,
        ], jnp.float32)
        discrete = [DiscreteHead("split", self.n_actions_b),
                    DiscreteHead("channel", self.n_channels)]
        if self.multi_server:
            discrete.append(DiscreteHead("route", self.n_servers))
        self.action_space = HybridActionSpace(
            discrete=tuple(discrete),
            continuous=(ContinuousHead("power", 1e-4, float(params.p_max)),),
            masks={"split": params.feasible})

    def reset(self, key, *, eval_mode=False, randomize=False) -> EnvState:
        """``randomize=True`` (needs ``pool_ranges`` at construction) draws
        this episode's pool geometry uniformly from the ranges and stores
        it on the state; physics and entity observations then follow the
        drawn geometry, and every auto-reset redraws it. The default reset
        consumes exactly the pre-PR5 key stream."""
        p = self.params
        geom = None
        if randomize:
            if not self.randomizable:
                raise ValueError("randomize=True needs pool_ranges")
            key, kg = jax.random.split(key)
            geom = self._draw_geom(kg)
        kk, kd, kn = jax.random.split(key, 3)
        if eval_mode:
            k = jnp.full((p.n_ue,), p.lam_tasks, jnp.float32)
            d = jnp.full((p.n_ue,), 50.0, jnp.float32)
        else:
            k = jax.random.poisson(kk, p.lam_tasks, (p.n_ue,)).astype(jnp.float32)
            d = jax.random.uniform(kd, (p.n_ue,), minval=p.d_low,
                                   maxval=p.d_high)
        return EnvState(k=k, l=jnp.zeros((p.n_ue,)), n=jnp.zeros((p.n_ue,)),
                        d=d, t=jnp.zeros((), jnp.int32), key=kn,
                        active=jnp.ones((p.n_ue,), bool), geom=geom)

    def _draw_geom(self, key):
        p = self.params
        return jax.random.uniform(key, p.pool_low.shape, minval=p.pool_low,
                                  maxval=p.pool_high)

    def _geom(self, s: EnvState):
        """This state's (E, 3) pool geometry: resampled (on the state) or
        the construction-time default."""
        return self.params.pool_geom if s.geom is None else s.geom

    def _pool_phys(self, s: EnvState):
        """None on the static-geometry path (physics read the precomputed
        params arrays — bit-for-bit the pre-PR5 graph); with resampled
        geometry, the (server_dist, omega, t_edge) triple recomputed from
        the state's draw."""
        if not self.multi_server or s.geom is None:
            return None
        p = self.params
        dist = s.geom[:, 0]
        omega = s.geom[:, 1][:, None] * p.omega_cell[None, :]
        # service time is LINEAR in the drawn slowness (0 = instant edge)
        t_edge = p.edge_work[:, :, None] * s.geom[None, None, :, 2]
        return dist, omega, t_edge

    def observe(self, s: EnvState):
        p = self.params
        base = [s.k / jnp.maximum(p.lam_tasks, 1.0),
                s.l / p.t0,
                s.n / 1e6,
                s.d / 100.0]
        if self.dynamic:
            act = s.active.astype(jnp.float32)
            frac = jnp.broadcast_to(act.sum() / p.n_ue, (p.n_ue,))
            base += [act, frac]
        return jnp.concatenate(base)

    def observe_per_ue(self, s: EnvState):
        """Structured per-UE feature rows for a WEIGHT-SHARED policy:
        (N, OBS_UE_DIM), one row per actor, dimension independent of N,
        B_max, and E (raw tables and pools enter only as normalized scalar
        summaries — see core.fleets). Row layout:

          own (5, zeroed while standby): queue k, in-flight local seconds,
              in-flight offload bits, distance, distance to the NEAREST
              server (pool-position aware)
          activity flag (1)
          device/table descriptor (5): fleets.ue_table_features
          pool aggregate (4): fleets.pool_aggregate_features
          mean-field fleet aggregates (4): active fraction, mean active
              queue, mean active distance, active UEs per (server,
              channel) slot — O(1) context in N, permutation-invariant

        Rows are permutation-EQUIVARIANT under UE reordering (own/device
        features permute, aggregates are symmetric), which is what makes
        the shared policy a set function over the fleet."""
        p = self.params
        n = p.n_ue
        act = s.active.astype(jnp.float32)
        own = jnp.stack([
            s.k / jnp.maximum(p.lam_tasks, 1.0),
            s.l / p.t0,
            s.n / BITS_NORM,
            s.d / DIST_NORM,
            s.d * self._min_dist_scale / DIST_NORM,
        ], axis=1) * act[:, None]
        n_act = jnp.maximum(act.sum(), 1.0)
        fleet = jnp.stack([
            act.sum() / n,
            (s.k * act).sum() / (n_act * jnp.maximum(p.lam_tasks, 1.0)),
            (s.d * act).sum() / (n_act * DIST_NORM),
            act.sum() / (self.n_servers * self.n_channels),
        ])
        return jnp.concatenate([
            own,
            act[:, None],
            self._ue_static,
            jnp.broadcast_to(self._pool_static, (n, OBS_UE_POOL)),
            jnp.broadcast_to(fleet, (n, OBS_UE_FLEET)),
        ], axis=1)

    def observe_entities(self, s: EnvState):
        """Structured ENTITY-SET observation for the per-server route
        scorer: a pytree ``{"ue": (N, d_u), "server": (E, d_s),
        "edge": (N, E, d_e)}`` whose row dimensions are constants
        (independent of N, E, and B_max). Unlike `observe_per_ue`, the
        edge pool is not flattened into mean-field aggregates — servers
        are first-class entities the policy scores individually, which is
        what lets it transfer across pool layouts AND pool sizes.

          ue (OBS_ENT_UE): own queue/task/channel state (zeroed standby,
              nearest-server distance from the LIVE geometry), activity
              flag, static device/table descriptors, mean-field fleet
              aggregates — the `observe_per_ue` row minus the pool block
          server (OBS_ENT_SRV): geometry [dist_scale, bw_scale,
              slowness / EDGE_SLOW_NORM] + active UEs per (server,
              channel) slot
          edge (OBS_ENT_EDGE): UE->server distance, clean-channel rate
              proxy at p_max, and mean feasible edge-service seconds of
              THIS ue on THIS server

        Rows are permutation-equivariant over UEs AND servers (aggregates
        are symmetric; edge features permute on both axes), and all three
        blocks follow a state's resampled geometry when present."""
        p = self.params
        n = p.n_ue
        geom = self._geom(s)                                   # (E, 3)
        n_srv = geom.shape[0]
        act = s.active.astype(jnp.float32)
        own = jnp.stack([
            s.k / jnp.maximum(p.lam_tasks, 1.0),
            s.l / p.t0,
            s.n / BITS_NORM,
            s.d / DIST_NORM,
            s.d * geom[:, 0].min() / DIST_NORM,
        ], axis=1) * act[:, None]
        n_act = jnp.maximum(act.sum(), 1.0)
        per_slot = act.sum() / (n_srv * self.n_channels)
        fleet = jnp.stack([
            act.sum() / n,
            (s.k * act).sum() / (n_act * jnp.maximum(p.lam_tasks, 1.0)),
            (s.d * act).sum() / (n_act * DIST_NORM),
            per_slot,
        ])
        ue = jnp.concatenate([
            own,
            act[:, None],
            self._ue_static,
            jnp.broadcast_to(fleet, (n, OBS_UE_FLEET)),
        ], axis=1)

        srv = jnp.concatenate([
            geom * jnp.asarray([1.0, 1.0, 1.0 / EDGE_SLOW_NORM]),
            jnp.broadcast_to(per_slot, (n_srv,))[:, None],
        ], axis=1)

        dist_ne = s.d[:, None] * geom[None, :, 0]              # (N, E)
        g_ne = channel_gain(dist_ne, p.pathloss)
        om_mean = geom[:, 1] * p.omega_cell.mean()             # (E,)
        rate = om_mean[None, :] \
            * jnp.log2(1.0 + p.p_max * g_ne / p.sigma.mean()) / RATE_NORM
        te = self._ue_work_mean[:, None] * geom[None, :, 2] / p.t0
        edge = jnp.stack([dist_ne / DIST_NORM, rate, te], axis=-1)
        return {"ue": ue, "server": srv, "edge": edge}

    def observe_entities_raw(self, s: EnvState):
        """Kernel-path variant of ``observe_entities``: the IDENTICAL
        per-UE "ue" rows, but instead of materializing the (N, E, 3) edge
        tensor (and the (E, 4) server rows derived from it) the pytree
        carries the raw per-UE vectors + live geometry + physics constants
        that ``kernels.ops.pair_scorer`` consumes — the edge features, the
        per-(server, channel) occupancy reduction, and the server
        embedding are then fused into the scorer kernel and the O(N*E)
        blocks never hit memory (nor the stored trajectory: the raw block
        is O(N + E) per step instead of O(N*E)).

        Selected by ``MAHPPOConfig.fused_scorer`` / ``evaluate_policy(...,
        fused_scorer=True)``; the default path never calls this, so its
        observation pytree (and goldens) are untouched."""
        p = self.params
        n = p.n_ue
        geom = self._geom(s)                                   # (E, 3)
        act = s.active.astype(jnp.float32)
        own = jnp.stack([
            s.k / jnp.maximum(p.lam_tasks, 1.0),
            s.l / p.t0,
            s.n / BITS_NORM,
            s.d / DIST_NORM,
            s.d * geom[:, 0].min() / DIST_NORM,
        ], axis=1) * act[:, None]
        n_act = jnp.maximum(act.sum(), 1.0)
        per_slot = act.sum() / (geom.shape[0] * self.n_channels)
        fleet = jnp.stack([
            act.sum() / n,
            (s.k * act).sum() / (n_act * jnp.maximum(p.lam_tasks, 1.0)),
            (s.d * act).sum() / (n_act * DIST_NORM),
            per_slot,
        ])
        ue = jnp.concatenate([
            own,
            act[:, None],
            self._ue_static,
            jnp.broadcast_to(fleet, (n, OBS_UE_FLEET)),
        ], axis=1)
        return {"ue": ue, "raw": {
            "d": s.d, "work": self._ue_work_mean, "active": act,
            "geom": geom, "consts": self._scorer_consts}}

    def action_masks(self, s: EnvState = None):
        """Per-head feasibility masks ({head: (N, n) bool}; heads without
        an entry are unrestricted). The split head carries the per-UE
        table feasibility; given a state in a dynamic env, inactive UEs
        are further restricted to the always-feasible full-local action
        (the last one) so dead actors make one deterministic no-op choice
        instead of wandering the action space."""
        feas = self.action_space.masks["split"]   # == params.feasible
        if s is None or not self.dynamic:
            return {"split": feas}
        local_only = jnp.zeros_like(feas).at[:, -1].set(True)
        return {"split": jnp.where(s.active[:, None], feas, local_only)}

    # ------------------------------------------------------------ physics
    def _rates(self, d, c, p_tx, route, transmitting, phys=None):
        """Per-UE uplink rates at distances d under the joint action (the
        pool's per-server path loss and channels when routed). ``phys``:
        an optional `_pool_phys` triple overriding the static pool
        geometry with a state's resampled draw."""
        prm = self.params
        if self.multi_server:
            dist, omega = (prm.server_dist, prm.omega) if phys is None \
                else phys[:2]
            g = channel_gain(d * dist[route], prm.pathloss)
            r = uplink_rates(p_tx, c, g, transmitting, omega=omega,
                             sigma=prm.sigma, route=route)
        else:
            g = channel_gain(d, prm.pathloss)
            r = uplink_rates(p_tx, c, g, transmitting, omega=prm.omega,
                             sigma=prm.sigma)
        return jnp.maximum(r, 1.0)  # avoid div-by-zero; 1 b/s floor

    def _edge_seconds(self, b, route, offloads, phys=None):
        """Per-task edge service time under processor sharing: each
        offloaded task at split b on server e takes t_edge[n, b, e] times
        the number of UEs concurrently offloading to e."""
        prm = self.params
        t_edge = prm.t_edge if phys is None else phys[2]
        te = t_edge[jnp.arange(prm.n_ue), b, route]
        load = jax.nn.one_hot(route, self.n_servers,
                              dtype=te.dtype).T @ offloads.astype(te.dtype)
        return te * jnp.maximum(load[route], 1.0), load

    def step(self, s: EnvState, actions):
        """actions: dict pytree matching `self.action_space` — (N,) int32
        per discrete head, (N,) float physical watts for "power" (clamped
        into the head's bounds here, the single enforcement point).
        Returns (next_state, reward, done, info)."""
        prm = self.params
        a = self.action_space.clip(actions)
        b, c, p_tx = a["split"], a["channel"], a["power"]
        route = a["route"] if self.multi_server else None
        phys = self._pool_phys(s)
        act = s.active
        # inactive UEs do no work: no compute, no tx, no interference. With
        # act all-True (static env) the & is an exact identity, so the
        # static computation is bit-for-bit the pre-churn one.
        has_work = (s.k > 0) & act
        l_new = per_ue(prm.l_new, b)
        n_new = per_ue(prm.n_new, b)
        # a UE contributes interference if it offloads anything this frame
        offloads = ((s.n > 0) | (n_new > 0)) & has_work
        r = self._rates(s.d, c, p_tx, route, offloads, phys)

        t_rem = jnp.full_like(s.l, prm.t0)
        energy = jnp.zeros_like(s.l)
        completed = jnp.zeros_like(s.l)

        # ---- phase 1: carry-over task (old b; n already fixed), resumed
        # exactly where the previous frame left it
        dt_l = jnp.minimum(s.l, t_rem) * has_work
        t_rem = t_rem - dt_l
        energy += dt_l * prm.p_compute
        l1 = s.l - dt_l
        tx_time = jnp.where(l1 <= 0, jnp.minimum(s.n / r, t_rem), 0.0) * has_work
        n1 = s.n - tx_time * r
        eps_bits = jnp.maximum(n1, 0.0) * (n1 < TX_EPS_BITS)
        n1 = jnp.where(n1 < TX_EPS_BITS, 0.0, n1)
        t_rem = t_rem - tx_time
        energy += tx_time * p_tx
        carried = has_work & (s.l + s.n > 0)
        done_carry = carried & (l1 <= 0) & (n1 <= 0)
        # a carry-over the frame could not finish: its remainder (l1, n1)
        # survives into the next state below. It left t_rem == 0 (local
        # work ate the frame, or tx was clipped to the remaining time), so
        # phases 2/3 are inert for this UE and (l2, n2) end up zero.
        carry_open = carried & ~done_carry
        completed += done_carry
        k1 = s.k - done_carry

        # ---- phase 2: whole new tasks at the new split b
        t_task = l_new + n_new / r
        server_load = None
        if self.multi_server:
            te_eff, server_load = self._edge_seconds(b, route, offloads,
                                                     phys)
            t_task = t_task + te_eff
        can = (k1 > 0) & (t_task > 0) & act
        m = jnp.where(can, jnp.floor(t_rem / jnp.maximum(t_task, 1e-9)), 0.0)
        m = jnp.minimum(m, k1)
        completed += m
        k2 = k1 - m
        t_rem = t_rem - m * t_task
        energy += m * (l_new * prm.p_compute + (n_new / r) * p_tx)

        # ---- phase 3: start one partial task. A task must have SOME work
        # (l_new + n_new > 0; true for every feasible action) — otherwise a
        # forced padded action would mint one free completion per frame.
        start = (k2 > 0) & (t_rem > 0) & (l_new + n_new > 0) & act
        dt_l2 = jnp.minimum(l_new, t_rem) * start
        t_rem2 = t_rem - dt_l2
        energy += dt_l2 * prm.p_compute
        l2 = jnp.where(start, l_new - dt_l2, 0.0)
        tx2 = jnp.where(start & (l2 <= 0), jnp.minimum(n_new / r, t_rem2), 0.0)
        n2 = jnp.where(start, n_new - tx2 * r, 0.0)
        eps_bits += jnp.maximum(n2, 0.0) * start * (n2 < TX_EPS_BITS)
        n2 = jnp.where(n2 < TX_EPS_BITS, 0.0, n2)
        energy += tx2 * p_tx
        finished_partial = start & (l2 <= 0) & (n2 <= 0)
        completed += finished_partial
        k3 = k2 - finished_partial
        l2 = jnp.where(finished_partial, 0.0, l2)
        n2 = jnp.where(finished_partial, 0.0, n2)

        # ---- next-state in-flight task: the OPEN carry-over's remainder
        # takes precedence over the phase-3 partial (a UE holds at most one
        # in-flight task; the two are mutually exclusive because an open
        # carry zeroes t_rem). Discarding (l1, n1) here was the pre-fix
        # restart bug: any task needing more than 2 frames of work lost its
        # remainder at every frame boundary and could never complete.
        l_nxt = jnp.where(carry_open, l1, l2)
        n_nxt = jnp.where(carry_open, n1, n2)

        k_t = completed.sum()
        e_t = energy.sum()
        reward = -prm.t0 / jnp.maximum(k_t, 1.0) \
            - prm.beta * e_t / jnp.maximum(k_t, 1.0)

        # ---- churn: departures drop their remaining queue, arrivals draw a
        # fresh one (skipped entirely — including the extra key splits — in
        # the static env, preserving its PRNG stream bit-for-bit)
        spawned = jnp.float32(0.0)
        dropped = jnp.float32(0.0)
        d_next = s.d
        act_next = act
        if self.dynamic:
            key_next, key_reset, kj, kl, kf, kd = jax.random.split(s.key, 6)
            p_join = 1.0 - jnp.exp(-prm.churn_rate)
            joins = ~act & (jax.random.uniform(kj, act.shape) < p_join)
            leaves = act & (jax.random.uniform(kl, act.shape) < prm.leave_rate)
            k_fresh = jax.random.poisson(kf, prm.lam_tasks,
                                         act.shape).astype(jnp.float32)
            d_fresh = jax.random.uniform(kd, act.shape, minval=prm.d_low,
                                         maxval=prm.d_high)
            dropped = (k3 * leaves).sum()
            spawned = (k_fresh * joins).sum()
            k3 = jnp.where(leaves, 0.0, jnp.where(joins, k_fresh, k3))
            l_nxt = jnp.where(leaves | joins, 0.0, l_nxt)
            n_nxt = jnp.where(leaves | joins, 0.0, n_nxt)
            d_next = jnp.where(joins, d_fresh, s.d)
            act_next = (act & ~leaves) | joins
        else:
            key_next, key_reset = jax.random.split(s.key)

        done = jnp.all(k3 <= 0)

        # geometry-carrying states redraw their pool layout on episode end
        # ("resample per env at reset"); the extra key split exists only in
        # this traced variant, so static-geometry streams are untouched
        geom_next = s.geom
        if s.geom is not None:
            key_next, key_geom = jax.random.split(key_next)
            geom_next = jnp.where(done, self._draw_geom(key_geom), s.geom)

        # auto-reset on termination (full fleet active again)
        fresh = self.reset(key_reset)
        nxt = EnvState(
            k=jnp.where(done, fresh.k, k3),
            l=jnp.where(done, fresh.l, l_nxt),
            n=jnp.where(done, fresh.n, n_nxt),
            d=jnp.where(done, fresh.d, d_next),
            t=jnp.where(done, 0, s.t + 1),
            key=key_next,
            active=jnp.where(done, fresh.active, act_next),
            geom=geom_next)
        info = {"completed": k_t, "energy": e_t,
                "rate_mean": r.mean(), "offloads": offloads.sum(),
                "n_active": act.sum(), "spawned": spawned,
                "dropped": dropped, "eps_bits": eps_bits.sum()}
        if self.multi_server:
            info["server_load"] = server_load
        return nxt, reward, done, info

    def task_overhead(self, s: EnvState, actions):
        """Realized per-task latency/energy vectors (Eq. 7/8) for each UE
        under this frame's joint interference (and, with an edge pool,
        the routed servers' shared compute). Used by policy evaluation;
        the same head-dict contract as `step`."""
        prm = self.params
        a = self.action_space.clip(actions)
        b, c, p_tx = a["split"], a["channel"], a["power"]
        route = a["route"] if self.multi_server else None
        phys = self._pool_phys(s)
        l_b = per_ue(prm.l_new, b)
        n_b = per_ue(prm.n_new, b)
        offl = (n_b > 0) & s.active
        r = self._rates(s.d, c, p_tx, route, offl, phys)
        te_eff = None
        if self.multi_server:
            te_eff, _ = self._edge_seconds(b, route, offl, phys)
        return oh.task_latency_energy(l_b, n_b, r, prm.p_compute, p_tx,
                                      te_eff)
