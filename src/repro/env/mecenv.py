"""Multi-agent collaborative-inference MEC environment (paper §3–4).

State s_t = {k_t, l_t, n_t, d} (remaining tasks, remaining local seconds of
the half-completed task, remaining offload bits, UE distances). Action per UE
a = (b, c, p): split point, channel, transmit power. Reward (Eq. 12):

    r_t = -T0 / K_t - beta * E_t / K_t

Frame dynamics are computed *analytically* (no inner loop): with the frame's
rates fixed (Eq. 5 interference, per the paper), each UE finishes its
carry-over task, then floor(T_rem / t_task) whole tasks, then starts one
partial task. Fully vectorized over UEs and vmappable over parallel envs.

UEs may be heterogeneous: the overhead tables l_new/n_new/feasible are
(N, B_max+2) — one row per UE, built from a core.split.FleetPlan mixing
backbones and device tiers — and p_compute is a (N,) vector. A single
SplitPlan broadcasts to N identical rows, reproducing the seed scenario.

Fleets may also be DYNAMIC: with `churn_rate` > 0 and/or `leave_rate` > 0
(EnvParams), UEs join from a standby pool (Poisson arrivals per standby
slot: join prob 1 - exp(-churn_rate) per frame) and depart (geometric
session length: leave prob `leave_rate` per frame). `EnvState.active` is a
(N,) bool mask — N stays the static *maximum* fleet size, so every shape
is fixed and the env stays jit/vmap-clean; membership is data, not
structure. Inactive UEs contribute no interference, energy, completions,
or reward; a re-joining UE draws a fresh task queue and distance. With
both rates at 0.0 the dynamic machinery is compiled out entirely and the
env is bit-for-bit identical to the static one (same PRNG key stream).
"""
from __future__ import annotations

from typing import NamedTuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.split import FleetPlan, SplitPlan
from repro.env.channel import channel_gain, uplink_rates


class EnvParams(NamedTuple):
    l_new: jnp.ndarray      # (N, B_max+2) local+compression seconds per split
    n_new: jnp.ndarray      # (N, B_max+2) offload bits per split
    feasible: jnp.ndarray   # (N, B_max+2) bool; False on padded actions
    p_compute: jnp.ndarray  # (N,) per-UE compute power (W)
    t0: jnp.ndarray         # frame seconds
    beta: jnp.ndarray
    omega: jnp.ndarray      # (C,)
    sigma: jnp.ndarray      # (C,)
    p_max: jnp.ndarray
    lam_tasks: jnp.ndarray  # Poisson mean of K_n
    d_low: jnp.ndarray
    d_high: jnp.ndarray
    n_ue: int
    pathloss: jnp.ndarray
    churn_rate: jnp.ndarray = 0.0  # Poisson join intensity per standby slot
    leave_rate: jnp.ndarray = 0.0  # per-frame departure prob (geometric)


def per_ue(table: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Gather each UE's own table entry: table (N, B+2), b (N,) -> (N,).
    vmap-friendly (no dynamic shapes)."""
    return jnp.take_along_axis(table, b[:, None], axis=1)[:, 0]


def make_env_params(plan: Union[SplitPlan, FleetPlan], *, n_ue=5,
                    n_channels=2, t0=0.5, beta=0.47, p_compute=None,
                    omega=1e6, sigma=1e-9, p_max=0.5, lam_tasks=200.0,
                    d_low=1.0, d_high=100.0, pathloss=3.0,
                    churn_rate=0.0, leave_rate=0.0) -> EnvParams:
    """A single SplitPlan is broadcast to n_ue identical UEs (the seed
    homogeneous scenario); a FleetPlan supplies per-UE tables and device
    power draws (n_ue/p_compute then come from the fleet). Nonzero
    churn_rate/leave_rate make the fleet dynamic (see module docstring)."""
    if isinstance(plan, FleetPlan):
        n_ue = plan.n_ue
        l_new = jnp.asarray(plan.t_local + plan.t_comp, jnp.float32)
        n_new = jnp.asarray(plan.f_bits, jnp.float32)
        feasible = jnp.asarray(plan.feasible)
        p_vec = jnp.asarray(plan.p_compute if p_compute is None
                            else np.full((n_ue,), p_compute), jnp.float32)
    else:
        l_new = jnp.tile(jnp.asarray(plan.t_local + plan.t_comp,
                                     jnp.float32)[None], (n_ue, 1))
        n_new = jnp.tile(jnp.asarray(plan.f_bits, jnp.float32)[None],
                         (n_ue, 1))
        feasible = jnp.tile(jnp.asarray(plan.feasible)[None], (n_ue, 1))
        p_vec = jnp.full((n_ue,), 2.1 if p_compute is None else p_compute,
                         jnp.float32)
    return EnvParams(
        l_new=l_new, n_new=n_new, feasible=feasible, p_compute=p_vec,
        t0=jnp.float32(t0), beta=jnp.float32(beta),
        omega=jnp.full((n_channels,), omega, jnp.float32),
        sigma=jnp.full((n_channels,), sigma, jnp.float32),
        p_max=jnp.float32(p_max), lam_tasks=jnp.float32(lam_tasks),
        d_low=jnp.float32(d_low), d_high=jnp.float32(d_high),
        n_ue=n_ue, pathloss=jnp.float32(pathloss),
        churn_rate=jnp.float32(churn_rate),
        leave_rate=jnp.float32(leave_rate))


class EnvState(NamedTuple):
    k: jnp.ndarray          # (N,) remaining tasks (incl. in-flight)
    l: jnp.ndarray          # (N,) remaining local seconds of current task
    n: jnp.ndarray          # (N,) remaining offload bits of current task
    d: jnp.ndarray          # (N,) distances
    t: jnp.ndarray          # frame counter
    key: jnp.ndarray
    active: jnp.ndarray = None  # (N,) bool membership mask (all True static)


class MECEnv:
    """Functional env; all methods are jit/vmap friendly.

    `self.dynamic` is a Python-level flag fixed at construction: when both
    churn rates are 0.0 every churn branch below is skipped at trace time,
    so the compiled static env is exactly the pre-churn one (identical
    computation graph AND identical PRNG key stream).
    """

    def __init__(self, params: EnvParams):
        self.params = params
        self.n_actions_b = int(params.l_new.shape[1])
        self.n_channels = int(params.omega.shape[0])
        self.dynamic = bool(float(params.churn_rate) > 0.0
                            or float(params.leave_rate) > 0.0)
        # dynamic fleets append an activity flag + fleet-size feature per UE
        self.obs_dim = (6 if self.dynamic else 4) * params.n_ue

    def reset(self, key, *, eval_mode=False) -> EnvState:
        p = self.params
        kk, kd, kn = jax.random.split(key, 3)
        if eval_mode:
            k = jnp.full((p.n_ue,), p.lam_tasks, jnp.float32)
            d = jnp.full((p.n_ue,), 50.0, jnp.float32)
        else:
            k = jax.random.poisson(kk, p.lam_tasks, (p.n_ue,)).astype(jnp.float32)
            d = jax.random.uniform(kd, (p.n_ue,), minval=p.d_low,
                                   maxval=p.d_high)
        return EnvState(k=k, l=jnp.zeros((p.n_ue,)), n=jnp.zeros((p.n_ue,)),
                        d=d, t=jnp.zeros((), jnp.int32), key=kn,
                        active=jnp.ones((p.n_ue,), bool))

    def observe(self, s: EnvState):
        p = self.params
        base = [s.k / jnp.maximum(p.lam_tasks, 1.0),
                s.l / p.t0,
                s.n / 1e6,
                s.d / 100.0]
        if self.dynamic:
            act = s.active.astype(jnp.float32)
            frac = jnp.broadcast_to(act.sum() / p.n_ue, (p.n_ue,))
            base += [act, frac]
        return jnp.concatenate(base)

    def action_mask(self, s: EnvState = None):
        """(N, B_max+2) per-UE feasibility; padded fleet actions are False.
        Given a state in a dynamic env, inactive UEs are further restricted
        to the always-feasible full-local action (the last one) so dead
        actors make one deterministic no-op choice instead of wandering the
        action space."""
        feas = self.params.feasible
        if s is None or not self.dynamic:
            return feas
        local_only = jnp.zeros_like(feas).at[:, -1].set(True)
        return jnp.where(s.active[:, None], feas, local_only)

    def step(self, s: EnvState, b, c, p_tx):
        """b, c: (N,) int32; p_tx: (N,) float in (0, p_max].
        Returns (next_state, reward, done, info)."""
        prm = self.params
        p_tx = jnp.clip(p_tx, 1e-4, prm.p_max)
        g = channel_gain(s.d, prm.pathloss)
        act = s.active
        # inactive UEs do no work: no compute, no tx, no interference. With
        # act all-True (static env) the & is an exact identity, so the
        # static computation is bit-for-bit the pre-churn one.
        has_work = (s.k > 0) & act
        l_new = per_ue(prm.l_new, b)
        n_new = per_ue(prm.n_new, b)
        # a UE contributes interference if it offloads anything this frame
        offloads = ((s.n > 0) | (n_new > 0)) & has_work
        r = uplink_rates(p_tx, c, g, offloads, omega=prm.omega,
                         sigma=prm.sigma)
        r = jnp.maximum(r, 1.0)  # avoid div-by-zero; 1 b/s floor

        t_rem = jnp.full_like(s.l, prm.t0)
        energy = jnp.zeros_like(s.l)
        completed = jnp.zeros_like(s.l)

        # ---- phase 1: carry-over task (old b; n already fixed)
        dt_l = jnp.minimum(s.l, t_rem) * has_work
        t_rem = t_rem - dt_l
        energy += dt_l * prm.p_compute
        l1 = s.l - dt_l
        tx_time = jnp.where(l1 <= 0, jnp.minimum(s.n / r, t_rem), 0.0) * has_work
        n1 = s.n - tx_time * r
        n1 = jnp.where(n1 < 1.0, 0.0, n1)
        t_rem = t_rem - tx_time
        energy += tx_time * p_tx
        carried = has_work & (s.l + s.n > 0)
        done_carry = carried & (l1 <= 0) & (n1 <= 0)
        completed += done_carry
        k1 = s.k - done_carry

        # ---- phase 2: whole new tasks at the new split b
        t_task = l_new + n_new / r
        can = (k1 > 0) & (t_task > 0) & act
        m = jnp.where(can, jnp.floor(t_rem / jnp.maximum(t_task, 1e-9)), 0.0)
        m = jnp.minimum(m, k1)
        completed += m
        k2 = k1 - m
        t_rem = t_rem - m * t_task
        energy += m * (l_new * prm.p_compute + (n_new / r) * p_tx)

        # ---- phase 3: start one partial task
        start = (k2 > 0) & (t_rem > 0) & act
        dt_l2 = jnp.minimum(l_new, t_rem) * start
        t_rem2 = t_rem - dt_l2
        energy += dt_l2 * prm.p_compute
        l2 = jnp.where(start, l_new - dt_l2, 0.0)
        tx2 = jnp.where(start & (l2 <= 0), jnp.minimum(n_new / r, t_rem2), 0.0)
        n2 = jnp.where(start, n_new - tx2 * r, 0.0)
        n2 = jnp.where(n2 < 1.0, 0.0, n2)
        energy += tx2 * p_tx
        finished_partial = start & (l2 <= 0) & (n2 <= 0)
        completed += finished_partial
        k3 = k2 - finished_partial
        l2 = jnp.where(finished_partial, 0.0, l2)
        n2 = jnp.where(finished_partial, 0.0, n2)

        k_t = completed.sum()
        e_t = energy.sum()
        reward = -prm.t0 / jnp.maximum(k_t, 1.0) \
            - prm.beta * e_t / jnp.maximum(k_t, 1.0)

        # ---- churn: departures drop their remaining queue, arrivals draw a
        # fresh one (skipped entirely — including the extra key splits — in
        # the static env, preserving its PRNG stream bit-for-bit)
        spawned = jnp.float32(0.0)
        dropped = jnp.float32(0.0)
        d_next = s.d
        act_next = act
        if self.dynamic:
            key_next, key_reset, kj, kl, kf, kd = jax.random.split(s.key, 6)
            p_join = 1.0 - jnp.exp(-prm.churn_rate)
            joins = ~act & (jax.random.uniform(kj, act.shape) < p_join)
            leaves = act & (jax.random.uniform(kl, act.shape) < prm.leave_rate)
            k_fresh = jax.random.poisson(kf, prm.lam_tasks,
                                         act.shape).astype(jnp.float32)
            d_fresh = jax.random.uniform(kd, act.shape, minval=prm.d_low,
                                         maxval=prm.d_high)
            dropped = (k3 * leaves).sum()
            spawned = (k_fresh * joins).sum()
            k3 = jnp.where(leaves, 0.0, jnp.where(joins, k_fresh, k3))
            l2 = jnp.where(leaves | joins, 0.0, l2)
            n2 = jnp.where(leaves | joins, 0.0, n2)
            d_next = jnp.where(joins, d_fresh, s.d)
            act_next = (act & ~leaves) | joins
        else:
            key_next, key_reset = jax.random.split(s.key)

        done = jnp.all(k3 <= 0)

        # auto-reset on termination (full fleet active again)
        fresh = self.reset(key_reset)
        nxt = EnvState(
            k=jnp.where(done, fresh.k, k3),
            l=jnp.where(done, fresh.l, l2),
            n=jnp.where(done, fresh.n, n2),
            d=jnp.where(done, fresh.d, d_next),
            t=jnp.where(done, 0, s.t + 1),
            key=key_next,
            active=jnp.where(done, fresh.active, act_next))
        info = {"completed": k_t, "energy": e_t,
                "rate_mean": r.mean(), "offloads": offloads.sum(),
                "n_active": act.sum(), "spawned": spawned,
                "dropped": dropped}
        return nxt, reward, done, info
