"""Optimizers: AdamW (f32 states) and Adafactor (factored states, for XL
archs where AdamW states cannot fit the mesh — see DESIGN.md).

State layout is a plain pytree so pjit shards it like params; adafactor
stores per-leaf slot dicts in a flat list (same tree order as params).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


# ------------------------------------------------------------------- AdamW
def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(grads, state, params, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 weight_decay=0.1):
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    bc1 = 1 - b1 ** sf
    bc2 = 1 - b2 ** sf

    new_m = jax.tree_util.tree_map(
        lambda g, m: b1 * m + (1 - b1) * g.astype(jnp.float32),
        grads, state["m"])
    new_v = jax.tree_util.tree_map(
        lambda g, v: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        grads, state["v"])

    def upd(p, m, v):
        update = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
        wd = weight_decay if p.ndim >= 2 else 0.0
        p2 = p.astype(jnp.float32) - lr * (update + wd * p.astype(jnp.float32))
        return p2.astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, new_m, new_v)
    return new_params, {"m": new_m, "v": new_v, "step": step}


# --------------------------------------------------------------- Adafactor
def _factored(p):
    return p.ndim >= 2


def adafactor_init(params):
    leaves = jax.tree_util.tree_leaves(params)
    slots = []
    for p in leaves:
        if _factored(p):
            slots.append({
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            })
        else:
            slots.append({"v": jnp.zeros(p.shape, jnp.float32)})
    return {"slots": slots, "step": jnp.zeros((), jnp.int32)}


def adafactor_update(grads, state, params, lr, *, decay=0.8, eps=1e-30,
                     clip_thresh=1.0, weight_decay=0.0):
    step = state["step"] + 1
    sf = step.astype(jnp.float32)
    beta = 1.0 - sf ** (-decay)
    pleaves, treedef = jax.tree_util.tree_flatten(params)
    gleaves = treedef.flatten_up_to(grads)
    new_p, new_slots = [], []
    for p, g, slot in zip(pleaves, gleaves, state["slots"]):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if _factored(p):
            vr = beta * slot["vr"] + (1 - beta) * g2.mean(axis=-1)
            vc = beta * slot["vc"] + (1 - beta) * g2.mean(axis=-2)
            rfac = jax.lax.rsqrt(
                vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps))
            cfac = jax.lax.rsqrt(vc)
            update = gf * rfac[..., None] * cfac[..., None, :]
            new_slot = {"vr": vr, "vc": vc}
        else:
            v = beta * slot["v"] + (1 - beta) * g2
            update = gf * jax.lax.rsqrt(v)
            new_slot = {"v": v}
        rms = jnp.sqrt(jnp.mean(update * update))
        update = update / jnp.maximum(1.0, rms / clip_thresh)
        p2 = p.astype(jnp.float32) - lr * update
        if weight_decay and p.ndim >= 2:
            p2 = p2 - lr * weight_decay * p.astype(jnp.float32)
        new_p.append(p2.astype(p.dtype))
        new_slots.append(new_slot)
    return (jax.tree_util.tree_unflatten(treedef, new_p),
            {"slots": new_slots, "step": step})


# ------------------------------------------------------------------ facade
def make_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(name)


def opt_state_pspec(name: str, params_specs):
    """PartitionSpec tree for the optimizer state, derived from param specs.
    params_specs: pytree of PartitionSpec (same structure as params)."""
    from jax.sharding import PartitionSpec as P
    if name == "adamw":
        return {"m": params_specs, "v": params_specs, "step": P()}
    specs = jax.tree_util.tree_leaves(
        params_specs, is_leaf=lambda x: isinstance(x, P))
    slots = []
    for s in specs:
        entries = tuple(s)
        if len(entries) >= 2:
            slots.append({"vr": P(*entries[:-1]),
                          "vc": P(*(entries[:-2] + entries[-1:]))})
        else:
            slots.append({"v": P(*entries)})
    return {"slots": slots, "step": P()}
