from repro.optim.optimizers import (adafactor_init, adafactor_update,
                                    adamw_init, adamw_update, global_norm,
                                    make_optimizer)
from repro.optim.schedule import cosine_schedule

__all__ = ["adamw_init", "adamw_update", "adafactor_init", "adafactor_update",
           "global_norm", "make_optimizer", "cosine_schedule"]
