"""Serving caches: full / ring-buffer KV caches, SSM and RG-LRU states.

The cache pytree mirrors the parameter layout (per-pattern-position stacks
over scan groups + unstacked tail) so the same lax.scan drives both. Slot
semantics: an entry with absolute position p lives at slot p % cache_len;
``pos`` maps slot -> absolute position (-1 = empty), which the flash-attention
mask consumes directly, making full and sliding-window caches uniform.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dtype_of
from repro.models import ssm as ssm_lib


def quantize_kv(x, bits):
    """Symmetric per-(token, kv-head) int8 quantization of k or v
    (B, S, Hkv, D) -> (codes int8, scale (B, S, Hkv) f32). Paper Eq. 1
    applied to the serving cache."""
    levels = (1 << (bits - 1)) - 1
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / levels
    scale = jnp.maximum(scale, 1e-8)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -levels, levels).astype(jnp.int8)
    return codes, scale


def dequantize_kv(codes, scale, dtype):
    return (codes.astype(jnp.float32) * scale[..., None]).astype(dtype)


def pack_full_kv(k, v, positions, cache_len, window=0, kv_bits=0):
    """Build a decode cache entry from full-sequence k/v (prefill).

    k, v: (B, S, Hkv, D); positions: (B, S). cache_len: allocated length
    (window if window>0). Entries beyond capacity keep only the most recent.
    kv_bits > 0 stores int8 codes + per-(slot, head) scales.
    """
    lc = window if window else cache_len
    b, s, hkv, dh = k.shape
    ksc = vsc = None
    if kv_bits:
        k, ksc = quantize_kv(k, kv_bits)
        v, vsc = quantize_kv(v, kv_bits)
    if s >= lc:
        ks, vs, ps = k[:, -lc:], v[:, -lc:], positions[:, -lc:]
        slots = jnp.mod(ps[0], lc)                       # (lc,)
        kb = jnp.zeros((b, lc, hkv, dh), k.dtype).at[:, slots].set(ks)
        vb = jnp.zeros((b, lc, hkv, dh), v.dtype).at[:, slots].set(vs)
        pb = jnp.full((b, lc), -1, jnp.int32).at[:, slots].set(ps)
        if kv_bits:
            ksc = jnp.zeros((b, lc, hkv), jnp.float32).at[:, slots].set(
                ksc[:, -lc:])
            vsc = jnp.zeros((b, lc, hkv), jnp.float32).at[:, slots].set(
                vsc[:, -lc:])
    else:
        kb = jnp.zeros((b, lc, hkv, dh), k.dtype)
        kb = jax.lax.dynamic_update_slice(kb, k, (0, 0, 0, 0))
        vb = jnp.zeros((b, lc, hkv, dh), v.dtype)
        vb = jax.lax.dynamic_update_slice(vb, v, (0, 0, 0, 0))
        pb = jnp.full((b, lc), -1, jnp.int32)
        pb = jax.lax.dynamic_update_slice(pb, positions.astype(jnp.int32), (0, 0))
        if kv_bits:
            ksc = jax.lax.dynamic_update_slice(
                jnp.zeros((b, lc, hkv), jnp.float32), ksc, (0, 0, 0))
            vsc = jax.lax.dynamic_update_slice(
                jnp.zeros((b, lc, hkv), jnp.float32), vsc, (0, 0, 0))
    entry = {"k": kb, "v": vb, "pos": pb}
    if kv_bits:
        entry["k_scale"] = ksc
        entry["v_scale"] = vsc
    return entry


def entry_shape(cfg, btype, batch, attn_len):
    """Shape/dtype tree (as (shape, dtype) leaves) of one layer's cache."""
    cdt = dtype_of(cfg.compute_dtype)
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    if btype == "mamba2":
        d_inner, h, pdim, n, d_conv = ssm_lib.dims(cfg)
        return {"conv_x": ((batch, d_conv - 1, d_inner), cdt),
                "conv_bc": ((batch, d_conv - 1, 2 * n), cdt),
                "h": ((batch, h, pdim, n), jnp.float32)}
    if btype == "rec":
        d_rnn = cfg.d_model
        return {"conv": ((batch, 3, d_rnn), cdt),
                "h": ((batch, d_rnn), jnp.float32)}
    if btype == "xattn":
        return {"ck": ((batch, cfg.n_aux_tokens, hkv, dh), cdt),
                "cv": ((batch, cfg.n_aux_tokens, hkv, dh), cdt)}
    lc = cfg.window if btype == "lattn" else attn_len
    kv_dt = jnp.int8 if cfg.kv_quant_bits else cdt
    e = {"k": ((batch, lc, hkv, dh), kv_dt),
         "v": ((batch, lc, hkv, dh), kv_dt),
         "pos": ((batch, lc), jnp.int32)}
    if cfg.kv_quant_bits:
        e["k_scale"] = ((batch, lc, hkv), jnp.float32)
        e["v_scale"] = ((batch, lc, hkv), jnp.float32)
    if btype == "decx":
        nf = cfg.encoder.n_frames
        e["ck"] = ((batch, nf, hkv, dh), cdt)
        e["cv"] = ((batch, nf, hkv, dh), cdt)
    return e


def entry_payload_bits(cfg, btype, batch, ctx_len):
    """Bits to ship one layer's serving-cache state for a `ctx_len`-token
    context: ``entry_shape``'s leaves with sequence axes at the FILLED
    length (min(ctx_len, window) for sliding-window layers — the ring
    buffer never holds more), honoring ``kv_quant_bits`` (int8 codes +
    f32 per-(slot, head) scales). SSM/RG-LRU layers carry O(1) state.
    The boundary payload of an LLM-decode split
    (core.split.llm_decode_split_table) sums this over the UE-side
    layers, which is what makes f_bits a function of context length."""
    import numpy as np
    ctx_len = int(ctx_len)
    if ctx_len < 1:
        raise ValueError("ctx_len must be >= 1")
    if btype == "lattn" and cfg.window:
        cfg = cfg.replace(window=min(ctx_len, cfg.window))
    total = 0
    for shape, dtype in entry_shape(cfg, btype, batch, ctx_len).values():
        n = 1
        for s in shape:
            n *= int(s)
        total += n * np.dtype(dtype).itemsize * 8
    return int(total)


def make_cache(cfg, batch, attn_len, leaf_fn=None):
    """Build the full cache pytree. leaf_fn(shape, dtype) -> leaf;
    defaults to zeros (pos leaves get -1)."""
    from repro.models.model import layer_plan

    def default_leaf(shape, dtype, is_pos):
        if is_pos:
            return jnp.full(shape, -1, dtype)
        return jnp.zeros(shape, dtype)

    def build(btype, stack_n=None):
        tree = entry_shape(cfg, btype, batch, attn_len)

        def mk(name, sd):
            shape, dtype = sd
            if stack_n is not None:
                shape = (stack_n,) + tuple(shape)
            if leaf_fn is not None:
                return leaf_fn(shape, dtype)
            return default_leaf(shape, dtype, name == "pos")
        return {name: mk(name, sd) for name, sd in tree.items()}

    pattern, n_groups, tail_types = layer_plan(cfg)
    blocks = [build(bt, n_groups) for bt in pattern] if n_groups else []
    tail = [build(bt) for bt in tail_types]
    return {"blocks": tuple(blocks), "tail": tuple(tail)}
