"""Mixture-of-Experts FFN (GShard-style capacity dispatch, sort-based ranks).

Dispatch avoids materializing the (T, k, E) one-hot: expert ranks are computed
with a sort over the T*k assignment list, tokens are scattered into a dense
(E, C, d) buffer (overflow dropped), experts run as a single batched einsum
(expert dim shardable over the "model" axis = expert parallelism), and results
are combined with a weighted scatter-add. Compiled FLOPs ~= activated FLOPs
times the capacity factor, so roofline numbers stay honest.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dtype_of


def init_moe(key, cfg):
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_expert, m.n_experts
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    p = {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "wi": dense_init(ks[1], (e, d, f), dt),
        "wg": dense_init(ks[2], (e, d, f), dt),
        "wo": dense_init(ks[3], (e, f, d), dt),
    }
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        p["shared_wi"] = dense_init(ks[4], (d, fs), dt)
        p["shared_wg"] = dense_init(ks[5], (d, fs), dt)
        p["shared_wo"] = dense_init(ks[6], (fs, d), dt)
    return p


def apply_moe(p, x, cfg):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    Under a production mesh (meshctx set) with E % model_size == 0, uses the
    explicit expert-parallel shard_map path (local per-data-shard dispatch,
    FSDP weight all-gather, one psum per layer). Otherwise the pure-GSPMD
    global-dispatch path below (correct everywhere, used by CPU tests)."""
    from repro.models import meshctx
    if meshctx.ep_available(cfg):
        mesh = meshctx.get_mesh()
        dp_size = 1
        for a in meshctx.dp_axes():
            dp_size *= mesh.shape[a]
        tokens = x.shape[0] * x.shape[1]
        if (cfg.fsdp and tokens <= 4096
                and x.shape[0] % dp_size == 0
                and cfg.d_model % mesh.shape["data"] == 0):
            # decode regime: gathering FSDP expert weights per token costs
            # ~params bytes; gather the (tiny) token set instead and contract
            # over the local d-slice of the stationary weights.
            return apply_moe_ep_decode(p, x, cfg, mesh)
        if x.shape[0] % dp_size == 0:  # shard_map needs batch divisibility
            return apply_moe_ep(p, x, cfg, mesh)
    return _apply_moe_global(p, x, cfg)


def _apply_moe_global(p, x, cfg):
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k, e = m.top_k, m.n_experts
    cap = max(1, math.ceil(t * k / e * m.capacity_factor))

    xf = x.reshape(t, d)
    logits = (xf.astype(jnp.float32) @ p["router"])            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # sort-based rank within expert
    e_flat = top_e.reshape(t * k)
    order = jnp.argsort(e_flat)                                # stable
    e_sorted = e_flat[order]
    counts = jnp.zeros((e,), jnp.int32).at[e_flat].add(1)
    offsets = jnp.cumsum(counts) - counts                      # exclusive
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - offsets[e_sorted]

    tok_sorted = (order // k).astype(jnp.int32)
    w_sorted = top_p.reshape(t * k)[order]

    # dispatch: (E, C, d) buffer; overflow (rank >= cap) dropped
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[e_sorted, rank_sorted].set(
        xf[tok_sorted].astype(x.dtype), mode="drop")

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, p["wo"])

    # combine: gather each assignment's expert output, weighted scatter-add
    gathered = y.at[e_sorted, rank_sorted].get(
        mode="fill", fill_value=0.0)                           # (T*k, d)
    out = jnp.zeros((t, d), jnp.float32).at[tok_sorted].add(
        gathered.astype(jnp.float32) * w_sorted[:, None])
    out = out.astype(x.dtype)

    if m.n_shared_experts:
        sh = (jax.nn.silu(xf @ p["shared_wg"]) * (xf @ p["shared_wi"])) @ p["shared_wo"]
        out = out + sh

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_bar_e
    f_e = counts.astype(jnp.float32) / (t * k)
    p_bar = probs.mean(axis=0)
    aux = e * jnp.sum(f_e * p_bar) * m.router_aux_weight
    return out.reshape(b, s, d), aux


# ------------------------------------------------------------------ EP path
def apply_moe_ep(p, x, cfg, mesh):
    """Explicit expert parallelism via shard_map.

    Tokens stay sharded over the data axes; each data shard dispatches
    LOCALLY (no global sort => no global collectives); expert weights are
    sharded E over 'model' (+ FSDP dim over 'data', all-gathered just-in-time
    and re-sharded in the backward pass); each model shard computes only its
    own experts and contributes a partial token-output, combined with one
    psum over 'model' per layer — the same volume as a dense TP layer's
    activation all-reduce.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    b, s, d = x.shape
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    n_model = mesh.shape["model"]
    e_loc = m.n_experts // n_model
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    t_loc = (b * s) // dp_size
    k = m.top_k
    # floor of 4 keeps tiny decode batches from starving experts
    cap = max(4, math.ceil(t_loc * k / m.n_experts * m.capacity_factor))

    def body(xs, router, wi, wg, wo):
        bl = xs.shape[0]
        if cfg.fsdp:
            wi = jax.lax.all_gather(wi, "data", axis=1, tiled=True)
            wg = jax.lax.all_gather(wg, "data", axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        xf = xs.reshape(-1, d)
        t = xf.shape[0]
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        e_flat = top_e.reshape(t * k)
        order = jnp.argsort(e_flat)
        e_sorted = e_flat[order]
        counts = jnp.zeros((m.n_experts,), jnp.int32).at[e_flat].add(1)
        offsets = jnp.cumsum(counts) - counts
        rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - offsets[e_sorted]
        tok_sorted = (order // k).astype(jnp.int32)
        w_sorted = top_p.reshape(t * k)[order]

        lo = jax.lax.axis_index("model").astype(jnp.int32) * e_loc
        el = e_sorted - lo
        mine = (el >= 0) & (el < e_loc) & (rank_sorted < cap)
        el_s = jnp.where(mine, el, e_loc)            # positive OOB sentinel
        rk_s = jnp.where(mine, rank_sorted, cap)

        buf = jnp.zeros((e_loc, cap, d), xs.dtype)
        buf = buf.at[el_s, rk_s].set(xf[tok_sorted].astype(xs.dtype),
                                     mode="drop")
        h = jnp.einsum("ecd,edf->ecf", buf, wi)
        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo)
        gathered = y.at[el_s, rk_s].get(mode="fill", fill_value=0.0)
        out = jnp.zeros((t, d), jnp.float32).at[tok_sorted].add(
            gathered.astype(jnp.float32) * w_sorted[:, None])
        out = jax.lax.psum(out.astype(xs.dtype), "model")

        f_e = counts.astype(jnp.float32) / (t * k)
        p_bar = probs.mean(axis=0)
        aux = m.n_experts * jnp.sum(f_e * p_bar) * m.router_aux_weight
        aux = jax.lax.pmean(aux, dp)
        return out.reshape(bl, s, d), aux

    wspec_i = P("model", "data" if cfg.fsdp else None, None)
    wspec_o = P("model", None, "data" if cfg.fsdp else None)
    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None), wspec_i, wspec_i,
                  wspec_o),
        out_specs=(P(dp, None, None), P()),
        check_rep=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])

    if m.n_shared_experts:
        xf = x.reshape(-1, d)
        sh = (jax.nn.silu(xf @ p["shared_wg"]) * (xf @ p["shared_wi"])) \
            @ p["shared_wo"]
        out = out + sh.reshape(b, s, d)
    return out, aux


def apply_moe_ep_decode(p, x, cfg, mesh):
    """Decode-regime expert parallelism: weights stay fully sharded
    (E over 'model', d over 'data'); the tiny token set is all-gathered to
    every device, each device contracts over its LOCAL d-slice of its local
    experts, and partials are psum'd. Collective volume is O(tokens*d), not
    O(params) — the FSDP-gather path costs ~params bytes per step, which at
    one token per sequence is catastrophic (see EXPERIMENTS.md §Perf)."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    m = cfg.moe
    b, s, d = x.shape
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    n_model = mesh.shape["model"]
    n_data = mesh.shape["data"]
    e_loc = m.n_experts // n_model
    d_loc = d // n_data
    k = m.top_k
    t_all = b * s
    cap = max(4, math.ceil(t_all * k / m.n_experts * m.capacity_factor))

    def body(xs, router, wi, wg, wo):
        # xs: (b_local, s, d) -> gather ALL tokens (tiny at decode)
        xall = jax.lax.all_gather(xs, dp, axis=0, tiled=True)
        xf = xall.reshape(-1, d)
        t = xf.shape[0]
        logits = xf.astype(jnp.float32) @ router
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

        e_flat = top_e.reshape(t * k)
        order = jnp.argsort(e_flat)
        e_sorted = e_flat[order]
        counts = jnp.zeros((m.n_experts,), jnp.int32).at[e_flat].add(1)
        offsets = jnp.cumsum(counts) - counts
        rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - offsets[e_sorted]
        tok_sorted = (order // k).astype(jnp.int32)
        w_sorted = top_p.reshape(t * k)[order]

        lo = jax.lax.axis_index("model").astype(jnp.int32) * e_loc
        el = e_sorted - lo
        mine = (el >= 0) & (el < e_loc) & (rank_sorted < cap)
        el_s = jnp.where(mine, el, e_loc)
        rk_s = jnp.where(mine, rank_sorted, cap)

        buf = jnp.zeros((e_loc, cap, d), xs.dtype)
        buf = buf.at[el_s, rk_s].set(xf[tok_sorted].astype(xs.dtype),
                                     mode="drop")
        # contract over the LOCAL d-slice; psum partials over 'data'
        di = jax.lax.axis_index("data").astype(jnp.int32) * d_loc
        buf_sl = jax.lax.dynamic_slice_in_dim(buf, di, d_loc, axis=2)
        h = jax.lax.psum(
            jnp.einsum("ecd,edf->ecf", buf_sl, wi), "data")
        g = jax.lax.psum(
            jnp.einsum("ecd,edf->ecf", buf_sl, wg), "data")
        y_part = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo)
        y = jax.lax.all_gather(y_part, "data", axis=2, tiled=True)

        gathered = y.at[el_s, rk_s].get(mode="fill", fill_value=0.0)
        out = jnp.zeros((t, d), jnp.float32).at[tok_sorted].add(
            gathered.astype(jnp.float32) * w_sorted[:, None])
        out = jax.lax.psum(out.astype(xs.dtype), "model")
        # slice back this shard's tokens
        bi = jax.lax.axis_index(dp[0]) if len(dp) == 1 else (
            jax.lax.axis_index("pod") * mesh.shape["data"]
            + jax.lax.axis_index("data"))
        bl = xs.shape[0]
        out_local = jax.lax.dynamic_slice_in_dim(
            out.reshape(xall.shape[0], s, d), bi.astype(jnp.int32) * bl, bl,
            axis=0)

        f_e = counts.astype(jnp.float32) / (t * k)
        p_bar = probs.mean(axis=0)
        aux = m.n_experts * jnp.sum(f_e * p_bar) * m.router_aux_weight
        return out_local, aux

    wspec_i = P("model", "data", None)
    wspec_o = P("model", None, "data")
    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, None), P(None, None), wspec_i, wspec_i,
                  wspec_o),
        out_specs=(P(dp, None, None), P()),
        check_rep=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])

    if m.n_shared_experts:
        xf = x.reshape(-1, d)
        sh = (jax.nn.silu(xf @ p["shared_wg"]) * (xf @ p["shared_wi"])) \
            @ p["shared_wo"]
        out = out + sh.reshape(b, s, d)
    return out, aux
