"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x_t))
i_t = sigmoid(W_x x_t)

Full-sequence path uses jax.lax.associative_scan (the recurrence is a linear
first-order scan); decode is a single-step update. Recurrence math in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dtype_of

C_CONST = 8.0


def init_rglru(key, cfg):
    d = cfg.d_model
    d_rnn = d
    d_conv = 4
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "wx": dense_init(ks[0], (d, d_rnn), dt),
        "wgate": dense_init(ks[1], (d, d_rnn), dt),
        "conv_w": dense_init(ks[2], (d_conv, d_rnn), dt),
        "conv_b": jnp.zeros((d_rnn,), dt),
        "wa": dense_init(ks[3], (d_rnn, d_rnn), dt),
        "ba": jnp.zeros((d_rnn,), jnp.float32),
        "wi": dense_init(ks[4], (d_rnn, d_rnn), dt),
        "bi": jnp.zeros((d_rnn,), jnp.float32),
        # softplus(lambda) ~ 0.7 => a ~ exp(-8*0.7*0.5) moderately slow decay
        "lam": jnp.full((d_rnn,), 0.3, jnp.float32),
        "out": dense_init(ks[5], (d_rnn, d), dt),
    }


def _gates(p, xc):
    """xc: (..., d_rnn) post-conv branch. Returns log_a, b (f32)."""
    xf = xc.astype(jnp.float32)
    ra = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32) + p["ba"])
    ii = jax.nn.sigmoid(xf @ p["wi"].astype(jnp.float32) + p["bi"])
    log_a = -C_CONST * jax.nn.softplus(p["lam"]) * ra
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (ii * xf)
    return a, b


def _conv(p, x, init_state=None):
    d_conv = p["conv_w"].shape[0]
    pad = d_conv - 1
    if init_state is None:
        xpad = jnp.pad(x, ((0, 0), (pad, 0), (0, 0)))
    else:
        xpad = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)
    y = sum(xpad[:, i:i + x.shape[1], :] * p["conv_w"][i] for i in range(d_conv))
    return y + p["conv_b"], xpad[:, -pad:, :]


def apply_rglru(p, x, cfg, *, state=None):
    """x: (B, L, d). Returns (out, new_state {"conv","h"})."""
    xb = x @ p["wx"]
    gate = x @ p["wgate"]
    conv0 = None if state is None else state["conv"]
    xc, conv_state = _conv(p, xb, conv0)
    a, b = _gates(p, xc)                                 # (B,L,D) f32
    if state is not None:
        # fold h0 into the first step: b_0 += a_0 * h0
        b = b.at[:, 0].add(a[:, 0] * state["h"])

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    out = (h.astype(x.dtype) * jax.nn.gelu(gate)) @ p["out"]
    return out, {"conv": conv_state, "h": h[:, -1].astype(jnp.float32)}


def decode_rglru(p, x, cfg, state):
    """One-step decode. x: (B, 1, d); state {"conv": (B,3,D), "h": (B,D)}."""
    xb = x @ p["wx"]
    gate = x @ p["wgate"]
    d_conv = p["conv_w"].shape[0]
    xin = jnp.concatenate([state["conv"].astype(xb.dtype), xb], axis=1)
    xc = sum(xin[:, i, :] * p["conv_w"][i] for i in range(d_conv)) + p["conv_b"]
    a, b = _gates(p, xc)                                 # (B,D)
    hnew = a * state["h"] + b
    out = (hnew[:, None, :].astype(x.dtype) * jax.nn.gelu(gate)) @ p["out"]
    return out, {"conv": xin[:, 1:, :], "h": hnew}
