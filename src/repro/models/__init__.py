from repro.models.model import (apply_model, decode_step, init_params,
                                layer_plan, loss_fn, prefill)
from repro.models.cache import make_cache

__all__ = ["apply_model", "decode_step", "init_params", "layer_plan",
           "loss_fn", "prefill", "make_cache"]
