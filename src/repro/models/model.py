"""Model assembly: embedding -> scanned block groups (+tail) -> head.

The layer stack is compiled as lax.scan over ``n_layers // len(pattern)``
groups with per-pattern-position stacked parameters, so HLO size (and
compile time) is independent of depth. Layers that don't fill a whole
group run unstacked as the "tail".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import meshctx
from repro.models.blocks import apply_block, init_block
from repro.models.layers import apply_norm, dense_init, dtype_of, embed_init, init_norm


def layer_plan(cfg):
    pat = tuple(cfg.block_pattern)
    n_groups = cfg.n_layers // len(pat)
    tail = tuple(pat[i % len(pat)]
                 for i in range(n_groups * len(pat), cfg.n_layers))
    return pat, n_groups, tail


# --------------------------------------------------------------------- init
def _init_stack(key, cfg, pattern, n_groups, tail_types):
    keys = jax.random.split(key, len(pattern) + max(len(tail_types), 1))
    blocks = []
    for j, bt in enumerate(pattern):
        gkeys = jax.random.split(keys[j], n_groups)
        blocks.append(jax.vmap(lambda k, b=bt: init_block(k, cfg, b))(gkeys))
    tail = [init_block(keys[len(pattern) + i], cfg, bt)
            for i, bt in enumerate(tail_types)]
    return {"blocks": tuple(blocks), "tail": tuple(tail),
            "ln_f": init_norm(cfg)}


def init_params(cfg, key):
    k_emb, k_stack, k_head, k_enc = jax.random.split(key, 4)
    pattern, n_groups, tail_types = layer_plan(cfg)
    dt = dtype_of(cfg.param_dtype)
    params = {
        "embed": embed_init(k_emb, (cfg.vocab_size, cfg.d_model), dt),
        "decoder": _init_stack(k_stack, cfg, pattern, n_groups, tail_types),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size), dt)
    if cfg.family == "encdec":
        enc = cfg.encoder
        params["encoder"] = _init_stack(
            k_enc, cfg, ("enc",), enc.n_layers, ())
    return params


# ------------------------------------------------------------------- stack
def _run_stack(stack, x, cfg, pattern, tail_types, *, positions, mode,
               context=None, cache=None, idx=None, attn_len=0):
    """Returns (x, new_cache_or_None, aux_loss_sum)."""
    n_groups = None
    for leaf in jax.tree_util.tree_leaves(stack["blocks"]):
        n_groups = leaf.shape[0]
        break

    seq_par = cfg.seq_parallel_residual and mode in ("train", "prefill")

    def group_body(x, pgroup, cgroup):
        entries, aux_tot = [], 0.0
        for j, bt in enumerate(pattern):
            # pin the residual stream to batch/data sharding: stops GSPMD
            # flipping to batch-replicated layouts around FSDP weights
            x = meshctx.wsc_batch(x, seq_parallel=seq_par)
            x, ce, aux = apply_block(
                pgroup[j], x, cfg, bt, positions=positions, mode=mode,
                context=context, cache=None if cgroup is None else cgroup[j],
                idx=idx, attn_len=attn_len)
            entries.append(ce)
            aux_tot = aux_tot + aux
        x = meshctx.wsc_batch(x)
        return x, tuple(entries), jnp.asarray(aux_tot, jnp.float32)

    if mode == "train" and cfg.remat:
        group_body = jax.checkpoint(group_body)

    new_cache = {"blocks": (), "tail": ()}
    aux_total = jnp.zeros((), jnp.float32)

    if n_groups:
        if cache is None:
            def body(x, pgroup):
                x, entries, aux = group_body(x, pgroup, None)
                return x, (entries, aux)
            x, (entries, auxs) = jax.lax.scan(body, x, stack["blocks"])
        else:
            def body(x, xs):
                pgroup, cgroup = xs
                x, entries, aux = group_body(x, pgroup, cgroup)
                return x, (entries, aux)
            x, (entries, auxs) = jax.lax.scan(
                body, x, (stack["blocks"], cache["blocks"]))
        new_cache["blocks"] = entries
        aux_total = aux_total + auxs.sum()

    tail_entries = []
    for i, bt in enumerate(tail_types):
        ce_in = None if cache is None else cache["tail"][i]
        x, ce, aux = apply_block(
            stack["tail"][i], x, cfg, bt, positions=positions, mode=mode,
            context=context, cache=ce_in, idx=idx, attn_len=attn_len)
        tail_entries.append(ce)
        aux_total = aux_total + aux
    new_cache["tail"] = tuple(tail_entries)

    x = apply_norm(stack["ln_f"], x, cfg)
    if mode == "train":
        new_cache = None
    return x, new_cache, aux_total


def _logits(params, cfg, x):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ head


def apply_model(params, cfg, tokens, *, positions=None, aux_embeds=None,
                mode="train", cache=None, idx=None, attn_len=0):
    """tokens: (B, S) int32. aux_embeds: (B, n_aux, d_model) stubbed modality
    frontend output (audio frames / image patches). Returns
    (logits, new_cache, aux_loss)."""
    b, s = tokens.shape
    if positions is None:
        if mode == "decode":
            positions = jnp.full((b, s), idx, jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = jnp.take(params["embed"], tokens, axis=0).astype(
        dtype_of(cfg.compute_dtype))

    context = None
    if cfg.family == "encdec" and mode != "decode":
        enc_pos = jnp.broadcast_to(
            jnp.arange(aux_embeds.shape[1], dtype=jnp.int32),
            aux_embeds.shape[:2])
        ctx, _, _ = _run_stack(
            params["encoder"], aux_embeds.astype(x.dtype), cfg, ("enc",), (),
            positions=enc_pos, mode="train")
        context = ctx
    elif cfg.family == "vlm" and mode != "decode":
        context = None if aux_embeds is None else aux_embeds.astype(x.dtype)

    pattern, _, tail_types = layer_plan(cfg)
    x, new_cache, aux = _run_stack(
        params["decoder"], x, cfg, pattern, tail_types, positions=positions,
        mode=mode, context=context, cache=cache, idx=idx, attn_len=attn_len)
    logits = _logits(params, cfg, x)
    return logits, new_cache, aux


# ----------------------------------------------------------------- training
def loss_fn(params, cfg, batch):
    """batch: {"tokens": (B,S), "labels": (B,S) (-100 = ignore),
    optional "aux_embeds"}. Returns (loss, metrics)."""
    logits, _, aux = apply_model(
        params, cfg, batch["tokens"], aux_embeds=batch.get("aux_embeds"),
        mode="train")
    logits = logits.astype(jnp.float32)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    ce = ((lse - tgt) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    loss = ce + aux
    return loss, {"ce": ce, "aux": aux,
                  "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}


# ------------------------------------------------------------------ serving
def prefill(params, cfg, tokens, *, attn_len, aux_embeds=None):
    """Full forward building the decode cache. Returns (last_logits, cache)."""
    logits, cache, _ = apply_model(
        params, cfg, tokens, aux_embeds=aux_embeds, mode="prefill",
        attn_len=attn_len)
    return logits[:, -1], cache


def decode_step(params, cfg, cache, token, idx):
    """One-token decode. token: (B, 1) int32; idx: scalar int32 absolute
    position of this token. Returns (logits (B, V), new_cache)."""
    logits, new_cache, _ = apply_model(
        params, cfg, token, mode="decode", cache=cache, idx=idx)
    return logits[:, 0], new_cache
