"""Basic layers: norms, MLPs, RoPE, initializers. Pure-function + pytree style."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------- init utils
def dense_init(key, shape, dtype, scale: float = 1.0):
    fan_in = shape[0] if len(shape) >= 2 else 1
    std = scale / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------- norms
def init_norm(cfg, d=None):
    d = d or cfg.d_model
    p = {"scale": jnp.ones((d,), dtype_of(cfg.param_dtype))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype_of(cfg.param_dtype))
    return p


def apply_norm(p, x, cfg, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale, x, eps: float = 1e-6):
    """Per-head RMSNorm (qk_norm); x: (..., d_head)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- MLP
def init_mlp(key, cfg, d_ff=None):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 3)
    p = {"wo": dense_init(ks[2], (f, d), dt)}
    if cfg.act == "swiglu":
        p["wi"] = dense_init(ks[0], (d, f), dt)
        p["wg"] = dense_init(ks[1], (d, f), dt)
    else:
        p["wi"] = dense_init(ks[0], (d, f), dt)
    return p


def apply_mlp(p, x, cfg):
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    return h @ p["wo"]


# ---------------------------------------------------------------- RoPE
def rope_freqs(positions, d_head, theta, fraction=1.0):
    """positions: (..., S) int32 -> cos/sin (..., S, d_rot//2)."""
    d_rot = int(d_head * fraction)
    d_rot -= d_rot % 2
    inv = 1.0 / (theta ** (np.arange(0, d_rot, 2, dtype=np.float32) / d_rot))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang), d_rot


def apply_rope(x, positions, theta, fraction=1.0):
    """x: (B, S, H, D); positions: (B, S)."""
    cos, sin, d_rot = rope_freqs(positions, x.shape[-1], theta, fraction)
    if d_rot == 0:
        return x
    xr, xp = x[..., :d_rot], x[..., d_rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    o1 = x1 * cos - x2 * sin
    o2 = x1 * sin + x2 * cos
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)
