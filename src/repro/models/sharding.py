"""GSPMD sharding rules: param pytree path+shape -> PartitionSpec.

Baseline scheme:
  * tensor parallel over "model": attention head / d_ff / expert / vocab dims
  * data parallel over ("pod","data"): batch dims of activations and caches
  * fsdp configs additionally shard the non-TP param dim over "data"

Dims are only sharded when divisible by the axis size (uneven GSPMD padding
is avoided in the baseline; hillclimbs may relax this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axsize(mesh, axis):
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]


def _ok(mesh, dim, axis):
    return axis is not None and dim % _axsize(mesh, axis) == 0


def _guard(mesh, shape, spec):
    """Drop axes that don't divide their dim."""
    out = []
    for dim, ax in zip(shape, spec):
        out.append(ax if _ok(mesh, dim, ax) else None)
    return P(*out)


def param_pspec(path, leaf, cfg, mesh):
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    stacked = "blocks" in names  # scan-stacked: leading group dim unsharded
    fsdp = "data" if cfg.fsdp else None
    shape = leaf.shape[1:] if stacked else leaf.shape

    def out(*spec):
        spec = _guard(mesh, shape, spec)
        if stacked:
            spec = P(None, *spec)
        return spec

    nd = len(shape)
    if name == "embed":
        return out("model", fsdp)
    if name == "lm_head":
        return out(fsdp, "model")
    if name in ("wi", "wg", "wo") and nd == 3:          # MoE experts (E, ., .)
        if name == "wo":
            return out("model", None, fsdp)
        return out("model", fsdp, None)
    if name in ("wq", "wk", "wv", "wi", "wg", "wx", "wz", "wdt", "wgate",
                "shared_wi", "shared_wg") and nd == 2:
        return out(fsdp, "model")
    if name in ("wbc", "conv_bc") and nd == 2:   # head-shared B/C: replicate
        return out(None, None)
    if name in ("wo", "out_proj", "out", "shared_wo") and nd == 2:
        return out("model", fsdp)
    if name in ("wa",) and nd == 2:                     # RG-LRU gates (D, D)
        return out(None, "model")
    if name == "router":
        return out(fsdp, None)
    if name in ("conv_w", "conv_x"):
        return out(None, "model")
    if name in ("bq", "bk", "bv", "conv_b", "conv_x_b") and nd == 1:
        return out("model")
    return out(*([None] * nd))


def params_pspecs(mesh, params, cfg):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_pspec(path, leaf, cfg, mesh), params)


def params_shardings(mesh, params, cfg):
    def f(path, leaf):
        return NamedSharding(mesh, param_pspec(path, leaf, cfg, mesh))
    return jax.tree_util.tree_map_with_path(f, params)


def wrap(mesh, pspec_tree):
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))


def bytes_per_device(struct_tree, sharding_tree):
    """Per-device bytes of a ShapeDtypeStruct tree under given shardings."""
    total = 0
    for leaf, sh in zip(jax.tree_util.tree_leaves(struct_tree),
                        jax.tree_util.tree_leaves(
                            sharding_tree,
                            is_leaf=lambda x: isinstance(x, NamedSharding))):
        shard_shape = sh.shard_shape(leaf.shape)
        n = 1
        for d in shard_shape:
            n *= d
        total += n * leaf.dtype.itemsize
    return total


def batch_pspec(mesh):
    return P(dp_axes(mesh))


def batch_shardings(mesh, batch_tree):
    """Shard the leading (batch) dim of every leaf over the dp axes."""
    dp = dp_axes(mesh)

    def f(leaf):
        spec = [dp] + [None] * (len(leaf.shape) - 1)
        if leaf.shape[0] % _axsize(mesh, dp) != 0:
            spec[0] = None
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map(f, batch_tree)


def cache_shardings(mesh, cache_tree, cfg):
    """Caches: batch over dp; kv-head / state-head dims over model when they
    divide. Leaves are stacked (n_groups, B, ...) for scanned blocks — detect
    by path containing 'blocks'."""
    dp = dp_axes(mesh)

    def f(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        stacked = "blocks" in [str(n) for n in names] or any(
            getattr(p, "idx", None) is not None and "blocks" in str(path)
            for p in path)
        # robust stacked detection: blocks entries come as
        # ('blocks', idx, leafname); tail as ('tail', idx, leafname)
        stacked = "blocks" in str(path)
        shape = leaf.shape[1:] if stacked else leaf.shape
        name = names[-1] if names else ""
        spec = [None] * len(shape)
        if len(shape) >= 1 and shape[0] % _axsize(mesh, dp) == 0:
            spec[0] = dp
        if name in ("k", "v", "ck", "cv") and len(shape) == 4:
            # sequence-parallel KV cache: shard the LENGTH dim over 'model'
            # (kv-head counts rarely divide the TP degree; cache length
            # always does). Decode attention merges per-shard partials —
            # see attention._flash_decode.
            if shape[1] % mesh.shape["model"] == 0:
                spec[1] = "model"
            elif shape[2] % mesh.shape["model"] == 0:
                spec[2] = "model"
        if name == "pos" and len(shape) == 2:
            if shape[1] % mesh.shape["model"] == 0:
                spec[1] = "model"
        if name in ("k_scale", "v_scale") and len(shape) == 3:
            if shape[1] % mesh.shape["model"] == 0:
                spec[1] = "model"
        if name == "h" and len(shape) == 4:             # SSM (B, H, P, N)
            if shape[1] % mesh.shape["model"] == 0:
                spec[1] = "model"
        if name in ("conv", "conv_x") and len(shape) == 3:
            if shape[2] % mesh.shape["model"] == 0:
                spec[2] = "model"
        if name == "h" and len(shape) == 2:             # RG-LRU (B, D)
            if shape[1] % mesh.shape["model"] == 0:
                spec[1] = "model"
        if stacked:
            spec = [None] + spec
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(f, cache_tree)
