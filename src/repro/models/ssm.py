"""Mamba-2 SSD (state-space duality) mixer [arXiv:2405.21060].

Training / prefill use the chunked SSD algorithm (quadratic intra-chunk,
linear inter-chunk scan); decode is the O(1) recurrent update. n_groups is
fixed to 1 (B/C shared across heads), matching the mamba2-1.3b config.

Projections are SEPARATE matmuls (z, x, BC, dt) rather than one fused
in_proj: under tensor parallelism x/z/dt shard over heads ('model' axis)
while the head-shared B/C stay replicated — a fused projection forces GSPMD
to reshard slices of the fused output (collective-permute per layer) and to
all-reduce the C.B intra-chunk einsum. See EXPERIMENTS.md §Perf (mamba2).

All recurrence math runs in f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, dtype_of

NEG_INF = -1e30


def dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads, s.head_dim, s.d_state, s.d_conv


def init_mamba(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, h, pdim, n, d_conv = dims(cfg)
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 6)
    return {
        "wz": dense_init(ks[0], (d, d_inner), dt),
        "wx": dense_init(ks[1], (d, d_inner), dt),
        "wbc": dense_init(ks[2], (d, 2 * n), dt),
        "wdt": dense_init(ks[3], (d, h), dt),
        "conv_x": dense_init(ks[4], (d_conv, d_inner), dt, scale=1.0),
        "conv_x_b": jnp.zeros((d_inner,), dt),
        "conv_bc": dense_init(ks[5], (d_conv, 2 * n), dt, scale=1.0),
        "conv_bc_b": jnp.zeros((2 * n,), dt),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dt),
        "out_proj": dense_init(ks[0], (d_inner, d), dt),
    }


def _conv_seq(w, b, x, init_state=None):
    """Depthwise causal conv over time. x: (B, L, C). Returns (y, state)."""
    d_conv = w.shape[0]
    pad = d_conv - 1
    if init_state is None:
        xpad = jnp.pad(x, ((0, 0), (pad, 0), (0, 0)))
    else:
        xpad = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)
    y = sum(xpad[:, i:i + x.shape[1], :] * w[i] for i in range(d_conv))
    return jax.nn.silu(y + b), xpad[:, -pad:, :]


def _conv_step(w, b, x1, state):
    """One-step conv. x1: (B, C); state: (B, d_conv-1, C)."""
    d_conv = w.shape[0]
    xin = jnp.concatenate([state.astype(x1.dtype), x1[:, None, :]], axis=1)
    y = sum(xin[:, i, :] * w[i] for i in range(d_conv))
    return jax.nn.silu(y + b), xin[:, 1:, :]


def _gated_norm(p, y, z, eps=1e-6):
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    ms = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps) * p["norm_scale"].astype(jnp.float32))


def ssd_chunked(xh, dth, a_log, Bm, Cm, chunk, h0=None, use_pallas=False):
    """Chunked SSD.

    xh: (B, L, H, P) inputs; dth: (B, L, H) f32 (post-softplus);
    a_log: (B, L, H) f32 = -exp(A_log)*dt (log decay per step);
    Bm, Cm: (B, L, N) f32; h0: (B, H, P, N) initial state or None.
    use_pallas routes the intra-chunk quadratic through kernels/ssd_intra.
    Returns y (B, L, H, P) f32, final state (B, H, P, N) f32.
    """
    b, l, h, pdim = xh.shape
    n = Bm.shape[-1]
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dth = jnp.pad(dth, ((0, 0), (0, pad), (0, 0)))
        a_log = jnp.pad(a_log, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // q
    xh = xh.reshape(b, nc, q, h, pdim)
    dth = dth.reshape(b, nc, q, h)
    a_log = a_log.reshape(b, nc, q, h)
    Bm = Bm.reshape(b, nc, q, n)
    Cm = Cm.reshape(b, nc, q, n)

    la = jnp.cumsum(a_log, axis=2)                      # (B,nc,Q,H) inclusive
    # intra-chunk (dual / attention-like form)
    if use_pallas:
        from repro.kernels import ops as kops
        y_intra = kops.ssd_intra(xh, dth, la, Bm, Cm)
    else:
        seg = la[:, :, :, None, :] - la[:, :, None, :, :]   # (B,nc,i,j,H)
        mask = jnp.tril(jnp.ones((q, q), bool))
        seg = jnp.where(mask[None, None, :, :, None], seg, NEG_INF)
        decay = jnp.exp(seg)                                # (B,nc,i,j,H)
        cb = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)          # (B,nc,i,j)
        w = cb[..., None] * decay * dth[:, :, None, :, :]   # (B,nc,i,j,H)
        y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xh)

    # chunk states: contribution of chunk c to the state at its end
    last = la[:, :, -1:, :]                             # (B,nc,1,H)
    dec_to_end = jnp.exp(last - la)                     # (B,nc,Q,H)
    st = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                    dec_to_end * dth, Bm, xh)

    # inter-chunk scan
    chunk_decay = jnp.exp(la[:, :, -1, :])              # (B,nc,H)

    def step(hprev, inp):
        dec, s = inp                                    # (B,H), (B,H,P,N)
        hnew = hprev * dec[:, :, None, None] + s
        return hnew, hprev                              # emit state at chunk START

    hinit = jnp.zeros((b, h, pdim, n), jnp.float32) if h0 is None else h0
    hlast, hstart = jax.lax.scan(
        step, hinit,
        (chunk_decay.transpose(1, 0, 2), st.transpose(1, 0, 2, 3, 4)))
    hstart = hstart.transpose(1, 0, 2, 3, 4)            # (B,nc,H,P,N)

    # inter contribution: y_inter[i] = exp(la_i) * C_i . h_start
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp",
                         jnp.exp(la), Cm, hstart)
    y = (y_intra + y_inter).reshape(b, nc * q, h, pdim)[:, :l]
    return y, hlast


def apply_mamba(p, x, cfg, *, state=None):
    """Full-sequence forward (train/prefill). x: (B, L, d).
    state: optional {"conv_x","conv_bc","h"} to resume. Returns
    (out, new_state)."""
    d_inner, h, pdim, n, _ = dims(cfg)
    b, l, _ = x.shape
    z = x @ p["wz"]
    xs = x @ p["wx"]
    bc = x @ p["wbc"]
    dt = x @ p["wdt"]
    cx = None if state is None else state["conv_x"]
    cbc = None if state is None else state["conv_bc"]
    h0 = None if state is None else state["h"]
    xs, conv_x_state = _conv_seq(p["conv_x"], p["conv_x_b"], xs, cx)
    bc, conv_bc_state = _conv_seq(p["conv_bc"], p["conv_bc_b"], bc, cbc)
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    xh = xs.reshape(b, l, h, pdim).astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a_log = -jnp.exp(p["A_log"]) * dtf                  # (B,L,H)
    y, hlast = ssd_chunked(xh, dtf, a_log, Bm.astype(jnp.float32),
                           Cm.astype(jnp.float32), cfg.ssm.chunk, h0,
                           use_pallas=cfg.use_pallas_ssd)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(b, l, d_inner)
    out = _gated_norm(p, y, z.astype(jnp.float32)).astype(x.dtype) @ p["out_proj"]
    return out, {"conv_x": conv_x_state, "conv_bc": conv_bc_state, "h": hlast}


def decode_mamba(p, x, cfg, state):
    """One-token decode. x: (B, 1, d); state {"conv_x": (B, d_conv-1, di),
    "conv_bc": (B, d_conv-1, 2N), "h": (B, H, P, N)}."""
    d_inner, h, pdim, n, d_conv = dims(cfg)
    b = x.shape[0]
    z = x @ p["wz"]
    xs = (x @ p["wx"])[:, 0]
    bc = (x @ p["wbc"])[:, 0]
    dt = (x @ p["wdt"])[:, 0]
    xs, new_cx = _conv_step(p["conv_x"], p["conv_x_b"], xs, state["conv_x"])
    bc, new_cbc = _conv_step(p["conv_bc"], p["conv_bc_b"], bc,
                             state["conv_bc"])
    Bm, Cm = jnp.split(bc, 2, axis=-1)
    xh = xs.reshape(b, h, pdim).astype(jnp.float32)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(-jnp.exp(p["A_log"]) * dtf)             # (B,H)
    hnew = (state["h"] * a[:, :, None, None]
            + jnp.einsum("bh,bn,bhp->bhpn", dtf, Bm.astype(jnp.float32), xh))
    yh = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), hnew)
    yh = yh + p["D"][None, :, None] * xh
    yflat = yh.reshape(b, 1, d_inner)
    out = _gated_norm(p, yflat, z.astype(jnp.float32)).astype(x.dtype) @ p["out_proj"]
    return out, {"conv_x": new_cx, "conv_bc": new_cbc, "h": hnew}
