"""Residual block variants and their per-layer cache handling.

Block types:
  dense  attn + MLP                 moe    attn + MoE-FFN
  lattn  local-window attn + MLP    rec    RG-LRU + MLP (Griffin)
  mamba2 SSD mixer                  enc    bidirectional attn + MLP
  xattn  gated cross-attn + MLP     decx   self-attn + cross-attn + MLP
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.layers import apply_mlp, apply_norm, init_mlp, init_norm
from repro.models.moe import apply_moe, init_moe
from repro.models.rglru import apply_rglru, decode_rglru, init_rglru
from repro.models.ssm import apply_mamba, decode_mamba, init_mamba

ATTN_TYPES = ("dense", "moe", "lattn", "enc", "decx")


def init_block(key, cfg, btype):
    ks = jax.random.split(key, 4)
    if btype == "mamba2":
        return {"ln1": init_norm(cfg), "mixer": init_mamba(ks[0], cfg)}
    if btype == "rec":
        return {"ln1": init_norm(cfg), "mixer": init_rglru(ks[0], cfg),
                "ln2": init_norm(cfg), "mlp": init_mlp(ks[1], cfg)}
    if btype in ("dense", "lattn", "enc"):
        return {"ln1": init_norm(cfg), "attn": attn_lib.init_attn(ks[0], cfg),
                "ln2": init_norm(cfg), "mlp": init_mlp(ks[1], cfg)}
    if btype == "moe":
        return {"ln1": init_norm(cfg), "attn": attn_lib.init_attn(ks[0], cfg),
                "ln2": init_norm(cfg), "moe": init_moe(ks[1], cfg)}
    if btype == "xattn":
        return {"ln1": init_norm(cfg),
                "xattn": attn_lib.init_attn(ks[0], cfg, cross=True),
                "ln2": init_norm(cfg), "mlp": init_mlp(ks[1], cfg)}
    if btype == "decx":
        p = {"ln1": init_norm(cfg), "attn": attn_lib.init_attn(ks[0], cfg),
             "lnx": init_norm(cfg),
             "xattn": attn_lib.init_attn(ks[1], cfg, cross=True),
             "ln2": init_norm(cfg), "mlp": init_mlp(ks[2], cfg)}
        del p["xattn"]["gate"]  # enc-dec cross-attn is ungated
        return p
    raise ValueError(f"unknown block type {btype}")


def _ffn(p, x, cfg):
    """Second residual half; returns (delta, aux_loss)."""
    if "moe" in p:
        h = apply_norm(p["ln2"], x, cfg)
        out, aux = apply_moe(p["moe"], h, cfg)
        return out, aux
    h = apply_norm(p["ln2"], x, cfg)
    return apply_mlp(p["mlp"], h, cfg), 0.0


def apply_block(p, x, cfg, btype, *, positions, mode, context=None,
                cache=None, idx=None, attn_len=0):
    """Apply one residual block.

    mode: "train" (no cache output), "prefill" (build cache entry),
    "decode" (consume+update cache entry).
    Returns (x, cache_entry, aux_loss); cache_entry is () in train mode.
    """
    from repro.models.cache import pack_full_kv  # local import (cycle-free)

    aux = 0.0
    window = cfg.window if btype == "lattn" else 0

    if btype == "mamba2":
        h = apply_norm(p["ln1"], x, cfg)
        if mode == "decode":
            out, entry = decode_mamba(p["mixer"], h, cfg, cache)
        else:
            out, entry = apply_mamba(p["mixer"], h, cfg)
        x = x + out
        return x, (() if mode == "train" else entry), aux

    if btype == "rec":
        h = apply_norm(p["ln1"], x, cfg)
        if mode == "decode":
            out, entry = decode_rglru(p["mixer"], h, cfg, cache)
        else:
            out, entry = apply_rglru(p["mixer"], h, cfg)
        x = x + out
        d, aux = _ffn(p, x, cfg)
        return x + d, (() if mode == "train" else entry), aux

    if btype == "xattn":
        h = apply_norm(p["ln1"], x, cfg)
        if mode == "decode":
            out, _ = attn_lib.cross_attention(
                p["xattn"], h, cfg, kv=(cache["ck"], cache["cv"]))
            entry = cache
        else:
            out, (ck, cv) = attn_lib.cross_attention(
                p["xattn"], h, cfg, context=context)
            entry = () if mode == "train" else {"ck": ck, "cv": cv}
        x = x + out
        d, aux = _ffn(p, x, cfg)
        return x + d, entry, aux

    # attention blocks: dense / moe / lattn / enc / decx
    h = apply_norm(p["ln1"], x, cfg)
    causal = btype != "enc"
    if mode == "decode":
        lc = cache["k"].shape[1]
        slot = jax.lax.rem(idx, lc)
        pos_buf = jax.lax.dynamic_update_slice(
            cache["pos"], positions.astype(jnp.int32), (0, slot))
        out, kv = attn_lib.self_attention(
            p["attn"], h, cfg, positions, causal=True, window=window,
            kv_cache=cache, cache_slot=slot, cache_positions=pos_buf)
        entry = dict(kv, pos=pos_buf)
    else:
        out, (k, v) = attn_lib.self_attention(
            p["attn"], h, cfg, positions, causal=causal, window=window)
        if mode == "train" or btype == "enc":
            entry = ()
        else:
            entry = pack_full_kv(k, v, positions, attn_len, window=window,
                                 kv_bits=cfg.kv_quant_bits)
    x = x + out

    if btype == "decx":
        hx = apply_norm(p["lnx"], x, cfg)
        if mode == "decode":
            xout, _ = attn_lib.cross_attention(
                p["xattn"], hx, cfg, kv=(cache["ck"], cache["cv"]))
        else:
            xout, (ck, cv) = attn_lib.cross_attention(
                p["xattn"], hx, cfg, context=context)
            if entry != ():
                entry = dict(entry, ck=ck, cv=cv)
        x = x + xout
        if mode == "decode":
            entry = dict(entry, ck=cache["ck"], cv=cache["cv"])

    d, aux = _ffn(p, x, cfg)
    return x + d, entry, aux
