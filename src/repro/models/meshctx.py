"""Mesh context: lets model code apply sharding constraints / shard_map
when tracing under a known production mesh, while remaining mesh-agnostic
for CPU smoke tests (no-ops when unset).

Launch code (dryrun / train / serve) calls ``set_mesh(mesh)`` before
tracing; model internals use ``wsc_batch`` to pin the residual stream to
batch (data) sharding — without this, GSPMD may flip activations to
batch-replicated/feature-sharded layouts to avoid FSDP weight gathers,
which explodes collective volume (see EXPERIMENTS.md §Perf kimi-k2).
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None


def set_mesh(mesh):
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


@contextmanager
def use_mesh(mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev


def dp_axes():
    m = _MESH
    if m is None:
        return None
    return ("pod", "data") if "pod" in m.axis_names else ("data",)


def _axsize(mesh, axes):
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return n


def wsc_batch(x, *, seq_parallel=False):
    """Pin the leading (batch) dim of x to data-parallel sharding; with
    seq_parallel additionally shard the sequence dim over 'model'
    (Megatron-style sequence parallelism: the layer's output all-reduce
    becomes a reduce-scatter + the next layer's input all-gather, ~2x less
    collective volume, and norms compute on 1/TP of the tokens)."""
    m = _MESH
    if m is None:
        return x
    dp = dp_axes()
    if x.shape[0] % _axsize(m, dp) != 0:
        return x
    spec = [dp] + [None] * (x.ndim - 1)
    if (seq_parallel and x.ndim == 3 and x.shape[1] > 1
            and x.shape[1] % m.shape["model"] == 0):
        spec[1] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(m, P(*spec)))


def ep_available(cfg):
    """Expert-parallel shard_map path available for this config/mesh?"""
    m = _MESH
    if m is None or cfg.moe is None or "model" not in m.axis_names:
        return False
    if cfg.moe.n_experts % m.shape["model"] != 0:
        return False
    if cfg.fsdp and cfg.d_model % m.shape["data"] != 0:
        return False
    return True
