"""GQA attention: chunked (flash-style) online-softmax implementation usable
for training, prefill and decode, with causal / local-window / bidirectional
masking and ring-buffer KV caches.

The chunked formulation bounds peak activation memory to O(Sq * chunk) per
head instead of O(Sq * Sk) — required for prefill_32k / train_4k to fit HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.layers import (apply_rope, dense_init, dtype_of,
                                 rms_head_norm)

NEG_INF = -1e30


def init_attn(key, cfg, *, cross: bool = False):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = dtype_of(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * dh), dt),
        "wk": dense_init(ks[1], (d, hkv * dh), dt),
        "wv": dense_init(ks[2], (d, hkv * dh), dt),
        "wo": dense_init(ks[3], (hq * dh, d), dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dt)
        p["bk"] = jnp.zeros((hkv * dh,), dt)
        p["bv"] = jnp.zeros((hkv * dh,), dt)
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((dh,), dt)
        p["k_scale"] = jnp.ones((dh,), dt)
    if cross:
        p["gate"] = jnp.zeros((), dt)  # tanh-gated cross-attn (VLM)
    return p


def _qkv(p, x, xc, cfg):
    """x: (B,S,d) queries source; xc: kv source (==x for self-attn)."""
    b, s, _ = x.shape
    sk = xc.shape[1]
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"]
    k = xc @ p["wk"]
    v = xc @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, hq, dh)
    k = k.reshape(b, sk, hkv, dh)
    v = v.reshape(b, sk, hkv, dh)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_scale"], q)
        k = rms_head_norm(p["k_scale"], k)
    return q, k, v


def flash_attention(q, k, v, *, q_positions, k_positions, causal=True,
                    window=0, chunk=1024, q_block=2048, k_scale=None,
                    v_scale=None):
    """Online-softmax attention, chunked over the KV axis and (for long
    queries) blocked over the query axis so peak memory is
    O(q_block * chunk) per head rather than O(Sq * Sk).

    q: (B, Sq, Hq, D);  k, v: (B, Sk, Hkv, D);  Hq % Hkv == 0.
    q_positions: (B, Sq) int32;  k_positions: (B, Sk) int32, -1 = invalid slot.
    window > 0 limits attention to k_pos in (q_pos - window, q_pos].
    Returns (B, Sq, Hq, D) in q.dtype.
    """
    b, sq, hq, dh = q.shape
    if sq > q_block:
        pad = (-sq) % q_block
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pp = jnp.pad(q_positions, ((0, 0), (0, pad)), constant_values=-1)
        nq = qp.shape[1] // q_block
        qp = qp.reshape(b, nq, q_block, hq, dh).transpose(1, 0, 2, 3, 4)
        pp = pp.reshape(b, nq, q_block).transpose(1, 0, 2)
        out = jax.lax.map(
            lambda xs: _flash_inner(xs[0], k, v, q_positions=xs[1],
                                    k_positions=k_positions, causal=causal,
                                    window=window, chunk=chunk),
            (qp, pp))
        out = out.transpose(1, 0, 2, 3, 4).reshape(b, nq * q_block, hq, dh)
        return out[:, :sq]
    if sq == 1:
        # decode: no sequential dependency — per-chunk partials in parallel,
        # merged with a log-sum-exp combine. GSPMD keeps the cache sharded
        # over 'model' on the length dim (sequence-parallel flash-decode);
        # the merge is a tiny cross-shard reduction instead of gathering the
        # whole cache. See EXPERIMENTS.md §Perf.
        return _flash_decode(q, k, v, q_positions=q_positions,
                             k_positions=k_positions, causal=causal,
                             window=window, chunk=chunk,
                             k_scale=k_scale, v_scale=v_scale)
    assert k_scale is None, "quantized cache is a decode-path feature"
    return _flash_inner(q, k, v, q_positions=q_positions,
                        k_positions=k_positions, causal=causal,
                        window=window, chunk=chunk)


def _flash_decode(q, k, v, *, q_positions, k_positions, causal, window,
                  chunk, k_scale=None, v_scale=None):
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = dh ** -0.5
    mult_dtype = q.dtype if k_scale is not None else k.dtype
    qf = (q.reshape(b, hkv, g, dh) * jnp.asarray(scale, q.dtype)
          ).astype(mult_dtype)
    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)),
                              constant_values=-1)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
    nc = k.shape[1] // chunk
    kc = k.reshape(b, nc, chunk, hkv, dh)
    vc = v.reshape(b, nc, chunk, hkv, dh)
    pc = k_positions.reshape(b, nc, chunk)
    if k_scale is not None:
        kc = kc.astype(mult_dtype)
        vc = vc.astype(mult_dtype)

    s = jnp.einsum("bhgd,bnchd->bnhgc", qf, kc,
                   preferred_element_type=jnp.float32)   # (B,nc,Hkv,G,C)
    if k_scale is not None:
        ksc = k_scale.reshape(b, nc, chunk, hkv).transpose(0, 1, 3, 2)
        s = s * ksc[:, :, :, None, :]                    # (B,nc,Hkv,1,C)
    valid = pc[:, :, None, None, :] >= 0
    qpos = q_positions[:, 0][:, None, None, None, None]
    if causal:
        valid &= pc[:, :, None, None, :] <= qpos
    if window:
        valid &= pc[:, :, None, None, :] > qpos - window
    s = jnp.where(valid, s, NEG_INF)
    m_c = s.max(axis=-1)                                  # (B,nc,Hkv,G)
    p = jnp.exp(s - m_c[..., None])
    l_c = p.sum(axis=-1)
    if v_scale is not None:
        vsc = v_scale.reshape(b, nc, chunk, hkv).transpose(0, 1, 3, 2)
        p = p * vsc[:, :, :, None, :]
    acc_c = jnp.einsum("bnhgc,bnchd->bnhgd", p.astype(vc.dtype), vc,
                       preferred_element_type=jnp.float32)
    m = m_c.max(axis=1)                                   # (B,Hkv,G)
    w = jnp.exp(m_c - m[:, None])
    l = (l_c * w).sum(axis=1)
    acc = (acc_c * w[..., None]).sum(axis=1)              # (B,Hkv,G,D)
    out = acc / jnp.maximum(l, 1e-20)[..., None]
    return out.reshape(b, 1, hq, dh).astype(q.dtype)


def _flash_inner(q, k, v, *, q_positions, k_positions, causal, window, chunk):
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = dh ** -0.5
    # scores multiply in the cache's storage dtype with f32 MXU accumulation
    # (preferred_element_type) — converting the cache to f32 would let XLA
    # hoist a full-cache f32 copy out of the layer scan (15 GB at 32k) and
    # shard+all-gather it. See EXPERIMENTS.md §Perf iteration 1.
    qf = (q.reshape(b, sq, hkv, g, dh) * jnp.asarray(scale, q.dtype)
          ).astype(k.dtype)

    chunk = min(chunk, sk)
    pad = (-sk) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, ((0, 0), (0, pad)),
                              constant_values=-1)
    n_chunks = k.shape[1] // chunk
    # keep the cache in its storage dtype; cast per-chunk INSIDE the scan —
    # casting up front materializes an f32 copy of the whole cache (15 GB for
    # a 32k GQA cache), which GSPMD then shards+all-gathers. See §Perf log.
    kc = k.reshape(b, n_chunks, chunk, hkv, dh)
    vc = v.reshape(b, n_chunks, chunk, hkv, dh)
    pc = k_positions.reshape(b, n_chunks, chunk)

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, pb = xs  # (B,C,Hkv,D), (B,C,Hkv,D), (B,C)
        s = jnp.einsum("bqhgd,bchd->bhgqc", qf, kb,
                       preferred_element_type=jnp.float32)  # (B,Hkv,G,Sq,C)
        valid = pb[:, None, None, None, :] >= 0
        if causal:
            valid &= pb[:, None, None, None, :] <= q_positions[:, None, None, :, None]
        if window:
            valid &= pb[:, None, None, None, :] > (
                q_positions[:, None, None, :, None] - window)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqc,bchd->bhgqd", p.astype(vb.dtype), vb,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (kc.transpose(1, 0, 2, 3, 4), vc.transpose(1, 0, 2, 3, 4),
         pc.transpose(1, 0, 2)))
    out = acc / jnp.maximum(l, 1e-20)[..., None]          # (B,Hkv,G,Sq,D)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, dh)
    return out.astype(q.dtype)


def self_attention(p, x, cfg, positions, *, causal=True, window=0,
                   kv_cache=None, cache_slot=None, cache_positions=None):
    """Self-attention for train/prefill (kv_cache=None) or decode.

    Decode: kv_cache = {"k","v"} each (B, L, Hkv, D); the new token's k/v are
    written at ``cache_slot`` (scalar int32, already modulo cache length);
    cache_positions: (B, L) int32 slot->abs-position map (-1 invalid).
    Returns (out, new_kv) where new_kv is the (k, v) content to cache
    (prefill) or the updated cache dict (decode).
    """
    q, k, v = _qkv(p, x, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    if kv_cache is None:
        out = flash_attention(q, k, v, q_positions=positions,
                              k_positions=positions, causal=causal,
                              window=window, chunk=cfg.attn_chunk)
        new_kv = (k, v)
    elif cfg.kv_quant_bits:
        from repro.models.cache import quantize_kv
        kq, ks1 = quantize_kv(k, cfg.kv_quant_bits)
        vq, vs1 = quantize_kv(v, cfg.kv_quant_bits)
        ck = jax.lax.dynamic_update_slice(kv_cache["k"], kq, (0, cache_slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(kv_cache["v"], vq, (0, cache_slot, 0, 0))
        ksc = jax.lax.dynamic_update_slice(kv_cache["k_scale"], ks1,
                                           (0, cache_slot, 0))
        vsc = jax.lax.dynamic_update_slice(kv_cache["v_scale"], vs1,
                                           (0, cache_slot, 0))
        out = flash_attention(q, ck, cv, q_positions=positions,
                              k_positions=cache_positions, causal=True,
                              window=window, chunk=cfg.attn_chunk,
                              k_scale=ksc, v_scale=vsc)
        new_kv = {"k": ck, "v": cv, "k_scale": ksc, "v_scale": vsc}
    else:
        ck = jax.lax.dynamic_update_slice(kv_cache["k"], k, (0, cache_slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(kv_cache["v"], v, (0, cache_slot, 0, 0))
        out = flash_attention(q, ck, cv, q_positions=positions,
                              k_positions=cache_positions, causal=True,
                              window=window, chunk=cfg.attn_chunk)
        new_kv = {"k": ck, "v": cv}
    b, s = out.shape[0], out.shape[1]
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"], new_kv


def cross_attention(p, x, cfg, *, kv=None, context=None):
    """Cross-attention (VLM image layers / enc-dec decoder).
    Either ``context`` (B, Sc, d) to project, or precomputed ``kv``=(k, v).
    No RoPE; bidirectional over context. Gated if p has 'gate'."""
    if kv is None:
        _, k, v = _qkv(p, context, context, cfg)
    else:
        k, v = kv
    b, s, _ = x.shape
    hq, dh = cfg.n_heads, cfg.head_dim
    q = (x @ p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, s, hq, dh)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_scale"], q)
    qpos = jnp.zeros((b, s), jnp.int32)
    kpos = jnp.zeros((b, k.shape[1]), jnp.int32)
    out = flash_attention(q, k, v, q_positions=qpos, k_positions=kpos,
                          causal=False, chunk=cfg.attn_chunk)
    out = out.reshape(b, s, hq * dh) @ p["wo"]
    if "gate" in p:
        out = jnp.tanh(p["gate"].astype(out.dtype)) * out
    return out, (k, v)


def project_cross_kv(p, context, cfg):
    _, k, v = _qkv(p, context, context, cfg)
    return k, v
