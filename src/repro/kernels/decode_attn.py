"""Pallas TPU kernel: GQA flash-decode attention over a (ring) KV cache.

The edge server's serving hot spot: one query token against a long cache.
Grid (B, Hkv, S/bs) with the cache-length dimension innermost; online
softmax with running (m, l, acc) in VMEM scratch; the ring-buffer position
map (pos, -1 = empty) provides masking, so full and sliding-window caches
use the same kernel. Head-dim tiles are MXU/lane aligned (D multiple of 128
for full utilization; smaller D still works via padding by pallas).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(idx_ref, q_ref, k_ref, v_ref, pos_ref, o_ref,
            m_ref, l_ref, acc_ref, *, ns):
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, D)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (bs, D)
    v = v_ref[0, :, 0].astype(jnp.float32)       # (bs, D)
    pos = pos_ref[0]                             # (bs,)
    d = q.shape[-1]
    scores = jnp.dot(q * (d ** -0.5), k.T,
                     preferred_element_type=jnp.float32)       # (G, bs)
    valid = (pos >= 0) & (pos <= idx_ref[0, 0])
    scores = jnp.where(valid[None, :], scores, NEG_INF)

    m_prev = m_ref[...]                          # (G, 1)
    m_new = jnp.maximum(m_prev, scores.max(axis=-1, keepdims=True))
    p = jnp.exp(scores - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == ns - 1)
    def _store():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


def decode_attention(q, k, v, pos, idx, *, block_s=512, interpret=True):
    """q: (B, Hq, D); k, v: (B, S, Hkv, D); pos: (B, S) int32; idx: scalar.
    Returns (B, Hq, D) f32."""
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bs = min(block_s, s)
    ns = pl.cdiv(s, bs)
    qr = q.reshape(b, hkv, g, d)
    idx2 = jnp.asarray(idx, jnp.int32).reshape(1, 1)
    out = pl.pallas_call(
        functools.partial(_kernel, ns=ns),
        grid=(b, hkv, ns),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bi, h, si: (0, 0)),
            pl.BlockSpec((1, 1, g, d), lambda bi, h, si: (bi, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda bi, h, si: (bi, si, h, 0)),
            pl.BlockSpec((1, bs, 1, d), lambda bi, h, si: (bi, si, h, 0)),
            pl.BlockSpec((1, bs), lambda bi, h, si: (bi, si)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d), lambda bi, h, si: (bi, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, 1), jnp.float32),
                        pltpu.VMEM((g, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(idx2, qr, k, v, pos)
    return out.reshape(b, hq, d)
