"""Fused int8 dequant-matmul kernel for the distilled dispatch trunk.

The train-big/serve-small deployment path (``rl/distill.py``) serves the
entity policy as a small flat MLP over ``observe_per_ue``-style rows,
with every weight matrix stored as linear min-max int8 codes (paper
Eq. 1-2, the same scheme ``quant.py`` applies to intermediate features).
The naive serving chain — dequantize each W to f32 in HBM, then run the
MLP (``ref.flat_trunk_ref``) — pays one full-precision weight
materialization per layer per forward. This kernel fuses the whole
student forward:

  * per-layer dequant ``w = codes * ((mx - mn) / levels) + mn`` in
    VMEM/registers — the f32 weights never exist in HBM,
  * the matmul chain with tanh between layers (linear last), emitting
    the full head-logit row block (every ``HybridActionSpace`` head in
    ONE pass — no per-pair scorer, no attention pooling),

gridded over row blocks of the batch, so batch-10k serving streams rows
through a resident quantized weight set.

``flat_trunk_xla`` is the same computation in plain jnp — the fast path
on CPU/GPU hosts. Both impls share the exact dequant association, so
pallas-vs-xla parity is bitwise on the weight dequant; both match
``ref.flat_trunk_ref`` to f32 tolerance. Layer count and widths are
static (baked into the grid), matching the fixed-E deployment contract
of the distilled trunk.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams as _CompilerParams


def _trunk_kernel(*refs, n_layers, bits):
    x_ref, o_ref = refs[0], refs[-1]
    levels = float((1 << bits) - 1)
    h = x_ref[...].astype(jnp.float32)
    for i in range(n_layers):
        codes_ref, mn_ref, mx_ref, b_ref = refs[1 + 4 * i:5 + 4 * i]
        mn = mn_ref[0, 0]
        mx = mx_ref[0, 0]
        w = codes_ref[...].astype(jnp.float32) * ((mx - mn) / levels) + mn
        h = jnp.dot(h, w, preferred_element_type=jnp.float32) + b_ref[...]
        if i < n_layers - 1:
            h = jnp.tanh(h)
    o_ref[...] = h


def flat_trunk_pallas(x, codes, mns, mxs, bs, *, bits=8, block_n=512,
                      interpret=True):
    """Fused quantized trunk forward -> (M, W) f32 head columns.

    x: (M, F) feature rows (any float dtype); codes: per-layer integer
    weight codes ((nin_i, nout_i), uint8/16); mns/mxs: per-layer ()
    calibration scalars; bs: per-layer (nout_i,) f32 biases (biases stay
    full precision — they are O(width), the weights are O(width^2))."""
    f32 = jnp.float32
    m, feat = x.shape
    n_layers = len(codes)
    width = codes[-1].shape[1]
    bm = max(1, min(block_n, m))
    grid = (pl.cdiv(m, bm),)
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    row = lambda w_: pl.BlockSpec((bm, w_), lambda i: (i, 0))
    in_specs = [row(feat)]
    args = [x.astype(f32)]
    for i in range(n_layers):
        nin, nout = codes[i].shape
        in_specs += [full((nin, nout)), full((1, 1)), full((1, 1)),
                     full((1, nout))]
        args += [codes[i], jnp.asarray(mns[i], f32).reshape(1, 1),
                 jnp.asarray(mxs[i], f32).reshape(1, 1),
                 jnp.asarray(bs[i], f32).reshape(1, nout)]
    return pl.pallas_call(
        functools.partial(_trunk_kernel, n_layers=n_layers, bits=bits),
        grid=grid,
        in_specs=in_specs,
        out_specs=row(width),
        out_shape=jax.ShapeDtypeStruct((m, width), f32),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*args)


def flat_trunk_xla(x, codes, mns, mxs, bs, *, bits=8):
    """The decomposed trunk forward in plain jnp — same per-layer dequant
    association as the kernel (``codes * ((mx - mn) / levels) + mn``), so
    the two impls agree bitwise on the dequantized weights."""
    f32 = jnp.float32
    levels = float((1 << bits) - 1)
    h = x.astype(f32)
    n_layers = len(codes)
    for i in range(n_layers):
        mn = jnp.asarray(mns[i], f32)
        mx = jnp.asarray(mxs[i], f32)
        w = codes[i].astype(f32) * ((mx - mn) / levels) + mn
        h = h @ w + jnp.asarray(bs[i], f32)
        if i < n_layers - 1:
            h = jnp.tanh(h)
    return h
