"""Pallas TPU kernel: Mamba-2 SSD intra-chunk contribution.

The quadratic hot spot of the SSD algorithm (models/ssm.ssd_chunked):

    y[i] = sum_{j<=i} (C_i . B_j) * exp(la_i - la_j) * dt_j * x_j

Grid (batch, n_chunks, heads) with heads innermost; the (Q, Q) C.B^T Gram
matrix is head-independent, so it is computed once per (batch, chunk) into a
VMEM scratch tile on the first head step and reused across heads. Per-head
working set: (Q,Q) decay+weights and a (Q,P) x/out tile — VMEM-sized for
Q=256, P<=128 (Q multiple of 8/128 lanes for MXU alignment).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1e30


def _kernel(c_ref, b_ref, la_ref, dt_ref, x_ref, o_ref, cb_ref):
    h = pl.program_id(2)

    @pl.when(h == 0)
    def _gram():
        c = c_ref[0, 0].astype(jnp.float32)          # (Q, N)
        b = b_ref[0, 0].astype(jnp.float32)          # (Q, N)
        cb_ref[...] = jnp.dot(c, b.T, preferred_element_type=jnp.float32)

    la = la_ref[0, 0, :, 0].astype(jnp.float32)      # (Q,)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)      # (Q,)
    q = la.shape[0]
    seg = la[:, None] - la[None, :]                  # (Q, Q) la_i - la_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    seg = jnp.where(ii >= jj, seg, NEG_INF)
    w = cb_ref[...] * jnp.exp(seg) * dt[None, :]     # (Q, Q)
    x = x_ref[0, 0, :, 0].astype(jnp.float32)        # (Q, P)
    o_ref[0, 0, :, 0] = jnp.dot(w, x, preferred_element_type=jnp.float32
                                ).astype(o_ref.dtype)


def ssd_intra(xh, dt, la, Bm, Cm, *, interpret=True):
    """xh: (B, NC, Q, H, P); dt, la: (B, NC, Q, H) f32;
    Bm, Cm: (B, NC, Q, N). Returns y_intra (B, NC, Q, H, P) f32."""
    b, nc, q, h, p = xh.shape
    n = Bm.shape[-1]
    return pl.pallas_call(
        _kernel,
        grid=(b, nc, h),
        in_specs=[
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda bi, ci, hi: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, q, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, q, 1), lambda bi, ci, hi: (bi, ci, 0, hi)),
            pl.BlockSpec((1, 1, q, 1, p),
                         lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q, 1, p),
                               lambda bi, ci, hi: (bi, ci, 0, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nc, q, h, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((q, q), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(Cm, Bm, la, dt, xh)
