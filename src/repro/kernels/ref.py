"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(x, mn, mx, bits=8):
    """Linear min-max quantization (paper Eq. 1) with static calibration."""
    levels = (1 << bits) - 1
    scale = levels / jnp.maximum(mx - mn, 1e-12)
    y = jnp.clip(jnp.round((x.astype(jnp.float32) - mn) * scale), 0, levels)
    return y.astype(jnp.uint8 if bits <= 8 else jnp.uint16)


def dequantize_ref(y, mn, mx, bits=8):
    """Paper Eq. 2."""
    levels = (1 << bits) - 1
    return y.astype(jnp.float32) * (mx - mn) / levels + mn


def flat_trunk_ref(x, codes, mns, mxs, bs, bits=8):
    """Naive oracle for the fused int8 dequant-matmul dispatch trunk
    (``kernels/flat_trunk.py``): dequantize every weight matrix to f32
    via ``dequantize_ref`` (paper Eq. 2), then run the plain tanh MLP
    (linear last layer) — each full-precision W materializes in HBM."""
    h = x.astype(jnp.float32)
    for i in range(len(codes)):
        w = dequantize_ref(codes[i], jnp.float32(mns[i]),
                           jnp.float32(mxs[i]), bits)
        h = h @ w + jnp.asarray(bs[i], jnp.float32)
        if i < len(codes) - 1:
            h = jnp.tanh(h)
    return h


def bottleneck_encode_ref(x, w, mn, mx, bits=8):
    """Fused compressor encode: (T, d) @ (d, d') then quantize."""
    z = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    return quantize_ref(z, mn, mx, bits)


def ssd_intra_ref(xh, dt, la, Bm, Cm):
    """SSD intra-chunk oracle (mirrors models/ssm.ssd_chunked's intra part).
    xh: (B, NC, Q, H, P); dt, la: (B, NC, Q, H); Bm, Cm: (B, NC, Q, N)."""
    q = xh.shape[2]
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]   # (B,NC,i,j,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    seg = jnp.where(mask[None, None, :, :, None], seg, -1e30)
    cb = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)
    w = cb[..., None] * jnp.exp(seg) * dt[:, :, None, :, :]
    return jnp.einsum("bcijh,bcjhp->bcihp", w, xh)


def decode_attention_ref(q, k, v, pos, idx):
    """GQA decode attention over a (ring) KV cache.

    q: (B, Hq, D) single query token; k, v: (B, S, Hkv, D);
    pos: (B, S) absolute positions (-1 = empty slot); idx: scalar int32.
    Returns (B, Hq, D) f32."""
    b, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, d).astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32))
    valid = (pos >= 0) & (pos <= idx)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, d)


def pair_scorer_ref(ue_emb, d, work, active, geom, consts,
                    w_srv, b_srv, w1, b1, w2, b2):
    """Naive (UE, server) pair scorer: the oracle for
    ``pair_scorer.pair_scorer_pallas`` / ``pair_scorer_xla``.

    Deliberately mirrors the DEFAULT entity path op-for-op — the edge
    tensor build of ``MECEnv.observe_entities`` followed by
    ``nets.entity_trunk``'s materialized (N, E, d_ue+S+3) pair concat and
    scorer MLP — so fused-vs-ref parity is also fused-vs-default parity.
    ``consts`` is the env-built 8-vector (see kernels/pair_scorer.py);
    ``active`` enters only through the per-(server, channel) occupancy
    scalar. Returns (route_logits (N, E), srv_emb (E, S))."""
    f32 = jnp.float32
    ue_emb = ue_emb.astype(f32)
    d = d.astype(f32)
    work = work.astype(f32)
    active = active.astype(f32)
    geom = geom.astype(f32)
    consts = consts.astype(f32)
    n, d_ue = ue_emb.shape
    e = geom.shape[0]
    per_slot = active.sum() / consts[5]
    srv_rows = jnp.concatenate([
        geom * jnp.stack([jnp.float32(1.0), jnp.float32(1.0), consts[7]]),
        jnp.broadcast_to(per_slot, (e,))[:, None],
    ], axis=1)
    srv = jnp.tanh(srv_rows @ w_srv + b_srv)                   # (E, S)
    dist_ne = d[:, None] * geom[None, :, 0]                    # (N, E)
    g_ne = jnp.power(jnp.maximum(dist_ne, 1.0), -consts[0])
    rate = (geom[:, 1] * consts[3])[None, :] \
        * jnp.log2(1.0 + consts[1] * g_ne / consts[2])
    te = work[:, None] * geom[None, :, 2] / consts[4]
    edge = jnp.stack([dist_ne / consts[6], rate, te], axis=-1)
    pair = jnp.concatenate([
        jnp.broadcast_to(ue_emb[:, None, :], (n, e, d_ue)),
        jnp.broadcast_to(srv[None, :, :], (n, e, srv.shape[-1])),
        edge,
    ], axis=-1)
    h = jnp.tanh(pair @ w1 + b1)
    logits = (h @ w2 + b2)[..., 0]                             # (N, E)
    return logits, srv
