"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_ref(x, mn, mx, bits=8):
    """Linear min-max quantization (paper Eq. 1) with static calibration."""
    levels = (1 << bits) - 1
    scale = levels / jnp.maximum(mx - mn, 1e-12)
    y = jnp.clip(jnp.round((x.astype(jnp.float32) - mn) * scale), 0, levels)
    return y.astype(jnp.uint8 if bits <= 8 else jnp.uint16)


def dequantize_ref(y, mn, mx, bits=8):
    """Paper Eq. 2."""
    levels = (1 << bits) - 1
    return y.astype(jnp.float32) * (mx - mn) / levels + mn


def bottleneck_encode_ref(x, w, mn, mx, bits=8):
    """Fused compressor encode: (T, d) @ (d, d') then quantize."""
    z = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    return quantize_ref(z, mn, mx, bits)


def ssd_intra_ref(xh, dt, la, Bm, Cm):
    """SSD intra-chunk oracle (mirrors models/ssm.ssd_chunked's intra part).
    xh: (B, NC, Q, H, P); dt, la: (B, NC, Q, H); Bm, Cm: (B, NC, Q, N)."""
    q = xh.shape[2]
    seg = la[:, :, :, None, :] - la[:, :, None, :, :]   # (B,NC,i,j,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    seg = jnp.where(mask[None, None, :, :, None], seg, -1e30)
    cb = jnp.einsum("bcin,bcjn->bcij", Cm, Bm)
    w = cb[..., None] * jnp.exp(seg) * dt[:, :, None, :, :]
    return jnp.einsum("bcijh,bcjhp->bcihp", w, xh)


def decode_attention_ref(q, k, v, pos, idx):
    """GQA decode attention over a (ring) KV cache.

    q: (B, Hq, D) single query token; k, v: (B, S, Hkv, D);
    pos: (B, S) absolute positions (-1 = empty slot); idx: scalar int32.
    Returns (B, Hq, D) f32."""
    b, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, d).astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32))
    valid = (pos >= 0) & (pos <= idx)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return o.reshape(b, hq, d)
