"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on a real TPU
set REPRO_PALLAS_INTERPRET=0 (or rely on backend autodetection) to compile
them. Wrappers handle shape normalization (flattening leading dims, padding
to block multiples where required).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import bottleneck as _bn
from repro.kernels import decode_attn as _da
from repro.kernels import quant as _q


def _interpret_default():
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def _impl_default(env_var):
    """The REPRO_*_IMPL convention shared by every dual-impl op: the env
    var wins, else the compiled Pallas kernel on TPU and the decomposed
    XLA form elsewhere (interpret-mode Pallas is for parity testing, not
    speed)."""
    return os.environ.get(env_var) \
        or ("pallas" if jax.default_backend() == "tpu" else "xla")


def quantize(x, mn, mx, *, bits=8, impl=None, interpret=None):
    """Any-shape fused quantization; returns integer codes of x.shape.

    ``impl``: "pallas" | "xla" | None (REPRO_QUANT_IMPL, else backend
    autodetection). Both impls share the exact elementwise math, so the
    codes are bitwise-identical; an explicit ``interpret`` implies the
    Pallas path."""
    if impl is None:
        impl = "pallas" if interpret is not None \
            else _impl_default("REPRO_QUANT_IMPL")
    if impl == "xla":
        return _q.quantize_xla(x, mn, mx, bits=bits)
    if impl != "pallas":
        raise ValueError(f"unknown quant impl {impl!r}")
    interpret = _interpret_default() if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = _q.quantize_2d(x2, mn, mx, bits=bits, interpret=interpret)
    return out.reshape(shape)


def dequantize(y, mn, mx, *, bits=8, out_dtype=jnp.float32, impl=None,
               interpret=None):
    """Inverse of :func:`quantize`; same impl selection (REPRO_QUANT_IMPL)."""
    if impl is None:
        impl = "pallas" if interpret is not None \
            else _impl_default("REPRO_QUANT_IMPL")
    if impl == "xla":
        return _q.dequantize_xla(y, mn, mx, bits=bits, out_dtype=out_dtype)
    if impl != "pallas":
        raise ValueError(f"unknown quant impl {impl!r}")
    interpret = _interpret_default() if interpret is None else interpret
    shape = y.shape
    y2 = y.reshape(-1, shape[-1])
    out = _q.dequantize_2d(y2, mn, mx, bits=bits, out_dtype=out_dtype,
                           interpret=interpret)
    return out.reshape(shape)


def bottleneck_encode(x, w, mn, mx, *, bits=8, interpret=None):
    """Fused compressor encode. x: (..., d); w: (d, d')."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = _bn.bottleneck_encode(x2, w, mn, mx, bits=bits, interpret=interpret)
    return out.reshape(shape[:-1] + (w.shape[1],))


def decode_attention(q, k, v, pos, idx, *, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _da.decode_attention(q, k, v, pos, idx, interpret=interpret)


def ssd_intra(xh, dt, la, Bm, Cm, *, interpret=None):
    """Mamba-2 SSD intra-chunk contribution (see kernels/ssd_intra.py)."""
    from repro.kernels import ssd_intra as _ssd
    interpret = _interpret_default() if interpret is None else interpret
    return _ssd.ssd_intra(xh, dt, la, Bm, Cm, interpret=interpret)


def flat_trunk(rows, qlayers, *, bits=8, impl=None, interpret=None):
    """Fused int8 dequant-matmul trunk forward -> (..., W) f32 head
    columns (see kernels/flat_trunk.py).

    ``rows``: (..., F) ``observe_per_ue``-style feature rows; ``qlayers``:
    the weight-quantized layer list from ``rl.distill.quantize_flat_trunk``
    ([{"codes", "mn", "mx", "b"}, ...] — biases stay f32). ``bits`` is
    static (pass it from the quantized trunk's bookkeeping, outside any
    jit trace). ``impl``: "pallas" | "xla" | None (REPRO_FLAT_TRUNK_IMPL,
    else the backend autodetection every dual-impl op uses)."""
    from repro.kernels import flat_trunk as _ft
    if impl is None:
        impl = _impl_default("REPRO_FLAT_TRUNK_IMPL")
    shape = rows.shape
    x2 = rows.reshape(-1, shape[-1])
    codes = tuple(l["codes"] for l in qlayers)
    mns = tuple(l["mn"] for l in qlayers)
    mxs = tuple(l["mx"] for l in qlayers)
    bs = tuple(l["b"] for l in qlayers)
    if impl == "xla":
        out = _ft.flat_trunk_xla(x2, codes, mns, mxs, bs, bits=bits)
    elif impl == "pallas":
        interpret = _interpret_default() if interpret is None else interpret
        out = _ft.flat_trunk_pallas(x2, codes, mns, mxs, bs, bits=bits,
                                    interpret=interpret)
    else:
        raise ValueError(f"unknown flat_trunk impl {impl!r}")
    return out.reshape(shape[:-1] + (out.shape[-1],))


def pair_scorer(ue_emb, raw, srv_enc, scorer, *, impl=None, interpret=None):
    """Fused entity route scorer -> (route_logits (N, E), srv_emb (E, S)).

    ``raw`` is the env's kernel-path observation block
    (``MECEnv.observe_entities_raw``: {"d", "work", "active", "geom",
    "consts"}); ``srv_enc``/``scorer`` are the matching subtrees of
    ``nets.init_entity_actor``. ``impl``: "pallas" | "xla" | None
    (autodetect: the Pallas kernel on TPU, the decomposed XLA form
    elsewhere — interpret-mode Pallas is for parity testing, not speed).
    Override with REPRO_PAIR_SCORER_IMPL."""
    from repro.kernels import pair_scorer as _ps
    if impl is None:
        impl = _impl_default("REPRO_PAIR_SCORER_IMPL")
    args = (ue_emb, raw["d"], raw["work"], raw["active"], raw["geom"],
            raw["consts"], srv_enc["w"], srv_enc["b"],
            scorer[0]["w"], scorer[0]["b"], scorer[1]["w"], scorer[1]["b"])
    if impl == "xla":
        return _ps.pair_scorer_xla(*args)
    if impl != "pallas":
        raise ValueError(f"unknown pair_scorer impl {impl!r}")
    interpret = _interpret_default() if interpret is None else interpret
    return _ps.pair_scorer_pallas(*args, interpret=interpret)
