"""Public jit'd wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in interpret mode; on a real TPU
set REPRO_PALLAS_INTERPRET=0 (or rely on backend autodetection) to compile
them. Wrappers handle shape normalization (flattening leading dims, padding
to block multiples where required).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels import bottleneck as _bn
from repro.kernels import decode_attn as _da
from repro.kernels import quant as _q


def _interpret_default():
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def quantize(x, mn, mx, *, bits=8, interpret=None):
    """Any-shape fused quantization; returns integer codes of x.shape."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = _q.quantize_2d(x2, mn, mx, bits=bits, interpret=interpret)
    return out.reshape(shape)


def dequantize(y, mn, mx, *, bits=8, out_dtype=jnp.float32, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    shape = y.shape
    y2 = y.reshape(-1, shape[-1])
    out = _q.dequantize_2d(y2, mn, mx, bits=bits, out_dtype=out_dtype,
                           interpret=interpret)
    return out.reshape(shape)


def bottleneck_encode(x, w, mn, mx, *, bits=8, interpret=None):
    """Fused compressor encode. x: (..., d); w: (d, d')."""
    interpret = _interpret_default() if interpret is None else interpret
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    out = _bn.bottleneck_encode(x2, w, mn, mx, bits=bits, interpret=interpret)
    return out.reshape(shape[:-1] + (w.shape[1],))


def decode_attention(q, k, v, pos, idx, *, interpret=None):
    interpret = _interpret_default() if interpret is None else interpret
    return _da.decode_attention(q, k, v, pos, idx, interpret=interpret)


def ssd_intra(xh, dt, la, Bm, Cm, *, interpret=None):
    """Mamba-2 SSD intra-chunk contribution (see kernels/ssd_intra.py)."""
    from repro.kernels import ssd_intra as _ssd
    interpret = _interpret_default() if interpret is None else interpret
    return _ssd.ssd_intra(xh, dt, la, Bm, Cm, interpret=interpret)


def pair_scorer(ue_emb, raw, srv_enc, scorer, *, impl=None, interpret=None):
    """Fused entity route scorer -> (route_logits (N, E), srv_emb (E, S)).

    ``raw`` is the env's kernel-path observation block
    (``MECEnv.observe_entities_raw``: {"d", "work", "active", "geom",
    "consts"}); ``srv_enc``/``scorer`` are the matching subtrees of
    ``nets.init_entity_actor``. ``impl``: "pallas" | "xla" | None
    (autodetect: the Pallas kernel on TPU, the decomposed XLA form
    elsewhere — interpret-mode Pallas is for parity testing, not speed).
    Override with REPRO_PAIR_SCORER_IMPL."""
    from repro.kernels import pair_scorer as _ps
    if impl is None:
        impl = os.environ.get("REPRO_PAIR_SCORER_IMPL") \
            or ("pallas" if jax.default_backend() == "tpu" else "xla")
    args = (ue_emb, raw["d"], raw["work"], raw["active"], raw["geom"],
            raw["consts"], srv_enc["w"], srv_enc["b"],
            scorer[0]["w"], scorer[0]["b"], scorer[1]["w"], scorer[1]["b"])
    if impl == "xla":
        return _ps.pair_scorer_xla(*args)
    if impl != "pallas":
        raise ValueError(f"unknown pair_scorer impl {impl!r}")
    interpret = _interpret_default() if interpret is None else interpret
    return _ps.pair_scorer_pallas(*args, interpret=interpret)
