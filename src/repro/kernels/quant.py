"""Pallas TPU kernels: fused linear min-max quantize / dequantize (Eq. 1-2).

Memory-bound ops: fusing sub/scale/round/cast into one VMEM pass avoids three
HBM round-trips of the f32 intermediate. Tiles are (block_m, block_n) with
block_n a multiple of 128 (lane width); scales live in SMEM-like (1,1) blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, mn_ref, mx_ref, o_ref, *, bits):
    x = x_ref[...].astype(jnp.float32)
    mn = mn_ref[0, 0]
    mx = mx_ref[0, 0]
    levels = float((1 << bits) - 1)
    scale = levels / jnp.maximum(mx - mn, 1e-12)
    y = jnp.clip(jnp.round((x - mn) * scale), 0.0, levels)
    o_ref[...] = y.astype(o_ref.dtype)


def _dequant_kernel(y_ref, mn_ref, mx_ref, o_ref, *, bits):
    y = y_ref[...].astype(jnp.float32)
    mn = mn_ref[0, 0]
    mx = mx_ref[0, 0]
    levels = float((1 << bits) - 1)
    o_ref[...] = (y * ((mx - mn) / levels) + mn).astype(o_ref.dtype)


def _tiles(shape, bm, bn):
    m, n = shape
    return (pl.cdiv(m, bm), pl.cdiv(n, bn))


def quantize_xla(x, mn, mx, *, bits=8):
    """Decomposed-XLA quantize — the kernel's elementwise math in plain
    jnp, the fast path on CPU/GPU hosts (interpret-mode Pallas is for
    parity testing, not speed). Op-for-op identical to ``_quant_kernel``
    so the produced codes are bitwise-equal across impls."""
    levels = float((1 << bits) - 1)
    mn = jnp.asarray(mn, jnp.float32)
    mx = jnp.asarray(mx, jnp.float32)
    scale = levels / jnp.maximum(mx - mn, 1e-12)
    y = jnp.clip(jnp.round((x.astype(jnp.float32) - mn) * scale),
                 0.0, levels)
    return y.astype(jnp.uint8 if bits <= 8 else jnp.uint16)


def dequantize_xla(y, mn, mx, *, bits=8, out_dtype=jnp.float32):
    """Decomposed-XLA dequantize, bitwise-equal to ``_dequant_kernel``."""
    levels = float((1 << bits) - 1)
    mn = jnp.asarray(mn, jnp.float32)
    mx = jnp.asarray(mx, jnp.float32)
    out = y.astype(jnp.float32) * ((mx - mn) / levels) + mn
    return out.astype(out_dtype)


def quantize_2d(x, mn, mx, *, bits=8, block=(256, 512), interpret=True):
    """x: (M, N) float; mn/mx: () scalars. Returns uint8/16 codes (M, N)."""
    m, n = x.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = _tiles((m, n), bm, bn)
    out_dtype = jnp.uint8 if bits <= 8 else jnp.uint16
    scal = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(x, scal(mn), scal(mx))


def dequantize_2d(y, mn, mx, *, bits=8, out_dtype=jnp.float32,
                  block=(256, 512), interpret=True):
    m, n = y.shape
    bm, bn = min(block[0], m), min(block[1], n)
    grid = _tiles((m, n), bm, bn)
    scal = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_dequant_kernel, bits=bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=interpret,
    )(y, scal(mn), scal(mx))
