"""Pallas TPU kernel: fused compressor encode — z = quantize(x @ W_enc).

This is the entire UE-side cost of the paper's compressor for transformer
hidden states: a (T, d) x (d, d') bottleneck matmul (the 1x1 conv) fused
with Eq. 1 quantization so the f32 bottleneck activation never leaves VMEM.

Blocked matmul: grid (M/bm, N/bn, K/bk) with the K dimension innermost
("arbitrary" semantics), f32 accumulation in a VMEM scratch tile, quantize-
and-store on the last K step. Block sizes default to MXU-aligned multiples
of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _kernel(x_ref, w_ref, mn_ref, mx_ref, o_ref, acc_ref, *, bits, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _store():
        mn = mn_ref[0, 0]
        mx = mx_ref[0, 0]
        levels = float((1 << bits) - 1)
        scale = levels / jnp.maximum(mx - mn, 1e-12)
        y = jnp.clip(jnp.round((acc_ref[...] - mn) * scale), 0.0, levels)
        o_ref[...] = y.astype(o_ref.dtype)


def bottleneck_encode(x, w, mn, mx, *, bits=8, block=(256, 128, 512),
                      interpret=True):
    """x: (T, d); w: (d, d'); mn/mx: calibrated quantization range.
    Returns uint8 codes (T, d')."""
    t, d = x.shape
    dp = w.shape[1]
    bm = min(block[0], t)
    bn = min(block[1], dp)
    bk = min(block[2], d)
    grid = (pl.cdiv(t, bm), pl.cdiv(dp, bn), pl.cdiv(d, bk))
    scal = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((t, dp), jnp.uint8 if bits <= 8
                                       else jnp.uint16),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w, scal(mn), scal(mx))
