"""Fused (UE, server) pair-scorer kernel for the entity route policy.

The entity policy's route head (``nets.entity_trunk``) scores every
(UE, server) pair with one shared MLP over ``[ue_embed ‖ server_embed ‖
edge_feats]``. The default XLA path materializes the (N, E, 3) edge
tensor inside ``MECEnv.observe_entities`` and the (N, E, 128+S+3) pair
concat inside the net — at N=1024 those intermediates dominate the
scorer's footprint. This kernel fuses the whole chain:

  * the per-(server, channel) interference/occupancy reduction
    ``per_slot = active.sum() / (E * C)`` (the one fleet-global scalar
    the server rows carry),
  * the server rows + single-layer server embedding,
  * the (N, E, 3) edge-feature build — pairwise distance, clean-channel
    rate proxy, and mean edge-service seconds — which never exists in
    memory: each (block_n, 1) column is produced and consumed in
    registers/VMEM,
  * the pair MLP, with the first layer DECOMPOSED by input block:
    ``tanh(ue @ W1u + srv_e @ W1s + edge_e @ W1e + b1)`` — the ue term
    is computed once per UE block instead of once per (UE, server) pair,

emitting (N, E) route logits and the (E, S) server embeddings directly.

All physics constants arrive through an 8-vector ``consts`` built by the
env (``MECEnv._scorer_consts``) so this module depends on nothing but
pallas:

  [pathloss, p_max, sigma_mean, omega_mean / RATE_NORM, t0,
   E * n_channels, DIST_NORM, 1 / EDGE_SLOW_NORM]

``pair_scorer_xla`` is the same decomposed computation expressed in
plain jnp — the fast path on CPU/GPU hosts (and the thing the bench
races against ``ref.pair_scorer_ref``'s naive materialized build). The
Pallas kernel runs compiled on TPU and in interpret mode elsewhere. Both
match ``kernels.ref.pair_scorer_ref`` to fp32 tolerance; ``active``
feeds ONLY the occupancy reduction (the default path scores inactive
rows too and masks at the action level), so churn parity is exact by
construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._compat import CompilerParams as _CompilerParams

# consts-vector layout (see module docstring / MECEnv._scorer_consts)
C_PATHLOSS, C_PMAX, C_SIGMA, C_RATE_SCALE = 0, 1, 2, 3
C_T0, C_SLOT_DIV, C_DIST_NORM, C_SLOW_INV = 4, 5, 6, 7
N_CONSTS = 8


def _edge_cols(d, work, g0, g1, g2, consts):
    """The three edge-feature columns for one server, from (bn, 1)
    distance/work columns and the server's geometry scalars. Mirrors
    ``observe_entities``' (N, E, 3) build column-by-column."""
    dist = d * g0
    gain = jnp.power(jnp.maximum(dist, 1.0), -consts[C_PATHLOSS])
    rate = g1 * consts[C_RATE_SCALE] \
        * jnp.log2(1.0 + consts[C_PMAX] * gain / consts[C_SIGMA])
    te = work * g2 / consts[C_T0]
    return dist / consts[C_DIST_NORM], rate, te


def _srv_row(g0, g1, g2, per_slot, consts):
    """One server's raw entity row [dist, bw, slowness/NORM, per_slot]."""
    return jnp.stack([g0, g1, g2 * consts[C_SLOW_INV],
                      per_slot]).reshape(1, 4)


def _scorer_kernel(consts_ref, geom_ref, act_ref, ue_ref, d_ref, work_ref,
                   wsrv_ref, bsrv_ref, w1_ref, b1_ref, w2_ref, b2_ref,
                   logits_ref, srv_ref, *, n_srv, d_ue, s_dim):
    consts = consts_ref[0, :]
    # fused per-(server, channel) occupancy reduction over the FULL fleet
    per_slot = jnp.sum(act_ref[0, :]) / consts[C_SLOT_DIV]
    ue = ue_ref[...]                                    # (bn, d_ue)
    d = d_ref[...]                                      # (bn, 1)
    work = work_ref[...]                                # (bn, 1)
    w1 = w1_ref[...]                                    # (d_ue+S+3, 48)
    b1 = b1_ref[...]                                    # (1, 48)
    # the ue block of the decomposed first layer: once per block, not
    # once per (UE, server) pair
    ue_h = jnp.dot(ue, w1[:d_ue, :],
                   preferred_element_type=jnp.float32)  # (bn, 48)
    for e in range(n_srv):
        g0 = geom_ref[e, 0]
        g1 = geom_ref[e, 1]
        g2 = geom_ref[e, 2]
        semb = jnp.tanh(
            jnp.dot(_srv_row(g0, g1, g2, per_slot, consts), wsrv_ref[...],
                    preferred_element_type=jnp.float32)
            + bsrv_ref[...])                            # (1, S)
        srv_ref[e, :] = semb[0]
        dist_c, rate_c, te_c = _edge_cols(d, work, g0, g1, g2, consts)
        edge = jnp.concatenate([dist_c, rate_c, te_c], axis=1)  # (bn, 3)
        h = jnp.tanh(
            ue_h
            + jnp.dot(semb, w1[d_ue:d_ue + s_dim, :],
                      preferred_element_type=jnp.float32)
            + jnp.dot(edge, w1[d_ue + s_dim:, :],
                      preferred_element_type=jnp.float32)
            + b1)                                       # (bn, 48)
        logit = jnp.dot(h, w2_ref[...],
                        preferred_element_type=jnp.float32)
        logits_ref[:, e] = logit[:, 0] + b2_ref[0, 0]


def pair_scorer_pallas(ue_emb, d, work, active, geom, consts,
                       w_srv, b_srv, w1, b1, w2, b2, *,
                       block_n=256, interpret=True):
    """Fused pair scorer -> (route_logits (N, E), srv_emb (E, S)).

    ue_emb: (N, d_ue) tanh'd UE embeddings; d/work/active: (N,) raw
    per-UE vectors; geom: (E, 3) live pool geometry; consts: (8,) physics
    constants (layout above); the rest are the ``srv_enc``/``scorer``
    parameter arrays from ``nets.init_entity_actor``.
    """
    f32 = jnp.float32
    n, d_ue = ue_emb.shape
    n_srv = int(geom.shape[0])
    s_dim = int(w_srv.shape[1])
    bn = max(1, min(block_n, n))
    grid = (pl.cdiv(n, bn),)
    kernel = functools.partial(_scorer_kernel, n_srv=n_srv, d_ue=d_ue,
                               s_dim=s_dim)
    full = lambda shape: pl.BlockSpec(shape, lambda i: (0, 0))
    row = lambda width: pl.BlockSpec((bn, width), lambda i: (i, 0))
    logits, srv = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            full((1, N_CONSTS)),                        # consts
            full((n_srv, 3)),                           # geom
            full((1, n)),                               # active (full fleet)
            row(d_ue),                                  # ue_emb
            row(1),                                     # d
            row(1),                                     # work
            full((4, s_dim)),                           # w_srv
            full((1, s_dim)),                           # b_srv
            full((d_ue + s_dim + 3, w1.shape[1])),      # w1
            full((1, w1.shape[1])),                     # b1
            full((w2.shape[0], 1)),                     # w2
            full((1, 1)),                               # b2
        ],
        out_specs=(row(n_srv), full((n_srv, s_dim))),
        out_shape=(jax.ShapeDtypeStruct((n, n_srv), f32),
                   jax.ShapeDtypeStruct((n_srv, s_dim), f32)),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(consts.astype(f32).reshape(1, N_CONSTS), geom.astype(f32),
      active.astype(f32).reshape(1, n), ue_emb.astype(f32),
      d.astype(f32).reshape(n, 1), work.astype(f32).reshape(n, 1),
      w_srv.astype(f32), b_srv.astype(f32).reshape(1, s_dim),
      w1.astype(f32), b1.astype(f32).reshape(1, -1),
      w2.astype(f32), b2.astype(f32).reshape(1, 1))
    return logits, srv


def pair_scorer_xla(ue_emb, d, work, active, geom, consts,
                    w_srv, b_srv, w1, b1, w2, b2):
    """The decomposed pair scorer in plain jnp — same math as the Pallas
    kernel, vectorized over servers. Never materializes the (N, E,
    d_ue+S+3) pair concat the naive reference builds: the first scorer
    layer is split by input block so the dominant ue @ W1u product is
    (N, d_ue) @ (d_ue, 48) once, not per server."""
    f32 = jnp.float32
    ue_emb = ue_emb.astype(f32)
    d = d.astype(f32)
    work = work.astype(f32)
    active = active.astype(f32)
    geom = geom.astype(f32)
    consts = consts.astype(f32)
    d_ue = ue_emb.shape[1]
    s_dim = w_srv.shape[1]
    per_slot = active.sum() / consts[C_SLOT_DIV]
    srv_rows = jnp.concatenate([
        geom * jnp.stack([jnp.float32(1.0), jnp.float32(1.0),
                          consts[C_SLOW_INV]]),
        jnp.broadcast_to(per_slot, (geom.shape[0],))[:, None],
    ], axis=1)
    srv = jnp.tanh(srv_rows @ w_srv + b_srv)                   # (E, S)
    dist = d[:, None] * geom[None, :, 0]                       # (N, E)
    gain = jnp.power(jnp.maximum(dist, 1.0), -consts[C_PATHLOSS])
    rate = (geom[:, 1] * consts[C_RATE_SCALE])[None, :] \
        * jnp.log2(1.0 + consts[C_PMAX] * gain / consts[C_SIGMA])
    te = work[:, None] * geom[None, :, 2] / consts[C_T0]
    edge = jnp.stack([dist / consts[C_DIST_NORM], rate, te], axis=-1)
    h = jnp.tanh((ue_emb @ w1[:d_ue])[:, None, :]
                 + (srv @ w1[d_ue:d_ue + s_dim])[None, :, :]
                 + edge @ w1[d_ue + s_dim:]
                 + b1)                                         # (N, E, 48)
    logits = (h @ w2 + b2)[..., 0]                             # (N, E)
    return logits, srv
