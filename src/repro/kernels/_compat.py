"""Pallas-TPU API compatibility: jax renamed ``TPUCompilerParams`` to
``CompilerParams``; resolve whichever this jax version provides."""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
