# The paper's primary contribution: DNN decoupling + intermediate feature
# compression (autoencoder + quantization) + the overhead/split model that
# feeds the MAHPPO scheduler (repro.rl) through the MEC env (repro.env).
from repro.core.compressor import (compression_rate, dequantize, quantize)
from repro.core.split import SplitPlan, split_table
