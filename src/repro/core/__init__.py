# The paper's primary contribution: DNN decoupling + intermediate feature
# compression (autoencoder + quantization) + the overhead/split model that
# feeds the MAHPPO scheduler (repro.rl) through the MEC env (repro.env).
from repro.core.compressor import (compression_rate, dequantize, quantize)
from repro.core.fleets import make_mixed_fleet
from repro.core.split import (FleetPlan, SplitPlan, build_fleet,
                              homogeneous_fleet, split_table)
