"""The paper's CNN backbones (ResNet18 / VGG11 / MobileNetV2) in pure JAX,
organized as *modules* separated by the paper's partitioning points, with an
analytic per-module FLOPs/bytes walker used by the overhead model (Sec. 3.4
of the paper measures these on a Jetson Nano; we derive them from the same
module granularity — see core/overhead.py).

BatchNorm uses batch statistics (train-mode) throughout; running-stat
bookkeeping is irrelevant to the compression/scheduling experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ------------------------------------------------------------- primitives
# layer spec: ("conv", cin, cout, k, stride, pad) | ("dw", ch, k, stride)
# ("bn", ch) | ("relu",) | ("maxpool", k, s) | ("avgpool",) | ("fc", cin, cout)
# ("add", skip_marker)  -- handled inside blocks


def _conv_init(key, cin, cout, k):
    fan = cin * k * k
    w = jax.random.normal(key, (cout, cin, k, k)) * np.sqrt(2.0 / fan)
    return {"w": w}


def _conv(p, x, stride, pad, groups=1):
    return jax.lax.conv_general_dilated(
        x, p["w"], (stride, stride), [(pad, pad), (pad, pad)],
        feature_group_count=groups,
        dimension_numbers=("NCHW", "OIHW", "NCHW"))


def _bn_init(ch):
    return {"scale": jnp.ones((ch,)), "bias": jnp.zeros((ch,))}


def _bn(p, x, eps=1e-5):
    mu = x.mean(axis=(0, 2, 3), keepdims=True)
    var = x.var(axis=(0, 2, 3), keepdims=True)
    xn = (x - mu) * jax.lax.rsqrt(var + eps)
    return xn * p["scale"][None, :, None, None] + p["bias"][None, :, None, None]


# ------------------------------------------------------------- model defs
@dataclasses.dataclass
class CNNModel:
    name: str
    init: Callable                  # key -> params (list per module)
    run_module: Callable            # (params_i, i, x) -> x
    n_modules: int
    split_after: Tuple[int, ...]    # paper's 4 partitioning points (module idx)
    feature_shapes: Callable        # in_size -> list of (C,H,W) after each module
    module_flops: Callable          # in_size -> list of flops per module


# ------------------------------------------------------------------ resnet18
def _basic_block_init(key, cin, cout, stride):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"c1": _conv_init(k1, cin, cout, 3), "b1": _bn_init(cout),
         "c2": _conv_init(k2, cout, cout, 3), "b2": _bn_init(cout)}
    if stride != 1 or cin != cout:
        p["cd"] = _conv_init(k3, cin, cout, 1)
        p["bd"] = _bn_init(cout)
    return p


def _basic_block(p, x, stride):
    h = jax.nn.relu(_bn(p["b1"], _conv(p["c1"], x, stride, 1)))
    h = _bn(p["b2"], _conv(p["c2"], h, 1, 1))
    sc = x if "cd" not in p else _bn(p["bd"], _conv(p["cd"], x, stride, 0))
    return jax.nn.relu(h + sc)


def make_resnet18(num_classes=101, width=1.0):
    chs = [int(c * width) for c in (64, 64, 128, 256, 512)]

    def init(key):
        ks = jax.random.split(key, 12)
        mods = []
        mods.append({"c": _conv_init(ks[0], 3, chs[0], 7), "b": _bn_init(chs[0])})
        cin = chs[0]
        ki = 1
        for si, cout in enumerate(chs[1:]):
            blocks = []
            for bi in range(2):
                s = 2 if (si > 0 and bi == 0) else 1
                blocks.append(_basic_block_init(ks[ki], cin, cout, s))
                ki += 1
                cin = cout
            mods.append(blocks)
        wk = jax.random.split(ks[ki], 2)[0]
        mods.append({"w": jax.random.normal(wk, (cin, num_classes)) * 0.01,
                     "b": jnp.zeros((num_classes,))})
        return mods

    def run_module(p, i, x):
        if i == 0:
            x = jax.nn.relu(_bn(p["b"], _conv(p["c"], x, 2, 3)))
            return jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max, (1, 1, 3, 3), (1, 1, 2, 2),
                [(0, 0), (0, 0), (1, 1), (1, 1)])
        if i == 5:
            x = x.mean(axis=(2, 3))
            return x @ p["w"] + p["b"]
        for bi, bp in enumerate(p):
            s = 2 if (i > 1 and bi == 0) else 1
            x = _basic_block(bp, x, s)
        return x

    def feature_shapes(in_size):
        s = in_size // 4
        shapes = [(chs[0], s, s)]
        for si, c in enumerate(chs[1:]):
            if si > 0:
                s = (s + 1) // 2
            shapes.append((c, s, s))
        shapes.append((num_classes,))
        return shapes

    def module_flops(in_size):
        fl = []
        s = in_size // 2
        fl.append(2 * 3 * chs[0] * 49 * s * s)          # stem conv
        s = in_size // 4
        cin = chs[0]
        for si, c in enumerate(chs[1:]):
            if si > 0:
                s = (s + 1) // 2
            f = 2 * cin * c * 9 * s * s + 2 * c * c * 9 * s * s
            if si > 0:
                f += 2 * cin * c * s * s
            f += 2 * c * c * 9 * s * s * 2 + 2 * c * c * 9 * s * s  # 2nd block
            fl.append(f)
            cin = c
        fl.append(2 * cin * num_classes)
        return fl

    return CNNModel("resnet18", init, run_module, 6, (1, 2, 3, 4),
                    feature_shapes, module_flops)


# -------------------------------------------------------------------- vgg11
_VGG = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]


def make_vgg11(num_classes=101, width=1.0):
    cfgs = [int(c * width) if c != "M" else c for c in _VGG]
    # modules end after each of the first 4 maxpools; last module = rest+head
    bounds = [i + 1 for i, c in enumerate(cfgs) if c == "M"]
    mod_slices = ([slice(0, bounds[0])] +
                  [slice(bounds[i], bounds[i + 1]) for i in range(3)] +
                  [slice(bounds[3], len(cfgs))])

    def init(key):
        ks = jax.random.split(key, len(cfgs) + 1)
        mods = []
        cin = 3
        for sl in mod_slices:
            layers = []
            for j, c in enumerate(cfgs[sl]):
                if c == "M":
                    layers.append(("M", None))
                else:
                    layers.append(("C", {"c": _conv_init(ks[sl.start + j], cin, c, 3),
                                         "b": _bn_init(c)}))
                    cin = c
            mods.append(layers)
        mods.append({"w": jax.random.normal(ks[-1], (cin, num_classes)) * 0.01,
                     "b": jnp.zeros((num_classes,))})
        return mods

    def run_module(p, i, x):
        if i == 5:
            x = x.mean(axis=(2, 3))
            return x @ p["w"] + p["b"]
        for kind, lp in p:
            if kind == "M":
                x = jax.lax.reduce_window(
                    x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2),
                    [(0, 0)] * 4)
            else:
                x = jax.nn.relu(_bn(lp["b"], _conv(lp["c"], x, 1, 1)))
        return x

    def feature_shapes(in_size):
        shapes = []
        s, cin = in_size, 3
        for sl in mod_slices:
            for c in cfgs[sl]:
                if c == "M":
                    s //= 2
                else:
                    cin = c
            shapes.append((cin, s, s))
        shapes.append((num_classes,))
        return shapes

    def module_flops(in_size):
        fl = []
        s, cin = in_size, 3
        for sl in mod_slices:
            f = 0
            for c in cfgs[sl]:
                if c == "M":
                    s //= 2
                else:
                    f += 2 * cin * c * 9 * s * s
                    cin = c
            fl.append(f)
        fl.append(2 * cin * num_classes)
        return fl

    return CNNModel("vgg11", init, run_module, 6, (1, 2, 3, 4),
                    feature_shapes, module_flops)


# -------------------------------------------------------------- mobilenetv2
_MBV2 = [(1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
         (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]


def _inv_res_init(key, cin, cout, t, stride):
    k1, k2, k3 = jax.random.split(key, 3)
    mid = cin * t
    p = {}
    if t != 1:
        p["e"] = _conv_init(k1, cin, mid, 1)
        p["be"] = _bn_init(mid)
    p["d"] = {"w": jax.random.normal(k2, (mid, 1, 3, 3)) * np.sqrt(2.0 / 9)}
    p["bd"] = _bn_init(mid)
    p["p"] = _conv_init(k3, mid, cout, 1)
    p["bp"] = _bn_init(cout)
    return p


def _inv_res(p, x, cin, cout, t, stride):
    h = x
    if t != 1:
        h = jax.nn.relu6(_bn(p["be"], _conv(p["e"], h, 1, 0)))
    mid = cin * t
    h = jax.nn.relu6(_bn(p["bd"], _conv(p["d"], h, stride, 1, groups=mid)))
    h = _bn(p["bp"], _conv(p["p"], h, 1, 0))
    if stride == 1 and cin == cout:
        h = h + x
    return h


def make_mobilenetv2(num_classes=101, width=1.0):
    stages = [(t, int(c * width), n, s) for (t, c, n, s) in _MBV2]
    c_stem = int(32 * width)
    c_head = int(1280 * width)
    # modules: stem+stage1 | stage2 | stage3 | stage4+5 | stage6+7 | head
    groups = [[0], [1], [2], [3, 4], [5, 6]]

    def init(key):
        nblocks = sum(n for (_, _, n, _) in stages)
        ks = jax.random.split(key, nblocks + 3)
        mods = []
        cin = c_stem
        ki = 0
        first = {"c": _conv_init(ks[-1], 3, c_stem, 3), "b": _bn_init(c_stem)}
        for gi, g in enumerate(groups):
            blocks = [] if gi else [("stem", first)]
            for si in g:
                t, c, n, s = stages[si]
                for bi in range(n):
                    blocks.append((("blk", cin, c, t, s if bi == 0 else 1),
                                   _inv_res_init(ks[ki], cin, c, t,
                                                 s if bi == 0 else 1)))
                    ki += 1
                    cin = c
            mods.append(blocks)
        mods.append({"c": _conv_init(ks[-2], cin, c_head, 1),
                     "b": _bn_init(c_head),
                     "w": jax.random.normal(ks[-3], (c_head, num_classes)) * 0.01,
                     "bias": jnp.zeros((num_classes,))})
        return mods

    def run_module(p, i, x):
        if i == 5:
            x = jax.nn.relu6(_bn(p["b"], _conv(p["c"], x, 1, 0)))
            x = x.mean(axis=(2, 3))
            return x @ p["w"] + p["bias"]
        for item in p:
            if item[0] == "stem":
                x = jax.nn.relu6(_bn(item[1]["b"], _conv(item[1]["c"], x, 2, 1)))
            else:
                (_, cin, c, t, s), bp = item
                x = _inv_res(bp, x, cin, c, t, s)
        return x

    def feature_shapes(in_size):
        shapes = []
        s = in_size // 2
        cin = c_stem
        for g in groups:
            for si in g:
                t, c, n, st = stages[si]
                if st == 2:
                    s = (s + 1) // 2
                cin = c
            shapes.append((cin, s, s))
        shapes.append((num_classes,))
        return shapes

    def module_flops(in_size):
        fl = []
        s = in_size // 2
        f0 = 2 * 3 * c_stem * 9 * s * s
        cin = c_stem
        for gi, g in enumerate(groups):
            f = f0 if gi == 0 else 0
            f0 = 0
            for si in g:
                t, c, n, st = stages[si]
                for bi in range(n):
                    stride = st if bi == 0 else 1
                    mid = cin * t
                    if st == 2 and bi == 0:
                        s_out = (s + 1) // 2
                    else:
                        s_out = s
                    if t != 1:
                        f += 2 * cin * mid * s * s
                    f += 2 * mid * 9 * s_out * s_out
                    f += 2 * mid * c * s_out * s_out
                    s = s_out
                    cin = c
            fl.append(f)
        fl.append(2 * cin * c_head * s * s + 2 * c_head * num_classes)
        return fl

    return CNNModel("mobilenetv2", init, run_module, 6, (1, 2, 3, 4),
                    feature_shapes, module_flops)


CNN_FACTORY = {"resnet18": make_resnet18, "vgg11": make_vgg11,
               "mobilenetv2": make_mobilenetv2}


def forward(model: CNNModel, params, x, upto=None):
    """Run modules [0, upto) (None = all). x: (B, 3, H, W)."""
    n = model.n_modules if upto is None else upto
    for i in range(n):
        x = model.run_module(params[i], i, x)
    return x


def forward_from(model: CNNModel, params, feat, start):
    x = feat
    for i in range(start, model.n_modules):
        x = model.run_module(params[i], i, x)
    return x
