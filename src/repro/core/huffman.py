"""Actual Huffman codec for JALAD's entropy-coding stage.

The scheduling experiments only need coded *sizes* (core/jalad.py estimates
them information-theoretically); this module provides the real codec so that
estimate is validated end-to-end: canonical Huffman over the 8-bit quantized
feature codes, with encode -> bitstream -> decode round-trip. Pure python/
numpy (the coder runs on the UE CPU in the paper's system; it is not a TPU
kernel).
"""
from __future__ import annotations

import heapq
from collections import Counter
from typing import Dict, List, Tuple

import numpy as np


def build_code(symbols: np.ndarray) -> Dict[int, str]:
    """Canonical Huffman code lengths from symbol frequencies."""
    freq = Counter(symbols.tolist())
    if not freq:
        return {}
    if len(freq) == 1:
        (s, _), = freq.items()
        return {s: "0"}
    heap = [(n, i, sym) for i, (sym, n) in enumerate(freq.items())]
    heapq.heapify(heap)
    # (count, tiebreak, payload) where payload is a symbol or a merged node
    nodes = {i: (sym, None, None) for i, (_, i, sym) in enumerate(heap)}
    next_id = len(nodes)
    heap = [(n, i) for (n, i, _) in heap]
    heapq.heapify(heap)
    while len(heap) > 1:
        n1, i1 = heapq.heappop(heap)
        n2, i2 = heapq.heappop(heap)
        nodes[next_id] = (None, i1, i2)
        heapq.heappush(heap, (n1 + n2, next_id))
        next_id += 1
    root = heap[0][1]
    code: Dict[int, str] = {}

    def walk(i, prefix):
        sym, l, r = nodes[i]
        if sym is not None:
            code[sym] = prefix or "0"
        else:
            walk(l, prefix + "0")
            walk(r, prefix + "1")

    walk(root, "")
    return code


def encode(symbols: np.ndarray) -> Tuple[bytes, Dict[int, str], int]:
    """Returns (bitstream bytes, code table, n_symbols)."""
    code = build_code(symbols)
    if not code:
        return b"", code, 0
    bits = "".join(code[s] for s in symbols.tolist())
    pad = (-len(bits)) % 8
    bits += "0" * pad
    by = bytes(int(bits[i:i + 8], 2) for i in range(0, len(bits), 8))
    return by, code, len(symbols)


def decode(stream: bytes, code: Dict[int, str], n: int) -> np.ndarray:
    if n == 0:
        return np.empty(0, np.int64)
    if not code:
        raise ValueError("empty code table with n > 0")
    rev = {v: k for k, v in code.items()}
    maxlen = max(len(v) for v in code.values())
    bits = "".join(f"{b:08b}" for b in stream)
    out = np.empty(n, np.int64)
    pos = 0
    cur = ""
    for i in range(n):
        while True:
            cur += bits[pos]
            pos += 1
            if cur in rev:
                out[i] = rev[cur]
                cur = ""
                break
            if len(cur) > maxlen:
                raise ValueError("corrupt stream")
    return out


def coded_size_bits(symbols: np.ndarray) -> int:
    """Exact Huffman-coded payload size in bits (excluding the table)."""
    code = build_code(symbols)
    freq = Counter(symbols.tolist())
    return sum(len(code[s]) * n for s, n in freq.items())
