"""JALAD baseline [Li et al., ICPADS'18]: 8-bit quantization + entropy coding.

Only the *compressed size* enters the scheduling problem, so the entropy
coder is modelled information-theoretically: the coded size of the quantized
feature is its empirical byte entropy (the expected Huffman/arithmetic code
length). This matches how the paper uses JALAD (as a latency/size baseline).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compressor import dequantize, quantize


def byte_entropy_bits(codes, bits=8):
    """Empirical entropy (bits/symbol) of quantized codes."""
    n_sym = 1 << bits
    hist = jnp.zeros((n_sym,), jnp.float32).at[codes.reshape(-1)].add(1.0)
    p = hist / jnp.maximum(hist.sum(), 1.0)
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-30)), 0.0))


def jalad_compress_size_bits(feat, bits=8):
    """Estimated coded size (bits) of a feature map, plus the rate vs f32."""
    codes, mn, mx = quantize(feat, bits)
    h = byte_entropy_bits(codes, bits)
    n = feat.size
    size_bits = h * n
    rate = 32.0 / jnp.maximum(h, 1e-6)
    return size_bits, rate


def jalad_roundtrip(feat, bits=8):
    codes, mn, mx = quantize(feat, bits)
    return dequantize(codes, bits, mn, mx).astype(feat.dtype)


# entropy-coding throughput on the UE (symbols/s) — JALAD's coder runs on the
# CPU; this constant drives its (large) compression latency in the overhead
# model, mirroring the paper's Fig. 7 observation.
ENTROPY_CODER_SYMBOLS_PER_S = 2.0e7
