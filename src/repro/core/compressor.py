"""Lightweight autoencoder-based intermediate feature compression (paper §2).

Encoder/decoder are single 1x1 convolutions over the channel dim — for CNN
features (B, C, H, W) that is an einsum over C; for transformer hidden states
(B, S, d) it is a d -> d' matmul (a 1x1 conv over channels IS a matmul, which
on TPU maps straight onto the MXU — see kernels/bottleneck.py for the fused
Pallas version).

Quantization: linear min-max to c_q bits (Eq. 1-2). Overall rate
R = (ch * 32) / (ch' * c_q) (Eq. 3).

Training (paper §2.4): stage 1 optimizes the AE with the backbone frozen on
L2(feature, reconstruction) + xi * CE(prediction); stage 2 fine-tunes
everything with a small LR.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import cnn as cnn_lib
from repro.optim import adamw_init, adamw_update


# ------------------------------------------------------------ quantization
def quantize(x, bits, minv=None, maxv=None):
    """Eq. 1. Returns (codes, minv, maxv); codes are integers in [0, 2^b-1],
    stored in the smallest sufficient int dtype."""
    minv = jnp.min(x) if minv is None else minv
    maxv = jnp.max(x) if maxv is None else maxv
    levels = (1 << bits) - 1
    scale = levels / jnp.maximum(maxv - minv, 1e-12)
    y = jnp.round((x - minv) * scale)
    y = jnp.clip(y, 0, levels)
    dt = jnp.uint8 if bits <= 8 else jnp.uint16
    return y.astype(dt), minv, maxv


def dequantize(y, bits, minv, maxv):
    """Eq. 2."""
    levels = (1 << bits) - 1
    return y.astype(jnp.float32) * (maxv - minv) / levels + minv


def compression_rate(ch, ch_prime, bits):
    """Eq. 3: R = R_c * R_q."""
    return (ch * 32.0) / (ch_prime * bits)


# --------------------------------------------------------------- AE params
def init_autoencoder(key, ch, ch_prime):
    k1, k2 = jax.random.split(key)
    s = 1.0 / jnp.sqrt(ch)
    return {"enc": jax.random.normal(k1, (ch, ch_prime)) * s,
            "dec": jax.random.normal(k2, (ch_prime, ch)) * (1.0 / jnp.sqrt(ch_prime))}


def pca_init_autoencoder(feats, ch_prime):
    """Closed-form optimal LINEAR autoencoder: top principal components of
    the boundary features (beyond-paper: the paper random-inits and trains;
    PCA init converges in a fraction of the steps). feats: (B, C, H, W)
    CNN features (channels at axis 1, samples over B*H*W) or (..., C)
    channel-last (samples over all leading axes)."""
    if feats.ndim == 4:  # (B, C, H, W) -> samples over B*H*W
        f = jnp.moveaxis(feats, 1, -1).reshape(-1, feats.shape[1])
    else:                # (..., C) channel-last
        f = feats.reshape(-1, feats.shape[-1])
    mu = f.mean(0)
    _, _, vt = jnp.linalg.svd(f - mu, full_matrices=False)
    pcs = vt[:ch_prime].T
    return {"enc": pcs, "dec": pcs.T}


def encode(ae, feat):
    """feat: (B, C, H, W) or (B, S, C) -> bottleneck along channel dim."""
    if feat.ndim == 4:
        return jnp.einsum("bchw,cd->bdhw", feat, ae["enc"])
    return feat @ ae["enc"]


def decode(ae, z):
    if z.ndim == 4:
        return jnp.einsum("bdhw,dc->bchw", z, ae["dec"])
    return z @ ae["dec"]


def roundtrip(ae, feat, bits=None):
    """encode -> (optional quantize/dequantize) -> decode."""
    z = encode(ae, feat)
    if bits is not None:
        q, mn, mx = quantize(z, bits)
        z = dequantize(q, bits, mn, mx).astype(feat.dtype)
    return decode(ae, z)


# ------------------------------------------------- two-stage training (CNN)
def ae_loss(ae, backbone_params, model, split_module, x, labels, xi=0.1,
            bits=None):
    """Paper Eq. 4 for a CNN backbone split after module `split_module`."""
    feat = cnn_lib.forward(model, backbone_params, x, upto=split_module + 1)
    feat_hat = roundtrip(ae, feat, bits)
    logits = cnn_lib.forward_from(model, backbone_params, feat_hat,
                                  split_module + 1)
    l2 = jnp.sqrt(jnp.sum(jnp.square(feat - feat_hat)) + 1e-12) / x.shape[0]
    lse = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    ce = jnp.mean(lse - tgt)
    return l2 + xi * ce, (l2, ce)


def train_autoencoder(key, model, backbone_params, split_module, data_iter,
                      *, ch, ch_prime, steps=100, lr=1e-3, xi=0.1,
                      finetune_steps=0, ft_lr=1e-4, pca_init=True):
    """Stage 1: AE only, frozen backbone. Stage 2 (finetune_steps>0): joint.
    data_iter yields (x, labels). Returns (ae, backbone_params, logs)."""
    if pca_init:
        x0, _ = next(data_iter)
        feats = cnn_lib.forward(model, backbone_params, x0,
                                upto=split_module + 1)
        ae = pca_init_autoencoder(feats, ch_prime)
    else:
        ae = init_autoencoder(key, ch, ch_prime)
    opt = adamw_init(ae)
    logs = []

    @jax.jit
    def step1(ae, opt, x, y):
        (loss, (l2, ce)), g = jax.value_and_grad(
            ae_loss, has_aux=True)(ae, backbone_params, model, split_module,
                                   x, y, xi)
        ae, opt = adamw_update(g, opt, ae, lr, weight_decay=0.0)
        return ae, opt, loss, l2, ce

    for _ in range(steps):
        x, y = next(data_iter)
        ae, opt, loss, l2, ce = step1(ae, opt, x, y)
        logs.append({"stage": 1, "loss": float(loss), "l2": float(l2),
                     "ce": float(ce)})

    if finetune_steps:
        joint = {"ae": ae, "bb": backbone_params}
        jopt = adamw_init(joint)

        def jloss(j, x, y):
            return ae_loss(j["ae"], j["bb"], model, split_module, x, y, xi)

        @jax.jit
        def step2(j, o, x, y):
            (loss, (l2, ce)), g = jax.value_and_grad(jloss, has_aux=True)(j, x, y)
            j, o = adamw_update(g, o, j, ft_lr, weight_decay=0.0)
            return j, o, loss

        for _ in range(finetune_steps):
            x, y = next(data_iter)
            joint, jopt, loss = step2(joint, jopt, x, y)
            logs.append({"stage": 2, "loss": float(loss)})
        ae, backbone_params = joint["ae"], joint["bb"]

    return ae, backbone_params, logs


def measure_rate_distortion(model, backbone_params, data_iter_fn,
                            eval_batch_fn, *, points=None, ratios=(4, 8, 16),
                            bits=8, steps=30, lr=3e-3, xi=0.1, acc_drop=0.02,
                            base_acc=None, seed=0):
    """Per-split-point compressor rate-distortion by the paper's Fig. 4
    selection rule: at each candidate point, train an AE per channel-
    reduction ratio and keep the HIGHEST rate whose accuracy stays within
    `acc_drop` of the no-AE baseline; quant-only R = 32/bits (ch' = ch)
    is the fallback when no ratio qualifies.

    data_iter_fn(pi) -> (x, labels) iterator, fresh stream per point;
    eval_batch_fn(pi) -> (x, labels) batch for the accuracy check.
    Returns one row per split point
      {point, module, channels, ch_prime, bits, rate, acc, base_acc}
    consumable directly as measured_cnn_split_table(..., rd=rows)."""
    points = list(model.split_after) if points is None else list(points)
    if base_acc is None:
        accs = []
        for pi in range(len(points)):
            x, y = eval_batch_fn(pi)
            logits = cnn_lib.forward(model, backbone_params, x)
            accs.append(float(jnp.mean((jnp.argmax(logits, -1) == y))))
        base_acc = float(sum(accs) / len(accs))
    rows = []
    for pi, k in enumerate(points):
        x_eval, y_eval = eval_batch_fn(pi)
        ch = int(cnn_lib.forward(model, backbone_params, x_eval[:1],
                                 upto=k + 1).shape[1])
        best = {"ch_prime": ch, "rate": compression_rate(ch, ch, bits),
                "acc": base_acc}
        for rc in ratios:
            chp = max(1, ch // rc)
            ae, _, _ = train_autoencoder(
                jax.random.PRNGKey(seed + pi * 10 + rc), model,
                backbone_params, k, data_iter_fn(pi), ch=ch, ch_prime=chp,
                steps=steps, lr=lr, xi=xi)
            acc = float(accuracy_with_ae(model, backbone_params, ae, k,
                                         x_eval, y_eval, bits=bits))
            rate = compression_rate(ch, chp, bits)
            if acc >= base_acc - acc_drop and rate > best["rate"]:
                best = {"ch_prime": chp, "rate": rate, "acc": acc}
        rows.append({"point": pi + 1, "module": k, "channels": ch,
                     "bits": bits, "base_acc": base_acc, **best})
    return rows


def accuracy_with_ae(model, backbone_params, ae, split_module, x, labels,
                     bits=8):
    feat = cnn_lib.forward(model, backbone_params, x, upto=split_module + 1)
    feat_hat = roundtrip(ae, feat, bits)
    logits = cnn_lib.forward_from(model, backbone_params, feat_hat,
                                  split_module + 1)
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
