"""DNN decoupling: split plans and the per-split overhead tables that define
the RL environment's action space (paper §3.2-3.4).

A split decision b in {0, 1, ..., B+1} means (paper convention):
  b = 0    offload the raw input
  b = k    run modules/layers up to candidate point k on the UE, compress the
           boundary feature with the AE (+quantization), transmit
  b = B+1  full local inference

``split_table`` builds, for a backbone (CNN or assigned transformer arch),
the arrays {t_local, e_local, t_comp, e_comp, f_bits, feasible} the MEC env
consumes. Architecture-family constraints (DESIGN.md §6):
  * MoE archs: a split is feasible only if the UE-side parameter bytes fit
    UE memory (expert banks usually force b=0).
  * VLM: splits below the last cross-attn layer ship the image embeddings
    (compressed at the same rate) alongside the boundary feature.
  * enc-dec: the encoder runs on the UE for any decoder-side split; b=0
    ships the (stub) mel frames.
  * SSM / hybrid: boundary additionally carries the recurrent state.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import overhead as oh
from repro.core.cnn import CNNModel


@dataclasses.dataclass
class SplitPlan:
    name: str
    # candidate boundaries; entry k (1-based) = number of UE-side modules
    points: List[int]
    t_local: np.ndarray          # (B+2,) cumulative UE compute latency
    e_local: np.ndarray
    t_comp: np.ndarray           # compressor latency at each b
    e_comp: np.ndarray
    f_bits: np.ndarray           # offload payload (bits); 0 for b = B+1
    feasible: np.ndarray         # bool (B+2,)

    @property
    def n_actions(self):
        return len(self.f_bits)


def _finalize(name, points, rows, full_bits_zero=True):
    t_l, e_l, t_c, e_c, fb, feas = (np.array([r[i] for r in rows])
                                    for i in range(6))
    return SplitPlan(name, points, t_l, e_l, t_c, e_c, fb,
                     feas.astype(bool))


# --------------------------------------------------------------------- CNN
def cnn_split_table(model: CNNModel, in_size: int, *,
                    dev=oh.JETSON_NANO, ae_ratio=(16, 12, 8, 4),
                    quant_bits=8, batch=1,
                    input_bits_per_px=8) -> SplitPlan:
    """ae_ratio: per-split-point channel-reduction factors R_c. Defaults
    mirror the paper's Fig. 4 (R up to ~128 at early points, decreasing with
    depth: the AE compresses early features best). May be a scalar."""
    flops = model.module_flops(in_size)
    shapes = model.feature_shapes(in_size)
    points = list(model.split_after)
    if not hasattr(ae_ratio, "__len__"):
        ae_ratio = [ae_ratio] * len(points)
    rows = []
    # b = 0: raw input offload
    raw_bits = batch * 3 * in_size * in_size * input_bits_per_px
    rows.append((0.0, 0.0, 0.0, 0.0, raw_bits, True))
    for pi, k in enumerate(points):
        fl = sum(flops[:k + 1]) * batch
        t, e = oh.module_time_energy(fl, fl / 8, dev)
        c, h, w = shapes[k]
        cp = max(1, c // ae_ratio[pi])
        enc_fl = 2 * c * cp * h * w * batch
        tc, ec = oh.module_time_energy(enc_fl, enc_fl / 4, dev)
        bits = batch * cp * h * w * quant_bits
        rows.append((t, e, tc, ec, bits, True))
    fl = sum(flops) * batch
    t, e = oh.module_time_energy(fl, fl / 8, dev)
    rows.append((t, e, 0.0, 0.0, 0.0, True))
    return _finalize(model.name, points, rows)


def cnn_jalad_table(model: CNNModel, in_size: int, *, dev=oh.JETSON_NANO,
                    entropy_bits=5.0, batch=1) -> SplitPlan:
    """JALAD baseline: 8-bit quant + entropy coding; no channel reduction;
    coder latency from symbols/s throughput (the paper's Fig. 7 point that
    entropy coding on large features dominates)."""
    from repro.core.jalad import ENTROPY_CODER_SYMBOLS_PER_S as CPS
    flops = model.module_flops(in_size)
    shapes = model.feature_shapes(in_size)
    points = list(model.split_after)
    rows = []
    raw_bits = batch * 3 * in_size * in_size * 8
    rows.append((0.0, 0.0, 0.0, 0.0, raw_bits, True))
    for k in points:
        fl = sum(flops[:k + 1]) * batch
        t, e = oh.module_time_energy(fl, fl / 8, dev)
        c, h, w = shapes[k]
        n = batch * c * h * w
        tc = n / CPS
        ec = tc * dev.active_power
        rows.append((t, e, tc, ec, n * entropy_bits, True))
    fl = sum(flops) * batch
    t, e = oh.module_time_energy(fl, fl / 8, dev)
    rows.append((t, e, 0.0, 0.0, 0.0, True))
    return _finalize(model.name + "-jalad", points, rows)


# ------------------------------------------------------------- transformers
def transformer_split_table(cfg: ModelConfig, *, seq_len=128,
                            ue_dev=oh.PHONE_NPU, n_points=4,
                            ae_ratio=None, quant_bits=None,
                            batch=1) -> SplitPlan:
    ae_ratio = ae_ratio or cfg.bottleneck_ratio
    quant_bits = quant_bits or cfg.quant_bits
    layers = oh.layer_costs(cfg, seq_len)
    L = len(layers)
    emb = oh.embed_costs(cfg, seq_len)
    btypes = cfg.block_types()
    points = [max(1, round(L * (i + 1) / (n_points + 1)))
              for i in range(n_points)]

    embed_pb = cfg.vocab_size * cfg.d_model * 2
    cum_fl = np.cumsum([l["flops"] for l in layers]) * batch
    cum_pb = np.cumsum([l["param_bytes"] for l in layers])

    # family extras
    last_x = max((i for i, bt in enumerate(btypes) if bt in ("xattn",)),
                 default=-1)
    aux_bits_raw = 0
    if cfg.family == "vlm":
        aux_bits_raw = cfg.n_aux_tokens * cfg.d_model * 16 * batch
    enc_flops = 0
    if cfg.family == "encdec":
        enc_layers = oh.layer_costs(
            cfg.replace(block_pattern=("dense",), n_layers=cfg.encoder.n_layers),
            cfg.encoder.n_frames)
        enc_flops = sum(l["flops"] for l in enc_layers) * batch
        aux_bits_raw = cfg.encoder.n_frames * cfg.d_model * 16 * batch

    rows = []
    # b = 0: raw input (token ids; for audio the stub mel frames)
    if cfg.family == "encdec":
        raw_bits = cfg.encoder.n_frames * 80 * 32 * batch + seq_len * 32 * batch
    elif cfg.family == "vlm":
        # raw pixels for 1600 patches ~ (patch 14x14x3 @8bit)
        raw_bits = cfg.n_aux_tokens * 14 * 14 * 3 * 8 * batch + seq_len * 32 * batch
    else:
        raw_bits = seq_len * 32 * batch
    rows.append((0.0, 0.0, 0.0, 0.0, raw_bits, True))

    d = cfg.d_model
    dprime = max(1, d // ae_ratio)
    rate = (d * 32.0) / (dprime * quant_bits)
    for k in points:
        fl = cum_fl[k - 1] + (enc_flops if cfg.family == "encdec" else 0)
        t, e = oh.module_time_energy(fl, fl / 4, ue_dev)
        enc_fl = 2 * seq_len * d * dprime * batch
        tc, ec = oh.module_time_energy(enc_fl, enc_fl / 4, ue_dev)
        bits = seq_len * dprime * quant_bits * batch
        # NOTE: recurrent/SSM state does NOT cross the boundary — layer i's
        # state is internal to layer i; edge-side layers recompute their own
        # states from the transmitted hidden sequence. Only context extras
        # (image embeds / encoder output) ship.
        if cfg.family == "vlm" and k <= last_x:
            bits += aux_bits_raw * 32 / (16 * rate)   # embeds, AE+quant'ed
        if cfg.family == "encdec":
            bits += cfg.encoder.n_frames * dprime * quant_bits * batch
        ue_pb = embed_pb + cum_pb[k - 1]
        rows.append((t, e, tc, ec, bits, ue_pb <= ue_dev.mem_bytes))
    fl_full = cum_fl[-1] + emb["flops"] * batch \
        + (enc_flops if cfg.family == "encdec" else 0)
    t, e = oh.module_time_energy(fl_full, fl_full / 4, ue_dev)
    total_pb = embed_pb + cum_pb[-1] + (emb["param_bytes"] - embed_pb)
    rows.append((t, e, 0.0, 0.0, 0.0, total_pb <= ue_dev.mem_bytes))
    return _finalize(cfg.name, points, rows)


def split_table(target, **kw) -> SplitPlan:
    """target: CNNModel or ModelConfig."""
    if isinstance(target, CNNModel):
        return cnn_split_table(target, kw.pop("in_size", 224), **kw)
    return transformer_split_table(target, **kw)
