"""DNN decoupling: split plans and the per-split overhead tables that define
the RL environment's action space (paper §3.2-3.4).

A split decision b in {0, 1, ..., B+1} means (paper convention):
  b = 0    offload the raw input
  b = k    run modules/layers up to candidate point k on the UE, compress the
           boundary feature with the AE (+quantization), transmit
  b = B+1  full local inference

``split_table`` builds, for a backbone (CNN or assigned transformer arch),
the arrays {t_local, e_local, t_comp, e_comp, f_bits, feasible} the MEC env
consumes. Architecture-family constraints (DESIGN.md §6):
  * MoE archs: a split is feasible only if the UE-side parameter bytes fit
    UE memory (expert banks usually force b=0).
  * VLM: splits below the last cross-attn layer ship the image embeddings
    (compressed at the same rate) alongside the boundary feature.
  * enc-dec: the encoder runs on the UE for any decoder-side split; b=0
    ships the (stub) mel frames.
  * SSM / hybrid: boundary additionally carries the recurrent state.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import numpy as np

from repro.configs.base import ModelConfig
from repro.core import overhead as oh
from repro.core.cnn import CNNModel


@dataclasses.dataclass
class SplitPlan:
    name: str
    # candidate boundaries; entry k (1-based) = number of UE-side modules
    points: List[int]
    t_local: np.ndarray          # (B+2,) cumulative UE compute latency
    e_local: np.ndarray
    t_comp: np.ndarray           # compressor latency at each b
    e_comp: np.ndarray
    f_bits: np.ndarray           # offload payload (bits); 0 for b = B+1
    feasible: np.ndarray         # bool (B+2,)
    device: Optional[str] = None  # UE device the tables were built for

    @property
    def n_actions(self):
        return len(self.f_bits)


def _finalize(name, points, rows, device=None):
    t_l, e_l, t_c, e_c, fb, feas = (np.array([r[i] for r in rows])
                                    for i in range(6))
    if t_l[0] != 0.0:
        raise ValueError(f"{name}: raw offload (b=0) must cost no UE compute")
    if np.any(np.diff(t_l[1:-1]) < -1e-9):
        raise ValueError(f"{name}: cumulative t_local must be monotone over "
                         f"split points, got {t_l[1:-1]}")
    if fb[-1] != 0.0:
        raise ValueError(f"{name}: full-local (b=B+1) must offload 0 bits")
    return SplitPlan(name, points, t_l, e_l, t_c, e_c, fb,
                     feas.astype(bool), device=device)


# ------------------------------------------------------------------- fleets
@dataclasses.dataclass
class FleetPlan:
    """Per-UE split tables for a heterogeneous fleet, padded to a shared
    action space. Layout of each (B_max+2,) row: index 0 = raw offload,
    indices 1..B = that UE's split points, then infeasible padding, and the
    LAST index is always full-local — so b = n_actions-1 means "run locally"
    for every UE regardless of how many split points its backbone exposes."""
    names: List[str]
    profiles: List[oh.DeviceProfile]
    t_local: np.ndarray          # (N, B_max+2)
    e_local: np.ndarray
    t_comp: np.ndarray
    e_comp: np.ndarray
    f_bits: np.ndarray
    feasible: np.ndarray         # (N, B_max+2) bool; False on padding
    p_compute: np.ndarray        # (N,) W per local compute second

    @property
    def n_ue(self):
        return len(self.names)

    @property
    def n_actions(self):
        return self.t_local.shape[1]


def _pad_row(vals: np.ndarray, width: int, fill=0.0) -> np.ndarray:
    """Pad a (B+2,) table to (width,) keeping the last entry (full-local)
    last; padding goes between the split points and full-local."""
    out = np.full((width,), fill, dtype=np.float64)
    out[: len(vals) - 1] = vals[:-1]
    out[-1] = vals[-1]
    return out


def build_fleet(plans: Sequence[SplitPlan],
                profiles: Optional[Sequence[Union[oh.DeviceProfile,
                                                  oh.DeviceModel]]] = None
                ) -> FleetPlan:
    """Stack an arbitrary mix of SplitPlans (different backbones, different
    B) into per-UE tables. Padded action slots are marked infeasible and cost
    nothing, so a policy that respects the mask never sees them."""
    if not plans:
        raise ValueError("build_fleet needs at least one SplitPlan")
    if profiles is None:
        profiles = [oh.DeviceProfile.from_device(oh.JETSON_NANO)] * len(plans)
    if len(profiles) != len(plans):
        raise ValueError(f"{len(plans)} plans but {len(profiles)} profiles")
    profiles = [p if isinstance(p, oh.DeviceProfile)
                else oh.DeviceProfile.from_device(p) for p in profiles]
    for plan, prof in zip(plans, profiles):
        if plan.device is not None and prof.device.name != plan.device:
            raise ValueError(
                f"plan '{plan.name}' has tables built for {plan.device} but "
                f"its profile is {prof.device.name}; rebuild the split table "
                f"with dev/ue_dev={prof.device.name}")
    width = max(p.n_actions for p in plans)
    stack = {f: np.stack([_pad_row(getattr(p, f), width) for p in plans])
             for f in ("t_local", "e_local", "t_comp", "e_comp", "f_bits")}
    feas = np.zeros((len(plans), width), dtype=bool)
    for i, p in enumerate(plans):
        feas[i, : p.n_actions - 1] = p.feasible[:-1]
        feas[i, -1] = p.feasible[-1]
    return FleetPlan(
        names=[p.name for p in plans], profiles=list(profiles),
        feasible=feas,
        p_compute=np.array([pr.p_compute for pr in profiles]), **stack)


def homogeneous_fleet(plan: SplitPlan, n_ue: int,
                      profile: Optional[Union[oh.DeviceProfile,
                                              oh.DeviceModel]] = None
                      ) -> FleetPlan:
    """The seed scenario as a special case: N identical plans/devices. The
    default profile follows the device the plan was built for."""
    if profile is None:
        if plan.device is None:
            dev = oh.JETSON_NANO
        elif plan.device in oh.UE_TIERS:
            dev = oh.UE_TIERS[plan.device]
        else:
            raise ValueError(
                f"plan '{plan.name}' was built for '{plan.device}', which is "
                f"not a known UE tier {sorted(oh.UE_TIERS)}; pass an explicit "
                f"DeviceProfile")
        prof = oh.DeviceProfile.from_device(dev)
    else:
        prof = profile
    return build_fleet([plan] * n_ue, [prof] * n_ue)


# --------------------------------------------------------------------- CNN
def cnn_split_table(model: CNNModel, in_size: int, *,
                    dev=oh.JETSON_NANO, ae_ratio=(16, 12, 8, 4),
                    quant_bits=8, batch=1,
                    input_bits_per_px=8) -> SplitPlan:
    """ae_ratio: per-split-point channel-reduction factors R_c. Defaults
    mirror the paper's Fig. 4 (R up to ~128 at early points, decreasing with
    depth: the AE compresses early features best). May be a scalar."""
    flops = model.module_flops(in_size)
    shapes = model.feature_shapes(in_size)
    points = list(model.split_after)
    if not hasattr(ae_ratio, "__len__"):
        ae_ratio = [ae_ratio] * len(points)
    rows = []
    # b = 0: raw input offload
    raw_bits = batch * 3 * in_size * in_size * input_bits_per_px
    rows.append((0.0, 0.0, 0.0, 0.0, raw_bits, True))
    for pi, k in enumerate(points):
        fl = sum(flops[:k + 1]) * batch
        t, e = oh.module_time_energy(fl, fl / 8, dev)
        c, h, w = shapes[k]
        cp = max(1, c // ae_ratio[pi])
        enc_fl = 2 * c * cp * h * w * batch
        tc, ec = oh.module_time_energy(enc_fl, enc_fl / 4, dev)
        bits = batch * cp * h * w * quant_bits
        rows.append((t, e, tc, ec, bits, True))
    fl = sum(flops) * batch
    t, e = oh.module_time_energy(fl, fl / 8, dev)
    rows.append((t, e, 0.0, 0.0, 0.0, True))
    return _finalize(model.name, points, rows, device=dev.name)


def cnn_jalad_table(model: CNNModel, in_size: int, *, dev=oh.JETSON_NANO,
                    entropy_bits=5.0, batch=1) -> SplitPlan:
    """JALAD baseline: 8-bit quant + entropy coding; no channel reduction;
    coder latency from symbols/s throughput (the paper's Fig. 7 point that
    entropy coding on large features dominates)."""
    from repro.core.jalad import ENTROPY_CODER_SYMBOLS_PER_S as CPS
    flops = model.module_flops(in_size)
    shapes = model.feature_shapes(in_size)
    points = list(model.split_after)
    rows = []
    raw_bits = batch * 3 * in_size * in_size * 8
    rows.append((0.0, 0.0, 0.0, 0.0, raw_bits, True))
    for k in points:
        fl = sum(flops[:k + 1]) * batch
        t, e = oh.module_time_energy(fl, fl / 8, dev)
        c, h, w = shapes[k]
        n = batch * c * h * w
        tc = n / CPS
        ec = tc * dev.active_power
        rows.append((t, e, tc, ec, n * entropy_bits, True))
    fl = sum(flops) * batch
    t, e = oh.module_time_energy(fl, fl / 8, dev)
    rows.append((t, e, 0.0, 0.0, 0.0, True))
    return _finalize(model.name + "-jalad", points, rows, device=dev.name)


# ------------------------------------------------------------- transformers
def transformer_split_table(cfg: ModelConfig, *, seq_len=128,
                            ue_dev=oh.PHONE_NPU, n_points=4,
                            ae_ratio=None, quant_bits=None,
                            batch=1) -> SplitPlan:
    ae_ratio = ae_ratio or cfg.bottleneck_ratio
    quant_bits = quant_bits or cfg.quant_bits
    layers = oh.layer_costs(cfg, seq_len)
    L = len(layers)
    emb = oh.embed_costs(cfg, seq_len)
    btypes = cfg.block_types()
    points = [max(1, round(L * (i + 1) / (n_points + 1)))
              for i in range(n_points)]

    embed_pb = cfg.vocab_size * cfg.d_model * 2
    cum_fl = np.cumsum([l["flops"] for l in layers]) * batch
    cum_pb = np.cumsum([l["param_bytes"] for l in layers])

    # family extras
    last_x = max((i for i, bt in enumerate(btypes) if bt in ("xattn",)),
                 default=-1)
    aux_bits_raw = 0
    if cfg.family == "vlm":
        aux_bits_raw = cfg.n_aux_tokens * cfg.d_model * 16 * batch
    enc_flops = 0
    if cfg.family == "encdec":
        enc_layers = oh.layer_costs(
            cfg.replace(block_pattern=("dense",), n_layers=cfg.encoder.n_layers),
            cfg.encoder.n_frames)
        enc_flops = sum(l["flops"] for l in enc_layers) * batch
        aux_bits_raw = cfg.encoder.n_frames * cfg.d_model * 16 * batch

    rows = []
    # b = 0: raw input (token ids; for audio the stub mel frames)
    if cfg.family == "encdec":
        raw_bits = cfg.encoder.n_frames * 80 * 32 * batch + seq_len * 32 * batch
    elif cfg.family == "vlm":
        # raw pixels for 1600 patches ~ (patch 14x14x3 @8bit)
        raw_bits = cfg.n_aux_tokens * 14 * 14 * 3 * 8 * batch + seq_len * 32 * batch
    else:
        raw_bits = seq_len * 32 * batch
    rows.append((0.0, 0.0, 0.0, 0.0, raw_bits, True))

    d = cfg.d_model
    dprime = max(1, d // ae_ratio)
    rate = (d * 32.0) / (dprime * quant_bits)
    for k in points:
        fl = cum_fl[k - 1] + (enc_flops if cfg.family == "encdec" else 0)
        t, e = oh.module_time_energy(fl, fl / 4, ue_dev)
        enc_fl = 2 * seq_len * d * dprime * batch
        tc, ec = oh.module_time_energy(enc_fl, enc_fl / 4, ue_dev)
        bits = seq_len * dprime * quant_bits * batch
        # NOTE: recurrent/SSM state does NOT cross the boundary — layer i's
        # state is internal to layer i; edge-side layers recompute their own
        # states from the transmitted hidden sequence. Only context extras
        # (image embeds / encoder output) ship.
        if cfg.family == "vlm" and k <= last_x:
            bits += aux_bits_raw * 32 / (16 * rate)   # embeds, AE+quant'ed
        if cfg.family == "encdec":
            bits += cfg.encoder.n_frames * dprime * quant_bits * batch
        ue_pb = embed_pb + cum_pb[k - 1]
        rows.append((t, e, tc, ec, bits, ue_pb <= ue_dev.mem_bytes))
    fl_full = cum_fl[-1] + emb["flops"] * batch \
        + (enc_flops if cfg.family == "encdec" else 0)
    t, e = oh.module_time_energy(fl_full, fl_full / 4, ue_dev)
    total_pb = embed_pb + cum_pb[-1] + (emb["param_bytes"] - embed_pb)
    rows.append((t, e, 0.0, 0.0, 0.0, total_pb <= ue_dev.mem_bytes))
    return _finalize(cfg.name, points, rows, device=ue_dev.name)


def split_table(target, **kw) -> SplitPlan:
    """target: CNNModel or ModelConfig."""
    if isinstance(target, CNNModel):
        return cnn_split_table(target, kw.pop("in_size", 224), **kw)
    return transformer_split_table(target, **kw)


# ------------------------------------------------------- measured tables
def measured_cnn_module_costs(model: CNNModel, in_size: int, *,
                              batch=1) -> List[dict]:
    """Per-module {flops, bytes_accessed, hlo_dot_flops} from XLA itself:
    each module's forward is lowered + compiled against abstract params
    (nothing is materialized or executed) and the compiled cost analysis
    read out via launch.hloanalysis.compiled_costs. Unlike
    CNNModel.module_flops (the hand-derived conv walker), this counts
    everything XLA will actually run — BN reductions, elementwise ops,
    padding copies — and it is the same pipeline launch/dryrun.py records
    for the assigned transformer archs."""
    import jax
    from repro.launch.hloanalysis import compiled_costs

    pstruct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    shapes = model.feature_shapes(in_size)
    costs = []
    for i in range(model.n_modules):
        in_shape = ((batch, 3, in_size, in_size) if i == 0
                    else (batch,) + tuple(shapes[i - 1]))
        x = jax.ShapeDtypeStruct(in_shape, np.float32)

        def mod_fwd(p, x, _i=i):
            return model.run_module(p, _i, x)

        costs.append(compiled_costs(mod_fwd, pstruct[i], x))
    return costs


def measured_cnn_split_table(model: CNNModel, in_size: int, *,
                             dev=oh.JETSON_NANO, rd=None,
                             ae_ratio=(16, 12, 8, 4), quant_bits=8,
                             batch=1, input_bits_per_px=8,
                             module_costs=None) -> SplitPlan:
    """``cnn_split_table`` with MEASURED inputs instead of paper constants:

    * per-module FLOPs and bytes from the compiled-HLO cost analysis
      (``measured_cnn_module_costs``) through the same
      ``core.overhead.module_time_energy`` device model — in particular
      the memory side uses XLA's real bytes-accessed instead of the
      flops/8 heuristic;
    * per-split-point compressor rate-distortion from a measured sweep
      (``core.compressor.measure_rate_distortion``: trained AE at each
      candidate point, rate selected by the paper's 2%-accuracy rule),
      passed as ``rd``; the paper's ``ae_ratio`` constants remain the
      fallback when ``rd`` is None.

    Opt-in: the default ``cnn_split_table`` (paper constants) is untouched
    and stays golden-pinned. ``module_costs`` lets callers reuse a sweep."""
    costs = (measured_cnn_module_costs(model, in_size, batch=batch)
             if module_costs is None else module_costs)
    shapes = model.feature_shapes(in_size)
    points = list(model.split_after)
    if rd is not None and len(rd) != len(points):
        raise ValueError(f"rd has {len(rd)} rows for {len(points)} points")
    if not hasattr(ae_ratio, "__len__"):
        ae_ratio = [ae_ratio] * len(points)
    cum_fl = np.cumsum([c["flops"] for c in costs])
    cum_by = np.cumsum([c["bytes_accessed"] for c in costs])
    rows = []
    raw_bits = batch * 3 * in_size * in_size * input_bits_per_px
    rows.append((0.0, 0.0, 0.0, 0.0, raw_bits, True))
    for pi, k in enumerate(points):
        t, e = oh.module_time_energy(cum_fl[k], cum_by[k], dev)
        c, h, w = shapes[k]
        if rd is not None:
            cp = int(rd[pi]["ch_prime"])
            q = int(rd[pi].get("bits", quant_bits))
        else:
            cp = max(1, c // ae_ratio[pi])
            q = quant_bits
        enc_fl = 2 * c * cp * h * w * batch
        tc, ec = oh.module_time_energy(enc_fl, enc_fl / 4, dev)
        rows.append((t, e, tc, ec, batch * cp * h * w * q, True))
    t, e = oh.module_time_energy(cum_fl[-1], cum_by[-1], dev)
    rows.append((t, e, 0.0, 0.0, 0.0, True))
    return _finalize(model.name + "-measured", points, rows, device=dev.name)


def llm_decode_split_table(cfg: ModelConfig, ctx_len: int, *,
                           gen_tokens=32, ue_dev=oh.PHONE_NPU, n_points=4,
                           ae_ratio=None, quant_bits=None, kv_bits=None,
                           batch=1) -> SplitPlan:
    """LLM decode offloading: the intermediate feature IS the serving
    state, and its size grows with context length.

    A task serves one request of ``ctx_len`` context tokens plus
    ``gen_tokens`` generated tokens. A split b = k hands the edge
    everything above layer k: the UE prefills layers [0, k) over the
    context, then ships the AE-compressed boundary hidden-state sequence
    (ctx_len x d') PLUS the UE-side layers' serving cache
    (``models.cache.entry_payload_bits`` — KV at ``kv_bits``, sliding
    windows capped, SSM/RG-LRU O(1) state), so the edge can finish the
    prefill at layer k and decode through the full stack without redoing
    the UE's work. ``f_bits`` is therefore a FUNCTION OF CONTEXT LENGTH —
    a fundamentally different overhead curve than CNN features, where the
    payload shrinks with depth.

      b = 0    ship the raw token ids; the edge does everything
      b = k    UE prefills layers [0, k); payload = hiddens + cache
      b = B+1  full local: prefill + gen_tokens decode steps on the UE

    ``kv_bits`` overrides cfg.kv_quant_bits for the SHIPPED cache (0 =
    16-bit). Opt-in like the other measured builders; the default
    ``transformer_split_table`` is untouched."""
    from repro.models.cache import entry_payload_bits

    ctx_len = int(ctx_len)
    if kv_bits is not None:
        cfg = cfg.replace(kv_quant_bits=kv_bits)
    ae_ratio = ae_ratio or cfg.bottleneck_ratio
    quant_bits = quant_bits or cfg.quant_bits
    btypes = cfg.block_types()
    L = len(btypes)
    pre = oh.layer_costs(cfg, ctx_len)
    dec = oh.decode_layer_costs(cfg, ctx_len)
    points = [max(1, round(L * (i + 1) / (n_points + 1)))
              for i in range(n_points)]

    embed_pb = cfg.vocab_size * cfg.d_model * 2
    cum_fl = np.cumsum([l["flops"] for l in pre]) * batch
    cum_by = np.cumsum([l["bytes"] for l in pre]) * batch
    cum_pb = np.cumsum([l["param_bytes"] for l in pre])
    cum_kv = np.cumsum([entry_payload_bits(cfg, bt, batch, ctx_len)
                        for bt in btypes])

    d = cfg.d_model
    dprime = max(1, d // ae_ratio)
    rows = []
    # b = 0: raw token ids
    rows.append((0.0, 0.0, 0.0, 0.0, ctx_len * 32 * batch, True))
    for k in points:
        t, e = oh.module_time_energy(cum_fl[k - 1], cum_by[k - 1], ue_dev)
        enc_fl = 2 * ctx_len * d * dprime * batch
        tc, ec = oh.module_time_energy(enc_fl, enc_fl / 4, ue_dev)
        bits = ctx_len * dprime * quant_bits * batch + cum_kv[k - 1]
        ue_bytes = embed_pb + cum_pb[k - 1] + cum_kv[k - 1] / 8
        rows.append((t, e, tc, ec, bits, ue_bytes <= ue_dev.mem_bytes))
    # b = B+1: full-local prefill + decode (multi-frame on seconds scale)
    emb = oh.embed_costs(cfg, 1)
    dec_fl = sum(l["flops"] for l in dec) * batch + emb["flops"] * batch
    dec_by = sum(l["bytes"] for l in dec) * batch + emb["bytes"]
    t, e = oh.module_time_energy(cum_fl[-1] + gen_tokens * dec_fl,
                                 cum_by[-1] + gen_tokens * dec_by, ue_dev)
    total_pb = embed_pb + cum_pb[-1] + (emb["param_bytes"] - embed_pb)
    ue_bytes = total_pb + cum_kv[-1] / 8
    rows.append((t, e, 0.0, 0.0, 0.0, ue_bytes <= ue_dev.mem_bytes))
    name = f"{cfg.name}-decode-ctx{ctx_len}"
    return _finalize(name, points, rows, device=ue_dev.name)


def measured_split_table(target, **kw) -> SplitPlan:
    """Measured-table dispatcher, mirroring ``split_table``: CNNModel ->
    compiled-HLO-measured table; ModelConfig -> LLM-decode table (pass
    ``ctx_len``)."""
    if isinstance(target, CNNModel):
        return measured_cnn_split_table(target, kw.pop("in_size", 224), **kw)
    return llm_decode_split_table(target, kw.pop("ctx_len", 1024), **kw)
