"""Per-module latency/energy tables (paper §3.4, Fig. 7).

The paper *measures* these on a Jetson Nano with an external power monitor.
No such hardware exists in this container, so the tables come from an
analytic device model

    t(module) = max(flops / (eff * peak_flops), bytes / mem_bw)
    e(module) = t * active_power

calibrated so a full ResNet18(224) inference costs ~50 ms / ~0.11 J on the
UE — the magnitudes behind the paper's T0 = 0.5 s (~10x a full local
inference) and beta = 0.47 (latency/energy ratio). For the assigned
transformer architectures the same model runs over per-layer FLOPs/bytes
derived from the ModelConfig; on the TPU-edge side the constants are v5e's.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DeviceModel:
    name: str
    peak_flops: float           # effective FLOP/s (incl. utilization)
    mem_bw: float               # B/s
    active_power: float         # W while computing
    mem_bytes: float            # capacity for feasibility checks


# Jetson-Nano-like UE in 5 W low-power mode: ~236 GFLOPS fp16 peak, ~30%
# effective => 72 GFLOP/s; 25.6 GB/s LPDDR4; ~2.1 W above idle.
JETSON_NANO = DeviceModel("jetson-nano", 7.2e10, 2.56e10 * 0.6, 2.1, 4e9)

# A beefier UE tier (phone-class NPU) used for transformer-UE experiments.
PHONE_NPU = DeviceModel("phone-npu", 2.0e12, 5.0e10, 3.0, 8e9)

# Low-end IoT tier (Pi-Zero-class SoC): ~5 GFLOP/s effective, slow LPDDR2,
# little headroom above idle, 512 MB — most transformer splits are infeasible.
IOT_SOC = DeviceModel("iot-soc", 5.0e9, 2.0e9, 0.8, 5.12e8)

# TPU v5e edge chip (the "edge server" of the lifted scenario).
TPU_V5E = DeviceModel("tpu-v5e", 197e12 * 0.5, 819e9, 170.0, 16e9)

UE_TIERS = {d.name: d for d in (JETSON_NANO, PHONE_NPU, IOT_SOC)}


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Per-UE runtime profile the scheduler consumes: the device the UE's
    split table was built for plus the compute power draw the MEC env charges
    for local seconds (paper's P_compute; was a single global scalar)."""
    name: str
    p_compute: float            # W charged per local compute second
    device: DeviceModel = JETSON_NANO

    @classmethod
    def from_device(cls, dev: DeviceModel) -> "DeviceProfile":
        return cls(dev.name, dev.active_power, dev)


# Weaker edge tiers for multi-server pools: a rack GPU and a fanless NUC.
EDGE_GPU = DeviceModel("edge-gpu", 5.0e12, 3.0e11, 70.0, 1.2e10)
EDGE_NUC = DeviceModel("edge-nuc", 8.0e11, 6.0e10, 28.0, 8e9)


@dataclasses.dataclass(frozen=True)
class ServerProfile:
    """One edge server of an EdgePool, as the MEC env sees it.

    ``dist_scale`` multiplies each UE's distance for uplinks to THIS
    server (servers sit at different points of the cell), ``bw_scale``
    multiplies the per-channel bandwidth of this server's own uplink
    channels, and ``edge_speed`` is the effective FLOP/s the server
    devotes to finishing offloaded inferences — 0.0 keeps the paper's
    assumption of an instantaneous edge. A profile with all three at
    their defaults is the paper's single server: the env compiles the
    routing machinery out entirely and is bit-for-bit the seed env."""
    name: str
    device: DeviceModel = TPU_V5E
    dist_scale: float = 1.0
    bw_scale: float = 1.0
    edge_speed: float = 0.0      # 0.0 = instant edge (paper assumption)

    @property
    def is_paper_default(self) -> bool:
        return (self.dist_scale == 1.0 and self.bw_scale == 1.0
                and self.edge_speed == 0.0)

    @classmethod
    def from_device(cls, dev: DeviceModel, *, dist_scale=1.0, bw_scale=1.0,
                    utilization=0.3) -> "ServerProfile":
        """A server whose edge-side inference runs at ``utilization`` of
        the device's peak (edge chips juggle many tenants)."""
        return cls(dev.name, dev, dist_scale, bw_scale,
                   dev.peak_flops * utilization)


def module_time_energy(flops: float, bytes_moved: float, dev: DeviceModel):
    t = max(flops / dev.peak_flops, bytes_moved / dev.mem_bw)
    return t, t * dev.active_power


def task_latency_energy(l_b, n_b, rate, p_compute, p_tx, t_edge=None):
    """Eq. 7/8 closed-form per-task latency/energy — THE one definition.

    A task run at split b costs

        t = l_b + n_b / rate [+ t_edge]     (Eq. 7, + edge service)
        e = l_b * p_compute + (n_b / rate) * p_tx          (Eq. 8)

    where ``l_b`` is the UE-side local+compression seconds, ``n_b`` the
    offloaded bits, ``rate`` the uplink bits/s under the current
    interference, and ``t_edge`` the (processor-shared) edge service
    seconds (None or 0 for the paper's instantaneous edge).

    Shared by ``MECEnv.task_overhead``, ``rl.heuristics._joint_overhead``
    and the continuous-time stream simulator (``repro.stream.events``), so
    the three callers cannot drift; written with plain operators so it is
    exact on jnp float32 arrays and numpy float64 scalars alike. The op
    order (one division, reused) matches the historical env expression
    bit-for-bit on float32 inputs."""
    tx = n_b / rate
    t = l_b + tx
    if t_edge is not None:
        t = t + t_edge
    e = l_b * p_compute + tx * p_tx
    return t, e


# -------------------------------------------------- transformer layer costs
def layer_costs(cfg: ModelConfig, seq_len: int) -> List[dict]:
    """Per-layer {flops, bytes, param_bytes} for a seq_len-token forward.
    bytes = params read once + activations in/out (bf16)."""
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    s = seq_len
    act = 2 * s * d * 2  # in+out hidden, bf16
    out = []
    for bt in cfg.block_types():
        if bt == "mamba2":
            ss = cfg.ssm
            di = ss.expand * d
            h = di // ss.head_dim
            n = ss.d_state
            proj = 2 * s * d * (2 * di + 2 * n + h) + 2 * s * di * d
            ssd = 2 * s * h * ss.head_dim * n * 3 + 2 * s * ss.chunk * (
                n + h * ss.head_dim)
            pbytes = (d * (2 * di + 2 * n + h) + di * d) * 2
            out.append({"flops": proj + ssd, "bytes": pbytes + act,
                        "param_bytes": pbytes})
            continue
        if bt == "rec":
            drnn = d
            fl = 2 * s * d * drnn * 2 + 2 * s * drnn * drnn * 2 \
                + 2 * s * drnn * d + 6 * s * d * f
            pbytes = (2 * d * drnn + 2 * drnn * drnn + drnn * d + 3 * d * f) * 2
            out.append({"flops": fl, "bytes": pbytes + act,
                        "param_bytes": pbytes})
            continue
        # attention part
        attn_proj = 2 * s * d * (hq + 2 * hkv) * dh + 2 * s * hq * dh * d
        ctx = min(s, cfg.window) if bt == "lattn" else s
        attn_qk = 4 * s * ctx * hq * dh
        a_params = (d * (hq + 2 * hkv) * dh + hq * dh * d) * 2
        fl = attn_proj + attn_qk
        pbytes = a_params
        if bt in ("xattn",):
            fl = 2 * s * d * hq * dh + 2 * s * hq * dh * d \
                + 4 * s * cfg.n_aux_tokens * hq * dh \
                + 2 * cfg.n_aux_tokens * d * 2 * hkv * dh
        if bt == "decx":
            nf = cfg.encoder.n_frames if cfg.encoder else 0
            fl += 2 * s * d * hq * dh + 2 * s * hq * dh * d \
                + 4 * s * nf * hq * dh
            pbytes += a_params
        # ffn part
        if bt == "moe":
            m = cfg.moe
            ffl = 2 * s * d * m.n_experts  # router
            ffl += 6 * s * d * m.d_expert * (m.top_k + m.n_shared_experts)
            fp = (m.n_experts + m.n_shared_experts) * 3 * d * m.d_expert * 2
            # only the activated experts' weights stream from memory
            fbytes = 3 * d * m.d_expert * (m.top_k + m.n_shared_experts) * 2
        elif bt == "mamba2":
            ffl, fp, fbytes = 0, 0, 0
        else:
            mult = 3 if cfg.act == "swiglu" else 2
            ffl = mult * 2 * s * d * f
            fp = mult * d * f * 2
            fbytes = fp
        out.append({"flops": fl + ffl, "bytes": pbytes + fbytes + act,
                    "param_bytes": pbytes + fp})
    return out


def decode_layer_costs(cfg: ModelConfig, ctx_len: int) -> List[dict]:
    """Per-layer {flops, bytes, param_bytes} of ONE decode step at context
    length ``ctx_len``: s = 1 projections, attention scores over the
    (window-capped) context, and the layer's serving-cache bytes READ per
    token — decode is memory-bound, so the cache traffic is the term that
    grows with context. SSM/RG-LRU layers update O(1) state and are
    constant in ctx_len."""
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv, f = cfg.n_heads, cfg.n_kv_heads, cfg.d_ff
    act = 2 * d * 2  # in+out hidden for the single token, bf16
    kv_el = 1 if cfg.kv_quant_bits else 2   # int8 codes vs bf16
    out = []
    for bt in cfg.block_types():
        if bt == "mamba2":
            ss = cfg.ssm
            di = ss.expand * d
            h = di // ss.head_dim
            n = ss.d_state
            proj = 2 * d * (2 * di + 2 * n + h) + 2 * di * d
            step = 2 * h * ss.head_dim * n * 3
            pbytes = (d * (2 * di + 2 * n + h) + di * d) * 2
            state_b = h * ss.head_dim * n * 4 \
                + (ss.d_conv - 1) * (di + 2 * n) * 2
            out.append({"flops": proj + step,
                        "bytes": pbytes + state_b + act,
                        "param_bytes": pbytes})
            continue
        if bt == "rec":
            drnn = d
            fl = 2 * d * drnn * 2 + 2 * drnn * drnn * 2 + 2 * drnn * d \
                + 6 * d * f
            pbytes = (2 * d * drnn + 2 * drnn * drnn + drnn * d
                      + 3 * d * f) * 2
            out.append({"flops": fl, "bytes": pbytes + drnn * 4 + act,
                        "param_bytes": pbytes})
            continue
        # attention part: project the new token, score it against the cache
        ctx = min(ctx_len, cfg.window) if bt == "lattn" else ctx_len
        attn_proj = 2 * d * (hq + 2 * hkv) * dh + 2 * hq * dh * d
        attn_qk = 4 * ctx * hq * dh
        a_params = (d * (hq + 2 * hkv) * dh + hq * dh * d) * 2
        cache_b = 2 * ctx * hkv * dh * kv_el \
            + (2 * ctx * hkv * 4 if cfg.kv_quant_bits else 0)
        fl = attn_proj + attn_qk
        pbytes = a_params
        if bt == "xattn":
            fl = 2 * d * hq * dh + 2 * hq * dh * d \
                + 4 * cfg.n_aux_tokens * hq * dh
            cache_b = 2 * cfg.n_aux_tokens * hkv * dh * 2
        if bt == "decx":
            nf = cfg.encoder.n_frames if cfg.encoder else 0
            fl += 2 * d * hq * dh + 2 * hq * dh * d + 4 * nf * hq * dh
            pbytes += a_params
            cache_b += 2 * nf * hkv * dh * 2
        # ffn part
        if bt == "moe":
            m = cfg.moe
            ffl = 2 * d * m.n_experts  # router
            ffl += 6 * d * m.d_expert * (m.top_k + m.n_shared_experts)
            fp = (m.n_experts + m.n_shared_experts) * 3 * d * m.d_expert * 2
            fbytes = 3 * d * m.d_expert * (m.top_k + m.n_shared_experts) * 2
        else:
            mult = 3 if cfg.act == "swiglu" else 2
            ffl = mult * 2 * d * f
            fp = mult * d * f * 2
            fbytes = fp
        out.append({"flops": fl + ffl,
                    "bytes": pbytes + fbytes + cache_b + act,
                    "param_bytes": pbytes + fp})
    return out


def embed_costs(cfg: ModelConfig, seq_len: int) -> dict:
    pb = cfg.vocab_size * cfg.d_model * 2
    return {"flops": 2 * seq_len * cfg.d_model * cfg.vocab_size,  # lm head
            "bytes": pb * 2, "param_bytes": pb * (1 if cfg.tie_embeddings else 2)}
