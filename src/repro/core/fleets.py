"""Canonical demo fleets and edge pools. The mixed 4-UE fleet below is
shared by ``examples/collaborative_serve.py --fleet`` and
``benchmarks/bench_hetero_fleet.py``; the 2-server pool is shared by
``--servers`` and ``benchmarks/bench_multi_server.py`` — so the demos,
the benchmarks, and the docs all describe the same scenarios."""
from __future__ import annotations

import dataclasses
from typing import Tuple

from repro.core import overhead as oh
from repro.core.cnn import make_resnet18
from repro.core.split import (FleetPlan, build_fleet, cnn_split_table,
                              transformer_split_table)


def make_mixed_fleet(arch: str = "qwen3-1.7b") -> FleetPlan:
    """ResNet18 on a Jetson, ResNet18 on an IoT-class SoC, and two
    reduced-transformer UEs on phone NPUs — each split table built for the
    device that runs it."""
    from repro.configs import get_config
    cnn = make_resnet18(101)
    tcfg = get_config(arch)
    plans = [cnn_split_table(cnn, 224, dev=oh.JETSON_NANO),
             cnn_split_table(cnn, 224, dev=oh.IOT_SOC),
             transformer_split_table(tcfg, ue_dev=oh.PHONE_NPU),
             transformer_split_table(tcfg, ue_dev=oh.PHONE_NPU)]
    return build_fleet(plans, [oh.JETSON_NANO, oh.IOT_SOC,
                               oh.PHONE_NPU, oh.PHONE_NPU])


# ---------------------------------------------------------------- edge side
@dataclasses.dataclass(frozen=True)
class EdgePool:
    """The edge side of the scenario: an ordered set of servers the
    `route` action head picks between. A pool of one paper-default server
    is the seed scenario — the env compiles the routing machinery out and
    stays bit-for-bit identical to the single-server env."""
    servers: Tuple[oh.ServerProfile, ...]

    def __post_init__(self):
        if not self.servers:
            raise ValueError("EdgePool needs at least one server")
        names = [s.name for s in self.servers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate server names: {names}")

    @property
    def n_servers(self) -> int:
        return len(self.servers)

    @property
    def is_single_paper_server(self) -> bool:
        return self.n_servers == 1 and self.servers[0].is_paper_default


def single_server() -> EdgePool:
    """The paper's scenario: one TPU-v5e-class server at the cell center,
    instantaneous edge inference."""
    return EdgePool((oh.ServerProfile("tpu-v5e"),))


def make_edge_pool(n: int = 2) -> EdgePool:
    """Canonical demo pool: a TPU-v5e at the cell center, then
    progressively farther / weaker tiers. With the default 2 servers a
    nearest-server policy piles every UE onto the v5e's two channels and
    pays the interference; spreading load across the farther edge-gpu
    (interference-free but ~1.4x the path loss distance) is the better
    joint policy MAHPPO should find."""
    tiers = [oh.ServerProfile("tpu-v5e", oh.TPU_V5E, 1.0, 1.0, 0.0),
             oh.ServerProfile.from_device(oh.EDGE_GPU, dist_scale=1.4),
             oh.ServerProfile.from_device(oh.EDGE_NUC, dist_scale=1.8,
                                          bw_scale=0.8)]
    if not 1 <= n <= len(tiers):
        raise ValueError(f"demo pool supports 1..{len(tiers)} servers")
    return EdgePool(tuple(tiers[:n]))
