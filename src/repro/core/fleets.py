"""Canonical demo fleets and edge pools. The mixed 4-UE fleet below is
shared by ``examples/collaborative_serve.py --fleet`` and
``benchmarks/bench_hetero_fleet.py``; the 2-server pool is shared by
``--servers`` and ``benchmarks/bench_multi_server.py`` — so the demos,
the benchmarks, and the docs all describe the same scenarios."""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core import overhead as oh
from repro.core.cnn import make_resnet18
from repro.core.split import (FleetPlan, build_fleet, cnn_split_table,
                              llm_decode_split_table, transformer_split_table)


def make_mixed_fleet(arch: str = "qwen3-1.7b", n_ue: int = 4) -> FleetPlan:
    """ResNet18 on a Jetson, ResNet18 on an IoT-class SoC, and two
    reduced-transformer UEs on phone NPUs — each split table built for the
    device that runs it. ``n_ue`` cycles that 4-UE device mix to any fleet
    size (the zero-shot generalization scenarios reuse the same mix at
    8 and 16 UEs)."""
    from repro.configs import get_config
    cnn = make_resnet18(101)
    tcfg = get_config(arch)
    base = [(cnn_split_table(cnn, 224, dev=oh.JETSON_NANO), oh.JETSON_NANO),
            (cnn_split_table(cnn, 224, dev=oh.IOT_SOC), oh.IOT_SOC),
            (transformer_split_table(tcfg, ue_dev=oh.PHONE_NPU),
             oh.PHONE_NPU),
            (transformer_split_table(tcfg, ue_dev=oh.PHONE_NPU),
             oh.PHONE_NPU)]
    picks = [base[i % len(base)] for i in range(n_ue)]
    return build_fleet([p for p, _ in picks], [d for _, d in picks])


# 2-3 context lengths exposed as DISTINCT task classes: each rung is its
# own SplitPlan (own f_bits curve, own full-local seconds), so a mixed
# fleet carries short-, mid- and long-context LLM UEs side by side with
# CNN UEs and the policy can treat them differently.
LLM_CTX_RUNGS = (256, 1024, 4096)


def make_llm_mixed_fleet(arch: str = "qwen3-1.7b", n_cnn: int = 2,
                         ctx_rungs=LLM_CTX_RUNGS, *, gen_tokens: int = 16,
                         kv_bits: int = 8) -> FleetPlan:
    """Mixed CNN + LLM-decode fleet: ``n_cnn`` ResNet18 UEs (Jetson / IoT
    alternating, the same device mix as ``make_mixed_fleet``) plus one
    LLM-decode UE per context rung on a phone NPU
    (``core.split.llm_decode_split_table``). CNN-feature offloading
    (payload shrinks with depth) and KV-cache offloading (payload grows
    with context) compete for the same channels and edge servers."""
    from repro.configs import get_config
    cnn = make_resnet18(101)
    cnn_devs = (oh.JETSON_NANO, oh.IOT_SOC)
    picks = [(cnn_split_table(cnn, 224, dev=cnn_devs[i % 2]), cnn_devs[i % 2])
             for i in range(n_cnn)]
    cfg = get_config(arch)
    for ctx in ctx_rungs:
        picks.append((llm_decode_split_table(cfg, ctx,
                                             gen_tokens=gen_tokens,
                                             ue_dev=oh.PHONE_NPU,
                                             kv_bits=kv_bits),
                      oh.PHONE_NPU))
    return build_fleet([p for p, _ in picks], [d for _, d in picks])


# ------------------------------------------------- per-UE feature extraction
# Static descriptor rows the env's `observe_per_ue` serves to the
# weight-shared policy. Everything is a NORMALIZED scalar summary — never a
# raw table — so the feature dimension is independent of the fleet size N,
# the widest action count B_max, and the pool size E, which is exactly what
# lets one policy transfer across fleets and pool layouts.

P_COMPUTE_NORM = 5.0        # W; spans the IoT (0.8) .. phone-NPU (3.0) tiers
OMEGA_NORM = 1e6            # Hz; the paper's per-channel bandwidth
BITS_NORM = 1e6             # bits; same scale `observe` uses for s.n
DIST_NORM = 100.0           # m; same scale `observe` uses for s.d
EDGE_SLOW_NORM = 1e-12      # s/FLOP; edge tiers span 0 (instant) .. 4.2e-12
RATE_NORM = 1e7             # b/s; a clean 50 m channel at p_max is ~1.2e7


def ue_table_features(l_new, n_new, feasible, p_compute, t0):
    """(N, 5) static per-UE device/table descriptors from the fleet's
    (N, B_max+2) overhead tables: normalized compute power draw, full-local
    seconds (device-speed proxy), feasible-action fraction, and mean
    feasible local-seconds / offload-bits. Rows permute with the fleet —
    a permutation-equivariance requirement of `observe_per_ue`."""
    l = np.asarray(l_new, np.float64)
    n = np.asarray(n_new, np.float64)
    feas = np.asarray(feasible, bool)
    t0 = float(t0)
    cnt = np.maximum(feas.sum(axis=1), 1)          # full-local always feasible
    return np.stack([
        np.asarray(p_compute, np.float64) / P_COMPUTE_NORM,
        l[:, -1] / t0,
        feas.mean(axis=1),
        (l * feas).sum(axis=1) / cnt / t0,
        (n * feas).sum(axis=1) / cnt / BITS_NORM,
    ], axis=1).astype(np.float32)


def pool_aggregate_features(server_dist, omega, t_edge, feasible, t0):
    """(4,) fixed-size edge-pool descriptor, independent of E: nearest /
    mean server distance scale, mean per-channel bandwidth, and the mean
    edge service time over feasible OFFLOAD slots (full-local — always
    the last slot, always feasible, definitionally zero edge time — is
    excluded so it can't deflate the mean). A single paper-default server
    yields (1, 1, mean omega, 0) — the degenerate pool."""
    om = np.asarray(omega, np.float64)
    dist = np.ones((1,)) if server_dist is None \
        else np.asarray(server_dist, np.float64)
    te_mean = 0.0
    if t_edge is not None:
        feas = np.asarray(feasible, bool)[:, :-1]
        te = np.asarray(t_edge, np.float64)[:, :-1]    # (N, B_max+1, E)
        te_mean = float(te[feas].mean() / float(t0))
    return np.array([dist.min(), dist.mean(), om.mean() / OMEGA_NORM,
                     te_mean], np.float32)


# --------------------------------------------- per-server feature builders
# Entity-set observations (env.observe_entities) describe each server by its
# GEOMETRY triple [dist_scale, bw_scale, slowness] — the three degrees of
# freedom a ServerProfile adds over the paper's fixed cell-center server.
# Slowness is 1 / edge_speed (seconds per FLOP; 0 = the paper's instant
# edge): the edge service time is LINEAR in it, so uniform geometry draws
# span instant .. weakest-tier service times smoothly instead of blowing up
# near zero speed. Geometry is data, not structure: the same (E, 3) array
# format is served statically from an EdgePool or resampled per episode
# from ranges, which is what lets one shared per-server route scorer
# transfer across pool layouts AND pool sizes.

def server_slowness(edge_speed) -> float:
    """s/FLOP a server devotes to an offloaded task (0 = instant edge)."""
    return 1.0 / edge_speed if edge_speed > 0 else 0.0


def pool_geometry(pool) -> np.ndarray:
    """(E, 3) [dist_scale, bw_scale, slowness] rows, one per server.
    ``None`` (or a single paper-default server) yields the degenerate
    [[1, 1, 0]] geometry — the paper's instantaneous cell-center edge."""
    if pool is None or pool.is_single_paper_server:
        return np.array([[1.0, 1.0, 0.0]], np.float32)
    return np.array([[s.dist_scale, s.bw_scale,
                      server_slowness(s.edge_speed)]
                     for s in pool.servers], np.float32)


def random_pool_ranges(n_servers: int, *, dist=(0.9, 2.0), bw=(0.5, 1.25),
                       slow=(0.0, 4.2e-12)):
    """(low, high) (E, 3) geometry bounds for randomized-pool training:
    each episode draws every server's [dist_scale, bw_scale, slowness]
    uniformly from these ranges, so the route head sees pool features that
    actually VARY (single-pool training leaves them constant — no gradient
    signal). The defaults cover the demo pools: `make_edge_pool` tiers
    (dist 1.0/1.4/1.8, bw 1.0/1.0/0.8, slowness 0 / 6.7e-13 / 4.2e-12)
    and the inverted/bandwidth-starved probe layouts."""
    low = np.tile(np.array([[dist[0], bw[0], slow[0]]], np.float32),
                  (n_servers, 1))
    high = np.tile(np.array([[dist[1], bw[1], slow[1]]], np.float32),
                   (n_servers, 1))
    return low, high


def ue_edge_work(l_new, feasible, peak_flops):
    """(N, B_max+2) float64 remaining-FLOPs table of the edge-side tail of
    each (ue, split): the work a routed server must finish, zeroed on
    padded slots and on full-local (which never touches the edge).
    Divided by a server's edge_speed this reproduces the env's t_edge
    column for that server bit-for-bit — the geometry-resampling path
    recomputes it on the fly from the drawn speeds."""
    t_loc = np.asarray(l_new, np.float64)
    feas = np.asarray(feasible, bool)
    work = np.maximum(t_loc[:, -1:] - t_loc, 0.0) \
        * np.asarray(peak_flops, np.float64)[:, None]
    work[~feas] = 0.0
    work[:, -1] = 0.0
    return work


# ---------------------------------------------------------------- edge side
@dataclasses.dataclass(frozen=True)
class EdgePool:
    """The edge side of the scenario: an ordered set of servers the
    `route` action head picks between. A pool of one paper-default server
    is the seed scenario — the env compiles the routing machinery out and
    stays bit-for-bit identical to the single-server env."""
    servers: Tuple[oh.ServerProfile, ...]

    def __post_init__(self):
        if not self.servers:
            raise ValueError("EdgePool needs at least one server")
        names = [s.name for s in self.servers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate server names: {names}")

    @property
    def n_servers(self) -> int:
        return len(self.servers)

    @property
    def is_single_paper_server(self) -> bool:
        return self.n_servers == 1 and self.servers[0].is_paper_default


def single_server() -> EdgePool:
    """The paper's scenario: one TPU-v5e-class server at the cell center,
    instantaneous edge inference."""
    return EdgePool((oh.ServerProfile("tpu-v5e"),))


def make_edge_pool(n: int = 2) -> EdgePool:
    """Canonical demo pool: a TPU-v5e at the cell center, then
    progressively farther / weaker tiers. With the default 2 servers a
    nearest-server policy piles every UE onto the v5e's two channels and
    pays the interference; spreading load across the farther edge-gpu
    (interference-free but ~1.4x the path loss distance) is the better
    joint policy MAHPPO should find."""
    tiers = [oh.ServerProfile("tpu-v5e", oh.TPU_V5E, 1.0, 1.0, 0.0),
             oh.ServerProfile.from_device(oh.EDGE_GPU, dist_scale=1.4),
             oh.ServerProfile.from_device(oh.EDGE_NUC, dist_scale=1.8,
                                          bw_scale=0.8)]
    if not 1 <= n <= len(tiers):
        raise ValueError(f"demo pool supports 1..{len(tiers)} servers")
    return EdgePool(tuple(tiers[:n]))
