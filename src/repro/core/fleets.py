"""Canonical demo fleets. The mixed 4-UE fleet below is shared by
``examples/collaborative_serve.py --fleet`` and
``benchmarks/bench_hetero_fleet.py`` so the demo, the benchmark, and the
docs all describe the same scenario."""
from __future__ import annotations

from repro.core import overhead as oh
from repro.core.cnn import make_resnet18
from repro.core.split import (FleetPlan, build_fleet, cnn_split_table,
                              transformer_split_table)


def make_mixed_fleet(arch: str = "qwen3-1.7b") -> FleetPlan:
    """ResNet18 on a Jetson, ResNet18 on an IoT-class SoC, and two
    reduced-transformer UEs on phone NPUs — each split table built for the
    device that runs it."""
    from repro.configs import get_config
    cnn = make_resnet18(101)
    tcfg = get_config(arch)
    plans = [cnn_split_table(cnn, 224, dev=oh.JETSON_NANO),
             cnn_split_table(cnn, 224, dev=oh.IOT_SOC),
             transformer_split_table(tcfg, ue_dev=oh.PHONE_NPU),
             transformer_split_table(tcfg, ue_dev=oh.PHONE_NPU)]
    return build_fleet(plans, [oh.JETSON_NANO, oh.IOT_SOC,
                               oh.PHONE_NPU, oh.PHONE_NPU])
