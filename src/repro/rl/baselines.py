"""Baselines (paper §6.3.1): full-local, and fixed/random policies."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.env.mecenv import MECEnv, per_ue


def local_policy_eval(env: MECEnv, *, frames=64, seed=0):
    """Always run fully locally (b = B+1; the last action for every UE in a
    fleet by FleetPlan construction). On dynamic fleets the per-task means
    cover ACTIVE UEs only (standby slots compute nothing)."""
    b_local = env.n_actions_b - 1

    @jax.jit
    def rollout(key):
        s = env.reset(key, eval_mode=True)

        def body(s, _):
            n = env.params.n_ue
            b = jnp.full((n,), b_local, jnp.int32)
            c = jnp.zeros((n,), jnp.int32)
            p = jnp.full((n,), 0.01)
            s2, reward, done, info = env.step(s, b, c, p)
            act = s.active.astype(jnp.float32)
            n_act = jnp.maximum(act.sum(), 1.0)
            t_task = per_ue(env.params.l_new, b)
            e_task = t_task * env.params.p_compute
            return s2, {"reward": reward,
                        "t_task": (t_task * act).sum() / n_act,
                        "e_task": (e_task * act).sum() / n_act,
                        "completed": info["completed"]}

        _, out = jax.lax.scan(body, s, None, length=frames)
        return out

    out = rollout(jax.random.PRNGKey(seed))
    return {k: float(np.asarray(v).mean()) for k, v in out.items()}


def random_policy_eval(env: MECEnv, *, frames=64, seed=0):
    """Uniform over each UE's OWN feasible actions (padded/infeasible
    entries carry -inf logits and are never drawn). On dynamic fleets the
    state-dependent mask pins inactive UEs to the inert full-local action."""

    @jax.jit
    def rollout(key):
        s = env.reset(key, eval_mode=True)

        def body(s, sub):
            n = env.params.n_ue
            rand_logits = jnp.where(env.action_mask(s), 0.0, -jnp.inf)
            kb, kc, kp = jax.random.split(sub, 3)
            b = jax.vmap(jax.random.categorical)(
                jax.random.split(kb, n), rand_logits).astype(jnp.int32)
            c = jax.random.randint(kc, (n,), 0, env.n_channels)
            p = jax.random.uniform(kp, (n,), minval=0.01,
                                   maxval=env.params.p_max)
            s2, reward, done, info = env.step(s, b, c, p)
            return s2, {"reward": reward, "completed": info["completed"]}

        _, out = jax.lax.scan(body, s, jax.random.split(key, frames))
        return out

    out = rollout(jax.random.PRNGKey(seed))
    return {k: float(np.asarray(v).mean()) for k, v in out.items()}
