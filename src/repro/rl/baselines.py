"""Baselines (paper §6.3.1): full-local and random policies, plus — on a
multi-server edge pool — two fixed-routing references:

* nearest-server greedy: every UE offloads at its clean-channel-optimal
  split but routes to the CLOSEST server (what a routing-oblivious
  deployment does). The whole fleet piles onto one server's channels and
  pays the interference — the gap MAHPPO should close by spreading load.
* load-aware round-robin: same per-UE splits, but UEs are dealt across
  servers round-robin (balanced UE count, still interference-oblivious).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.env.mecenv import MECEnv, per_ue


def _act(env: MECEnv, b, c, p, route=None):
    """Assemble the env's actions dict (adding a default route head on
    multi-server envs so hand-written policies stay terse)."""
    a = {"split": b, "channel": c, "power": p}
    if env.multi_server:
        a["route"] = jnp.zeros_like(b) if route is None else route
    return a


def local_policy_eval(env: MECEnv, *, frames=64, seed=0):
    """Always run fully locally (b = B+1; the last action for every UE in a
    fleet by FleetPlan construction). On dynamic fleets the per-task means
    cover ACTIVE UEs only (standby slots compute nothing)."""
    b_local = env.n_actions_b - 1

    @jax.jit
    def rollout(key):
        s = env.reset(key, eval_mode=True)

        def body(s, _):
            n = env.params.n_ue
            b = jnp.full((n,), b_local, jnp.int32)
            c = jnp.zeros((n,), jnp.int32)
            p = jnp.full((n,), 0.01)
            s2, reward, done, info = env.step(s, _act(env, b, c, p))
            act = s.active.astype(jnp.float32)
            n_act = jnp.maximum(act.sum(), 1.0)
            t_task = per_ue(env.params.l_new, b)
            e_task = t_task * env.params.p_compute
            return s2, {"reward": reward,
                        "t_task": (t_task * act).sum() / n_act,
                        "e_task": (e_task * act).sum() / n_act,
                        "completed": info["completed"]}

        _, out = jax.lax.scan(body, s, None, length=frames)
        return out

    out = rollout(jax.random.PRNGKey(seed))
    return {k: float(np.asarray(v).mean()) for k, v in out.items()}


def random_policy_eval(env: MECEnv, *, frames=64, seed=0):
    """Uniform over each UE's OWN feasible actions (padded/infeasible
    entries carry -inf logits and are never drawn) — and uniform over
    servers on an edge pool. On dynamic fleets the state-dependent mask
    pins inactive UEs to the inert full-local action."""

    @jax.jit
    def rollout(key):
        s = env.reset(key, eval_mode=True)

        def body(s, sub):
            n = env.params.n_ue
            mask = env.action_masks(s)["split"]
            rand_logits = jnp.where(mask, 0.0, -jnp.inf)
            keys = jax.random.split(sub, 4 if env.multi_server else 3)
            b = jax.vmap(jax.random.categorical)(
                jax.random.split(keys[0], n), rand_logits).astype(jnp.int32)
            c = jax.random.randint(keys[1], (n,), 0, env.n_channels)
            p = jax.random.uniform(keys[2], (n,), minval=0.01,
                                   maxval=env.params.p_max)
            route = None
            if env.multi_server:
                route = jax.random.randint(keys[3], (n,), 0, env.n_servers)
            s2, reward, done, info = env.step(s, _act(env, b, c, p, route))
            return s2, {"reward": reward, "completed": info["completed"]}

        _, out = jax.lax.scan(body, s, jax.random.split(key, frames))
        return out

    out = rollout(jax.random.PRNGKey(seed))
    return {k: float(np.asarray(v).mean()) for k, v in out.items()}


# ------------------------------------------------------- fixed routing
def _fixed_route_eval(env: MECEnv, route, *, d=50.0, active=None):
    """Score greedy per-UE splits under a FIXED routing assignment: each
    UE takes its best clean-channel (split) on its assigned server,
    channels round-robin within each server, p_max — then everything is
    evaluated jointly WITH interference and server sharing. `active`
    (N,) bool: standby UEs of a dynamic fleet neither transmit nor enter
    the means (same aggregation contract as greedy_eval)."""
    from repro.rl.heuristics import (_clean_cost_table, _joint_overhead,
                                     _round_robin_channels)
    prm = env.params
    n = prm.n_ue
    beta = float(prm.beta)
    act = np.ones((n,), bool) if active is None else np.asarray(active)
    if not act.any():
        raise ValueError("active mask selects no UE: nothing to score")
    cost = _clean_cost_table(env, d)                  # (N, B+2, E)
    b = [int(cost[ue, :, route[ue]].argmin()) for ue in range(n)]
    c = _round_robin_channels(route, env.n_channels)
    p = [float(prm.p_max)] * n
    t, e = _joint_overhead(env, b, c, p, [d] * n, active=act, route=route)
    return {"b": b, "route": list(route),
            "t_task": float(t[act].mean()), "e_task": float(e[act].mean()),
            "overhead": float((t + beta * e)[act].mean())}


def nearest_server_eval(env: MECEnv, *, d=50.0, active=None):
    """Routing-oblivious reference: every UE routes to the closest server
    (min dist_scale) and offloads at its clean-channel-best split there."""
    if not env.multi_server:
        raise ValueError("nearest_server_eval needs a multi-server env")
    e_near = int(np.argmin(np.asarray(env.params.server_dist)))
    return _fixed_route_eval(env, [e_near] * env.params.n_ue, d=d,
                             active=active)


def load_aware_eval(env: MECEnv, *, d=50.0, active=None):
    """Round-robin load balancing: UE i routes to server i mod E (equal
    UE counts per server), splits re-optimized per assigned server."""
    if not env.multi_server:
        raise ValueError("load_aware_eval needs a multi-server env")
    n = env.params.n_ue
    return _fixed_route_eval(env, [i % env.n_servers for i in range(n)],
                             d=d, active=active)
