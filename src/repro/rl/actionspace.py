"""Composable hybrid action spaces for the MEC scheduler.

The paper's MDP acts with a hybrid tuple per UE — discrete split point,
discrete channel, continuous transmit power. This module makes that tuple
*data* instead of code: a :class:`HybridActionSpace` is an ordered set of
named :class:`DiscreteHead`\\ s (each optionally carrying a per-actor
feasibility mask) plus bounded :class:`ContinuousHead`\\ s, with generic
``init_heads / forward / sample / log_prob / entropy / execute`` that
``nets.py`` and ``mahppo.py`` consume without knowing any head by name.

Actions travel as a flat dict pytree ``{head.name: array}`` — the same
structure the env's ``step`` takes — so adding a decision dimension is a
one-line change to the env's space, not a five-file plumbing job.

HOW TO ADD A HEAD
-----------------
1. Append a ``DiscreteHead(name, n)`` (or ``ContinuousHead(name, low,
   high)``) to the tuple the env builds in ``MECEnv.__init__``. Order
   matters only for the PRNG stream: heads are sampled in declaration
   order, discrete before continuous.
2. If only some choices are valid per actor, add a ``(N, n)`` bool mask
   under the head's name to the dict ``MECEnv.action_masks`` returns.
3. Consume ``actions[name]`` in ``MECEnv.step``. Nothing in nets/mahppo
   changes: actors automatically grow a ``(128, 64, n)`` branch (or a
   ``(128, 64, 2)`` (mu, log_std) branch for continuous heads), and
   sampling / log-probs / entropy / PPO losses sum over whatever heads
   exist. This is exactly how the multi-server ``route`` head landed.
4. Alternatively a network can PROVIDE a discrete head's logits itself —
   ``init_heads(..., skip=(name,))`` builds no branch and
   ``forward(..., provided={name: logits})`` injects them (still masked
   identically). That makes the head's width data-dependent: the entity
   policy's route scorer emits one logit per server, so the same
   parameters serve pools of any size E.

All functions are jit/vmap-clean and operate on a SINGLE actor (1-D
logits); callers vmap over actors and environments, mirroring the rest of
the RL stack. The space object itself is static Python (closed over by
jitted functions); only masks/dists/actions are traced.

Continuous heads own their bounds: ``execute`` squashes a pre-squash
Gaussian variable u through ``sigmoid(u) * high`` (the paper's power
parameterization) and ``clip`` clamps physical values into ``[low,
high]`` — the one place bounds are enforced, for the policy path and for
hand-written baselines alike.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

LOG_STD_MIN, LOG_STD_MAX = -3.0, 1.0
_NEG_INF = -1e9


class DiscreteHead(NamedTuple):
    """A categorical decision with ``n`` choices."""
    name: str
    n: int


class ContinuousHead(NamedTuple):
    """A bounded scalar decision. The policy emits (mu, log_std) over a
    pre-squash variable u; ``execute`` maps u -> sigmoid(u) * high and
    ``clip`` clamps physical values to [low, high] (low is the numerical
    floor, e.g. the env's 1e-4 W minimum transmit power)."""
    name: str
    low: float
    high: float

    def squash(self, u):
        return jax.nn.sigmoid(u) * self.high

    def clamp(self, x):
        return jnp.clip(x, self.low, self.high)


def _mask_logits(logits, mask):
    return logits if mask is None else jnp.where(mask, logits, _NEG_INF)


def _take(log_p, idx):
    """log_p[..., idx] for scalar or batched idx (matching shapes)."""
    if log_p.ndim == 1:
        return log_p[..., idx]
    return jnp.take_along_axis(log_p, idx[..., None], -1)[..., 0]


@dataclasses.dataclass(frozen=True)
class HybridActionSpace:
    """Ordered discrete + continuous heads, with optional per-actor
    feasibility masks (``{name: (N, n) bool}``) for discrete heads. Heads
    are sampled (and PRNG keys consumed) in declaration order, all
    discrete heads first.

    ``masks`` is the declarative FLEET-level feasibility (one row per
    actor) that ``MECEnv.action_masks`` serves from; it is deliberately
    NOT auto-applied by the per-actor ``forward``/``sample``/``mode``
    below — those take the single actor's ``{name: (n,)}`` slice via
    their ``masks`` argument (vmapped over actors, and state-dependent on
    dynamic fleets), exactly as mahppo threads it."""
    discrete: Tuple[DiscreteHead, ...]
    continuous: Tuple[ContinuousHead, ...]
    masks: Dict[str, jnp.ndarray] = dataclasses.field(default_factory=dict)

    @property
    def heads(self):
        return self.discrete + self.continuous

    @property
    def names(self):
        return tuple(h.name for h in self.heads)

    def head(self, name):
        for h in self.heads:
            if h.name == name:
                return h
        raise KeyError(f"no head named {name!r}; have {self.names}")

    def __post_init__(self):
        for h in self.discrete:
            if not isinstance(h, DiscreteHead):
                raise TypeError(f"discrete entries must be DiscreteHead, "
                                f"got {h!r} (missing trailing comma in a "
                                f"1-tuple?)")
        for h in self.continuous:
            if not isinstance(h, ContinuousHead):
                raise TypeError(f"continuous entries must be "
                                f"ContinuousHead, got {h!r}")
        names = [h.name for h in self.heads]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate head names: {names}")
        for name in self.masks:
            h = self.head(name)
            if not isinstance(h, DiscreteHead):
                raise ValueError(f"mask on non-discrete head {name!r}")

    def actor_mask(self, masks, name):
        """This-actor mask for head `name` from a {name: (n,)} dict."""
        if masks is None:
            return None
        return masks.get(name)

    def broadcast_masks(self, masks, n_actors):
        """Complete per-actor mask dict {head: (n_actors, n) bool} for
        EVERY discrete head: heads without an entry get all-True rows and
        single-actor (n,) rows are broadcast across the fleet. The uniform
        pytree is what lets a weight-shared actor be vmapped over actor
        rows with ``in_axes=(0, 0)`` — no special-casing of which heads
        happen to carry feasibility."""
        out = {}
        for h in self.discrete:
            m = None if masks is None else masks.get(h.name)
            if m is None:
                out[h.name] = jnp.ones((n_actors, h.n), bool)
            else:
                # unconditional: a no-op for correctly shaped (N, n) masks
                # and an immediate, clearly-located shape error for stale
                # ones (e.g. a 4-actor mask reused on an 8-UE env)
                out[h.name] = jnp.broadcast_to(jnp.asarray(m),
                                               (n_actors, h.n))
        return out

    # ------------------------------------------------------------ network
    def init_heads(self, key, feat_dim, mlp_init, skip=()):
        """One output branch per head: (feat_dim, 64, n) logits for a
        discrete head, (feat_dim, 64, 2) (mu, raw_log_std) for a
        continuous one. `key` is either a single PRNG key (split
        internally) or a stacked (n_heads, 2) key array — callers that
        must preserve an existing key stream pass the stack.

        ``skip``: head names whose logits the network PROVIDES itself
        (see `forward`'s ``provided``) — no fixed-width branch is built
        for them, which is how a head's width can be data-dependent (the
        entity policy's route scorer emits one logit per server, so E is
        free at inference time)."""
        heads = [h for h in self.heads if h.name not in skip]
        keys = key if key.ndim == 2 else jax.random.split(key, len(heads))
        out = {}
        for h, k in zip(heads, keys):
            width = h.n if isinstance(h, DiscreteHead) else 2
            out[h.name] = mlp_init(k, (feat_dim, 64, width))
        return out

    def forward(self, head_params, h, mlp_apply, masks=None, provided=None):
        """Trunk features -> distribution dict: masked logits per discrete
        head, {"mu", "log_std"} per continuous head. ``provided``: {name:
        logits} for heads whose logits the caller computed itself (heads
        skipped at `init_heads`); they still go through the same masking,
        so everything downstream (sample/log_prob/entropy/mode) treats
        provider heads and branch heads identically."""
        dist = {}
        for hd in self.discrete:
            logits = provided[hd.name] if provided \
                and hd.name in provided \
                else mlp_apply(head_params[hd.name], h)
            dist[hd.name] = _mask_logits(logits, self.actor_mask(masks,
                                                                 hd.name))
        for hd in self.continuous:
            mu, raw = jnp.split(mlp_apply(head_params[hd.name], h), 2, -1)
            dist[hd.name] = {"mu": mu[..., 0],
                             "log_std": jnp.clip(raw[..., 0], LOG_STD_MIN,
                                                 LOG_STD_MAX)}
        return dist

    # ------------------------------------------------------- distribution
    def sample(self, key, dist, masks=None):
        """Draw one action per head (keys consumed in head order). Masks
        are re-applied here so infeasible choices are never drawn even
        from raw logits (defense in depth under `forward`'s -1e9)."""
        keys = jax.random.split(key, len(self.heads))
        actions = {}
        for h, k in zip(self.heads, keys):
            if isinstance(h, DiscreteHead):
                logits = _mask_logits(dist[h.name],
                                      self.actor_mask(masks, h.name))
                actions[h.name] = jax.random.categorical(k, logits)
            else:
                d = dist[h.name]
                actions[h.name] = d["mu"] + jnp.exp(d["log_std"]) \
                    * jax.random.normal(k, d["mu"].shape)
        return actions

    def mode(self, dist, masks=None):
        """Deterministic action: masked argmax / mu."""
        actions = {}
        for h in self.discrete:
            m = self.actor_mask(masks, h.name)
            logits = dist[h.name] if m is None else \
                jnp.where(m, dist[h.name], -jnp.inf)
            actions[h.name] = jnp.argmax(logits, -1)
        for h in self.continuous:
            actions[h.name] = dist[h.name]["mu"]
        return actions

    def log_prob(self, dist, actions, active=None):
        """Joint log-prob, summed over heads. `active`: optional
        broadcastable activity weight — an inactive actor contributes
        exactly zero log-prob, so its (ignored-by-the-env) action can't
        steer the policy gradient."""
        out = 0.0
        for h in self.discrete:
            out = out + _take(jax.nn.log_softmax(dist[h.name]),
                              actions[h.name])
        for h in self.continuous:
            d = dist[h.name]
            u, mu, ls = actions[h.name], d["mu"], d["log_std"]
            out = out - 0.5 * ((u - mu) ** 2 / jnp.exp(2 * ls) + 2 * ls
                               + jnp.log(2 * jnp.pi))
        if active is not None:
            out = out * active
        return out

    def entropy(self, dist, active=None):
        """Joint entropy, summed over heads (inactive actors contribute
        zero — no bonus for dithering while off-fleet)."""
        out = 0.0
        for h in self.discrete:
            p = jax.nn.softmax(dist[h.name])
            out = out - jnp.sum(p * jnp.log(p + 1e-12), axis=-1)
        for h in self.continuous:
            out = out + 0.5 * jnp.log(2 * jnp.pi * jnp.e) \
                + dist[h.name]["log_std"]
        if active is not None:
            out = out * active
        return out

    # ----------------------------------------------------------- physical
    def execute(self, actions):
        """Map raw sampled actions to physical ones: continuous heads are
        squashed through their bounds, discrete pass through."""
        out = dict(actions)
        for h in self.continuous:
            out[h.name] = h.squash(actions[h.name])
        return out

    def clip(self, actions):
        """Clamp physical continuous values into each head's [low, high]
        — the single enforcement point for action bounds."""
        out = dict(actions)
        for h in self.continuous:
            out[h.name] = h.clamp(actions[h.name])
        return out
