"""Generalized advantage estimation (paper Eq. 18)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gae(rewards, values, dones, last_value, *, gamma=0.95, lam=0.95):
    """rewards, dones: (T, E); values: (T, E); last_value: (E,).
    Returns (advantages, returns), each (T, E)."""
    def body(carry, xs):
        adv_next, v_next = carry
        r, v, d = xs
        nonterm = 1.0 - d
        delta = r + gamma * v_next * nonterm - v
        adv = delta + gamma * lam * nonterm * adv_next
        return (adv, v), adv

    (_, _), advs = jax.lax.scan(
        body, (jnp.zeros_like(last_value), last_value),
        (rewards, values, dones.astype(rewards.dtype)), reverse=True)
    return advs, advs + values
