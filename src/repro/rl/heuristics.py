"""Non-RL scheduler baselines beyond the paper's Local/JALAD:

* greedy: each UE independently picks argmin_b (t_b + beta * e_b) over ITS
  OWN split table assuming a clean channel (no interference awareness) at
  max power, round-robin channels — what a non-coordinating heuristic would
  do. Heterogeneous fleets naturally get per-UE answers.
* oracle_static: exhaustive search over joint (b, c) assignments (max-power)
  for small N — the best *static* policy; the gap RL closes above it comes
  from state-dependent scheduling. Each UE's b ranges over its own feasible
  set (padded fleet actions are excluded).
"""
from __future__ import annotations

import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.env.channel import channel_gain, uplink_rates
from repro.env.mecenv import MECEnv, per_ue


def _joint_overhead(env: MECEnv, b, c, p, d, active=None):
    """Expected per-task latency/energy for each UE under joint actions.
    `active` (N,) bool: inactive UEs neither transmit nor interfere."""
    prm = env.params
    g = channel_gain(jnp.asarray(d), prm.pathloss)
    l_b = per_ue(prm.l_new, jnp.asarray(b))
    n_b = per_ue(prm.n_new, jnp.asarray(b))
    offl = n_b > 0
    if active is not None:
        offl = offl & jnp.asarray(active)
    r = jnp.maximum(uplink_rates(jnp.asarray(p), jnp.asarray(c), g, offl,
                                 omega=prm.omega, sigma=prm.sigma), 1.0)
    t = l_b + n_b / r
    e = l_b * prm.p_compute + (n_b / r) * jnp.asarray(p)
    return np.asarray(t), np.asarray(e)


def greedy_eval(env: MECEnv, *, d=50.0, active=None):
    """Interference-oblivious greedy (then evaluated WITH interference).
    `active` (N,) bool restricts the report to a dynamic fleet's current
    members; standby UEs are excluded from the means and don't interfere."""
    prm = env.params
    n = prm.n_ue
    beta = float(prm.beta)
    act = np.ones((n,), bool) if active is None else np.asarray(active)
    if not act.any():
        raise ValueError("active mask selects no UE: nothing to score")
    feas = np.asarray(prm.feasible)                 # (N, B+2)
    # clean-channel rate of a lone UE at p_max on channel 0: one value
    # covers every (ue, b) cell, so score the whole table in one shot
    g = channel_gain(jnp.full((1,), d), prm.pathloss)
    r = float(jnp.maximum(uplink_rates(
        jnp.full((1,), prm.p_max), jnp.zeros((1,), jnp.int32), g,
        jnp.asarray([True]), omega=prm.omega, sigma=prm.sigma)[0], 1.0))
    l_new = np.asarray(prm.l_new)
    n_new = np.asarray(prm.n_new)
    t = l_new + n_new / r
    e = (l_new * np.asarray(prm.p_compute)[:, None]
         + n_new / r * float(prm.p_max))
    cost = np.where(feas, t + beta * e, np.inf)
    b = [int(x) for x in np.argmin(cost, axis=1)]
    c = [i % env.n_channels for i in range(n)]
    p = [float(prm.p_max)] * n
    t, e = _joint_overhead(env, b, c, p, [d] * n, active=act)
    return {"b": b, "t_task": float(t[act].mean()),
            "e_task": float(e[act].mean()),
            "overhead": float((t + beta * e)[act].mean())}


def oracle_static_eval(env: MECEnv, *, d=50.0, max_joint=300_000,
                       active=None):
    """Exhaustive joint search over (b, c) per UE at p_max (small N only).
    With `active`, standby UEs are pinned to full-local (inert) and only
    active UEs are searched and scored."""
    prm = env.params
    n = prm.n_ue
    beta = float(prm.beta)
    act = np.ones((n,), bool) if active is None else np.asarray(active)
    if not act.any():
        raise ValueError("active mask selects no UE: nothing to score")
    feas_np = np.asarray(prm.feasible)
    b_local = env.n_actions_b - 1
    per_ue_feas = [list(np.where(feas_np[ue])[0]) if act[ue] else [b_local]
                   for ue in range(n)]
    n_c = env.n_channels
    # inactive UEs don't transmit, so their channel choice is irrelevant:
    # one combo per standby slot, not n_c
    spaces = [len(f) * (n_c if act[ue] else 1)
              for ue, f in enumerate(per_ue_feas)]
    total = math.prod(spaces)                # exact Python int, no overflow
    if total > max_joint:
        raise ValueError(f"joint space too large: {spaces}")
    best = None
    for combo in itertools.product(*(range(sp) for sp in spaces)):
        b = [per_ue_feas[ue][x // n_c if act[ue] else 0]
             for ue, x in enumerate(combo)]
        c = [x % n_c if act[ue] else 0 for ue, x in enumerate(combo)]
        p = [float(prm.p_max)] * n
        t, e = _joint_overhead(env, b, c, p, [d] * n, active=act)
        cost = float((t + beta * e)[act].mean())
        if best is None or cost < best["overhead"]:
            best = {"b": b, "c": c, "t_task": float(t[act].mean()),
                    "e_task": float(e[act].mean()), "overhead": cost}
    return best
