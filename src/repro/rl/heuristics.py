"""Non-RL scheduler baselines beyond the paper's Local/JALAD:

* greedy: each UE independently picks argmin over ITS OWN split table —
  and, on a multi-server env, over (split, server) pairs — assuming a
  clean channel (no interference awareness) at max power, round-robin
  channels (per server) — what a non-coordinating heuristic would do.
  Heterogeneous fleets naturally get per-UE answers.
* oracle_static: exhaustive search over joint (b, c[, e]) assignments
  (max-power) for small N — the best *static* policy; the gap RL closes
  above it comes from state-dependent scheduling. Each UE's b ranges over
  its own feasible set (padded fleet actions are excluded), and on an
  edge pool every server is enumerated per UE.

Simpler fixed-routing policies (nearest-server, load-aware round-robin)
live in repro.rl.baselines.
"""
from __future__ import annotations

import itertools
import math

import jax.numpy as jnp
import numpy as np

from repro.core.overhead import task_latency_energy
from repro.env.mecenv import MECEnv, per_ue


def _joint_overhead(env: MECEnv, b, c, p, d, active=None, route=None):
    """Expected per-task latency/energy for each UE under joint actions
    (the shared Eq. 7/8 closed form, `core.overhead.task_latency_energy`).
    `active` (N,) bool: inactive UEs neither transmit nor interfere.
    `route` (N,) int: target server on a multi-server env (default 0)."""
    prm = env.params
    b = jnp.asarray(b)
    l_b = per_ue(prm.l_new, b)
    n_b = per_ue(prm.n_new, b)
    offl = n_b > 0
    if active is not None:
        offl = offl & jnp.asarray(active)
    e_route = None
    if env.multi_server:
        e_route = jnp.zeros_like(b) if route is None else \
            jnp.asarray(route, jnp.int32)
    r = env._rates(jnp.asarray(d), jnp.asarray(c), jnp.asarray(p), e_route,
                   offl)
    te_eff = None
    if env.multi_server:
        te_eff, _ = env._edge_seconds(b, e_route, offl)
    t, e = task_latency_energy(l_b, n_b, r, prm.p_compute,
                               jnp.asarray(p), te_eff)
    return np.asarray(t), np.asarray(e)


def clean_rate(env: MECEnv, d=50.0, server=None):
    """Clean-channel rate of a lone UE at p_max on channel 0 (of `server`
    on a multi-server env): the rate a non-coordinating heuristic plans
    with."""
    prm = env.params
    if env.multi_server and server is None:
        raise ValueError("multi-server env: pass the target server index")
    pp = jnp.full((1,), prm.p_max)
    cc = jnp.zeros((1,), jnp.int32)
    tx = jnp.asarray([True])
    if server is None:
        r = env._rates(jnp.full((1,), d), cc, pp, None, tx)
    else:
        r = env._rates(jnp.full((1,), d), cc, pp,
                       jnp.full((1,), server, jnp.int32), tx)
    return float(r[0])


def _clean_cost_table(env: MECEnv, d=50.0):
    """(N, B+2) single-server — or (N, B+2, E) multi-server — per-task
    cost t + beta*e of each (ue, split[, server]) cell under a clean
    channel at p_max; infeasible cells are +inf."""
    prm = env.params
    beta = float(prm.beta)
    feas = np.asarray(prm.feasible)
    l_new = np.asarray(prm.l_new)
    n_new = np.asarray(prm.n_new)
    p_comp = np.asarray(prm.p_compute)[:, None]
    p_max = float(prm.p_max)

    def cell_cost(r, t_extra=0.0):
        t = l_new + n_new / r + t_extra
        e = l_new * p_comp + n_new / r * p_max
        return np.where(feas, t + beta * e, np.inf)

    if not env.multi_server:
        return cell_cost(clean_rate(env, d))
    te = np.asarray(prm.t_edge)                       # (N, B+2, E)
    return np.stack([cell_cost(clean_rate(env, d, e), te[:, :, e])
                     for e in range(env.n_servers)], axis=-1)


def _round_robin_channels(route, n_channels):
    """Round-robin channel assignment within each UE's target server."""
    counts = {}
    c = []
    for e in route:
        c.append(counts.get(e, 0) % n_channels)
        counts[e] = counts.get(e, 0) + 1
    return c


def greedy_eval(env: MECEnv, *, d=50.0, active=None):
    """Interference-oblivious greedy (then evaluated WITH interference).
    On an edge pool each UE picks its best (split, server) pair — servers
    scored by their clean-channel rate and (processor-sharing-free) edge
    service time. `active` (N,) bool restricts the report to a dynamic
    fleet's current members; standby UEs are excluded from the means and
    don't interfere."""
    prm = env.params
    n = prm.n_ue
    beta = float(prm.beta)
    act = np.ones((n,), bool) if active is None else np.asarray(active)
    if not act.any():
        raise ValueError("active mask selects no UE: nothing to score")
    cost = _clean_cost_table(env, d)
    route = None
    if env.multi_server:
        flat = cost.reshape(n, -1).argmin(axis=1)     # over (b, e) pairs
        b = [int(x) for x in flat // env.n_servers]
        route = [int(x) for x in flat % env.n_servers]
        c = _round_robin_channels(route, env.n_channels)
    else:
        b = [int(x) for x in np.argmin(cost, axis=1)]
        c = [i % env.n_channels for i in range(n)]
    p = [float(prm.p_max)] * n
    t, e = _joint_overhead(env, b, c, p, [d] * n, active=act, route=route)
    out = {"b": b, "t_task": float(t[act].mean()),
           "e_task": float(e[act].mean()),
           "overhead": float((t + beta * e)[act].mean())}
    if route is not None:
        out["route"] = route
    return out


def oracle_static_eval(env: MECEnv, *, d=50.0, max_joint=300_000,
                       active=None):
    """Exhaustive joint search over (b, c[, e]) per UE at p_max (small N
    only). With `active`, standby UEs are pinned to full-local (inert)
    and only active UEs are searched and scored."""
    prm = env.params
    n = prm.n_ue
    beta = float(prm.beta)
    act = np.ones((n,), bool) if active is None else np.asarray(active)
    if not act.any():
        raise ValueError("active mask selects no UE: nothing to score")
    feas_np = np.asarray(prm.feasible)
    b_local = env.n_actions_b - 1
    per_ue_feas = [list(np.where(feas_np[ue])[0]) if act[ue] else [b_local]
                   for ue in range(n)]
    n_c = env.n_channels
    n_e = env.n_servers
    n_ce = n_c * n_e
    # inactive UEs don't transmit, so their channel/server choice is
    # irrelevant: one combo per standby slot, not n_c * n_e
    spaces = [len(f) * (n_ce if act[ue] else 1)
              for ue, f in enumerate(per_ue_feas)]
    total = math.prod(spaces)                # exact Python int, no overflow
    if total > max_joint:
        raise ValueError(f"joint space too large: {spaces}")
    best = None
    for combo in itertools.product(*(range(sp) for sp in spaces)):
        b = [per_ue_feas[ue][x // n_ce if act[ue] else 0]
             for ue, x in enumerate(combo)]
        c = [(x % n_ce) // n_e if act[ue] else 0
             for ue, x in enumerate(combo)]
        e = [x % n_e if act[ue] else 0 for ue, x in enumerate(combo)]
        p = [float(prm.p_max)] * n
        t, en = _joint_overhead(env, b, c, p, [d] * n, active=act,
                                route=e if env.multi_server else None)
        cost = float((t + beta * en)[act].mean())
        if best is None or cost < best["overhead"]:
            best = {"b": b, "c": c, "t_task": float(t[act].mean()),
                    "e_task": float(en[act].mean()), "overhead": cost}
            if env.multi_server:
                best["route"] = e
    return best
