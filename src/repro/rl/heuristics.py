"""Non-RL scheduler baselines beyond the paper's Local/JALAD:

* greedy: each UE independently picks argmin_b (t_b + beta * e_b) assuming a
  clean channel (no interference awareness) at max power, round-robin
  channels — what a non-coordinating heuristic would do.
* oracle_static: exhaustive search over joint (b, c) assignments (max-power)
  for small N — the best *static* policy; the gap RL closes above it comes
  from state-dependent scheduling.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.env.channel import channel_gain, uplink_rates
from repro.env.mecenv import MECEnv


def _joint_overhead(env: MECEnv, b, c, p, d):
    """Expected per-task latency/energy for each UE under joint actions."""
    prm = env.params
    g = channel_gain(jnp.asarray(d), prm.pathloss)
    offl = prm.n_new[jnp.asarray(b)] > 0
    r = jnp.maximum(uplink_rates(jnp.asarray(p), jnp.asarray(c), g, offl,
                                 omega=prm.omega, sigma=prm.sigma), 1.0)
    t = prm.l_new[jnp.asarray(b)] + prm.n_new[jnp.asarray(b)] / r
    e = (prm.l_new[jnp.asarray(b)] * prm.p_compute
         + (prm.n_new[jnp.asarray(b)] / r) * jnp.asarray(p))
    return np.asarray(t), np.asarray(e)


def greedy_eval(env: MECEnv, *, d=50.0):
    """Interference-oblivious greedy (then evaluated WITH interference)."""
    prm = env.params
    n = prm.n_ue
    beta = float(prm.beta)
    feas = np.asarray(prm.feasible)
    # single-UE clean-channel overhead per b at p_max
    g = channel_gain(jnp.full((1,), d), prm.pathloss)
    best_b, best_cost = 0, np.inf
    for b in range(len(feas)):
        if not feas[b]:
            continue
        r = float(jnp.maximum(uplink_rates(
            jnp.full((1,), prm.p_max), jnp.zeros((1,), jnp.int32), g,
            jnp.asarray([prm.n_new[b] > 0]), omega=prm.omega,
            sigma=prm.sigma)[0], 1.0))
        t = float(prm.l_new[b]) + float(prm.n_new[b]) / r
        e = (float(prm.l_new[b]) * float(prm.p_compute)
             + float(prm.n_new[b]) / r * float(prm.p_max))
        cost = t + beta * e
        if cost < best_cost:
            best_b, best_cost = b, cost
    b = [best_b] * n
    c = [i % env.n_channels for i in range(n)]
    p = [float(prm.p_max)] * n
    t, e = _joint_overhead(env, b, c, p, [d] * n)
    return {"b": b, "t_task": float(t.mean()), "e_task": float(e.mean()),
            "overhead": float((t + beta * e).mean())}


def oracle_static_eval(env: MECEnv, *, d=50.0, max_joint=300_000):
    """Exhaustive joint search over (b, c) per UE at p_max (small N only)."""
    prm = env.params
    n = prm.n_ue
    beta = float(prm.beta)
    feas = [i for i in range(len(np.asarray(prm.feasible)))
            if bool(prm.feasible[i])]
    n_c = env.n_channels
    space = len(feas) * n_c
    if space ** n > max_joint:
        raise ValueError(f"joint space too large: {space}^{n}")
    best = None
    for combo in itertools.product(range(space), repeat=n):
        b = [feas[x // n_c] for x in combo]
        c = [x % n_c for x in combo]
        p = [float(prm.p_max)] * n
        t, e = _joint_overhead(env, b, c, p, [d] * n)
        cost = float((t + beta * e).mean())
        if best is None or cost < best["overhead"]:
            best = {"b": b, "c": c, "t_task": float(t.mean()),
                    "e_task": float(e.mean()), "overhead": cost}
    return best
