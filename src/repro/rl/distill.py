"""Train-big/serve-small: distill the entity policy into a flat trunk.

At production scale the scheduler is itself a serving workload: the
policy prices a dispatch decision for every task arrival, so its own
forward latency sits on the hot path of every Eq. 7/8 service. The
entity policy earns its cost at TRAINING time — permutation-equivariant
pair scoring is what generalizes across fleets and randomized pools —
but a deployment serves ONE pool, where that generality is pure
overhead. This module converts the trained teacher into a deployment
student: a small flat MLP (``nets.init_flat_trunk``) over
``observe_per_ue``'s constant-width rows that emits every action head in
one fused pass, optionally int8 weight-quantized for the fused
dequant-matmul serving kernel (``kernels/flat_trunk.py``).

The distillation is the same DAgger-style machinery as
``rl.streaming``: roll out episodes (round 0 under the sampled teacher,
later rounds under the sampled *student* so training visits the states
the student will actually induce), label every visited state with
actions SAMPLED from the teacher's distribution (``label_samples`` draws
per state — a Monte-Carlo cross-entropy whose minimizer is the teacher's
per-state distribution, i.e. KL matching through the space's generic
``log_prob`` path, continuous heads included), aggregate the dataset
across rounds, and fit with full-batch adamw epochs. On states whose
per-UE rows alias teacher-distinguishable entity views the student
learns the label marginals — exactly the property the sampling
deployment mode (``TrunkDispatcher``) turns into load spreading.

Fixed-fleet, fixed-pool by design: the student trades the teacher's
any-N/any-E transfer for microsecond batch-1 latency on the deployment
pool (the route head is a fixed-width slice). Distill against the env
you will serve.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.env.mecenv import MECEnv
from repro.optim import adamw_init, adamw_update
from repro.rl import nets


@dataclasses.dataclass(frozen=True)
class DistillConfig:
    """``iterations`` DAgger rounds of ``n_envs`` x ``frames`` rollout
    states each; every round refits on the aggregated dataset for
    ``epochs`` full-batch adamw steps. ``label_samples`` teacher draws
    per state set the Monte-Carlo resolution of the KL match."""
    iterations: int = 3
    frames: int = 64
    n_envs: int = 4
    label_samples: int = 4
    epochs: int = 80
    lr: float = 3e-3
    hidden: tuple = (64, 64)


def _const_masks(env: MECEnv):
    """The complete per-actor mask dict of a STATIC fleet (state-
    independent, so the training set need not store per-state masks)."""
    if env.dynamic:
        raise ValueError("distillation targets a fixed deployment fleet; "
                         "dynamic-churn envs have state-dependent masks")
    s0 = env.reset(jax.random.PRNGKey(0))
    return env.action_space.broadcast_masks(env.action_masks(s0),
                                            env.params.n_ue)


def _make_collect(env: MECEnv, teacher, cfg: DistillConfig, *,
                  use_student: bool):
    """jit(vmap(episode)): (keys (E,), student) -> (rows (E, T, N, F),
    labels {head: (E, T, S, N)}) — per-UE feature rows of every visited
    state plus ``label_samples`` teacher action draws for each."""
    space = env.action_space
    n_ue = env.params.n_ue
    t_actor = teacher["entity_actor"]

    def episode(key, student):
        kr, ks = jax.random.split(key)
        s = env.reset(kr)

        def body(carry, sub):
            s = carry
            masks = space.broadcast_masks(env.action_masks(s), n_ue)
            tdist = nets.entity_actor_forward(t_actor, space,
                                              env.observe_entities(s),
                                              masks)
            k_lab, k_act = jax.random.split(sub)
            lab_keys = jax.vmap(lambda k: jax.random.split(k, n_ue))(
                jax.random.split(k_lab, cfg.label_samples))
            labels = jax.vmap(
                lambda kk: jax.vmap(space.sample)(kk, tdist, masks))(
                    lab_keys)
            if use_student:
                bdist = nets.flat_trunk_forward(
                    student, space, env.observe_per_ue(s), masks)
            else:
                bdist = tdist
            raw = jax.vmap(space.sample)(jax.random.split(k_act, n_ue),
                                         bdist, masks)
            s2, _, _, _ = env.step(s, space.execute(raw))
            return s2, (env.observe_per_ue(s), labels)

        _, out = jax.lax.scan(body, s, jax.random.split(ks, cfg.frames))
        return out

    return jax.jit(jax.vmap(episode, in_axes=(0, None)))


def distill_entity_policy(env: MECEnv, teacher, cfg: DistillConfig = None,
                          *, seed=0, log_cb=None):
    """Distill an entity ``teacher`` ({"entity_actor": ...}) into a flat
    trunk student on the deployment ``env``. Returns (student params for
    ``nets.flat_trunk_forward``, history); each history row reports the
    round's final distillation loss (mean negative label log-prob) and
    the student-vs-teacher mode agreement on that round's fresh states."""
    if "entity_actor" not in teacher:
        raise ValueError("distillation needs an entity teacher "
                         "({'entity_actor': ...}); train with "
                         "MAHPPOConfig(entity_policy=True)")
    cfg = cfg or DistillConfig()
    space = env.action_space
    masks0 = _const_masks(env)
    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    obs_dim = int(env.observe_per_ue(env.reset(k_init)).shape[-1])
    student = nets.init_flat_trunk(k_init, obs_dim, space,
                                   hidden=cfg.hidden)

    collect_t = _make_collect(env, teacher, cfg, use_student=False)
    collect_s = _make_collect(env, teacher, cfg, use_student=True)

    def loss_fn(p, rows, labels):
        # rows: (M, N, F); labels: {head: (M, S, N)}
        def one(r, lab):
            dist = nets.flat_trunk_forward(p, space, r, masks0)
            lp = jax.vmap(
                lambda l: jax.vmap(space.log_prob)(dist, l))(lab)
            return lp.mean()

        return -jax.vmap(one)(rows, labels).mean()

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    opt = adamw_init(student)
    rows_all, labels_all = None, None
    history = []
    for it in range(cfg.iterations):
        key, k_roll = jax.random.split(key)
        collect = collect_t if it == 0 else collect_s
        rows, labels = collect(jax.random.split(k_roll, cfg.n_envs),
                               student)
        flat = lambda x: x.reshape((-1,) + x.shape[2:])
        rows = flat(rows)                       # (E*T, N, F)
        labels = jax.tree.map(flat, labels)     # {h: (E*T, S, N)}
        if rows_all is None:
            rows_all, labels_all = rows, labels
        else:
            rows_all = jnp.concatenate([rows_all, rows])
            labels_all = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b]), labels_all, labels)
        loss = np.inf
        for _ in range(cfg.epochs):
            loss, g = grad_fn(student, rows_all, labels_all)
            student, opt = adamw_update(g, opt, student, cfg.lr,
                                        weight_decay=0.0)
        agree = action_agreement(env, teacher, student,
                                 states=min(128, rows.shape[0]),
                                 seed=seed + 1000 + it)
        row = {"iteration": it, "states": int(rows_all.shape[0]),
               "loss": float(loss), "agreement": agree["all"]}
        history.append(row)
        if log_cb:
            log_cb(row)
    return student, history


def action_agreement(env: MECEnv, teacher, student, *, states=256,
                     seed=0):
    """Deterministic-mode agreement between teacher and student on
    held-out states visited under the SAMPLED teacher: per-discrete-head
    match fractions over (state, UE) slots, their conjunction ("all"),
    and the mean absolute squashed-power gap ("power_gap")."""
    space = env.action_space
    n_ue = env.params.n_ue
    t_actor = teacher["entity_actor"]
    frames = (states + n_ue - 1) // max(n_ue, 1)

    def rollout(key):
        s = env.reset(key)

        def body(carry, sub):
            s = carry
            masks = space.broadcast_masks(env.action_masks(s), n_ue)
            tdist = nets.entity_actor_forward(t_actor, space,
                                              env.observe_entities(s),
                                              masks)
            sdist = nets.flat_trunk_forward(student, space,
                                            env.observe_per_ue(s), masks)
            t_raw = jax.vmap(space.mode)(tdist, masks)
            s_raw = jax.vmap(space.mode)(sdist, masks)
            raw = jax.vmap(space.sample)(jax.random.split(sub, n_ue),
                                         tdist, masks)
            s2, _, _, _ = env.step(s, space.execute(raw))
            t_phys, s_phys = space.execute(t_raw), space.execute(s_raw)
            match = {h.name: t_raw[h.name] == s_raw[h.name]
                     for h in space.discrete}
            gaps = [jnp.abs(t_phys[h.name] - s_phys[h.name])
                    for h in space.continuous]
            return s2, (match, sum(gaps))

        _, (match, gap) = jax.lax.scan(body, s,
                                       jax.random.split(key, frames))
        return match, gap

    match, gap = jax.jit(rollout)(jax.random.PRNGKey(seed))
    out = {h.name: float(jnp.mean(match[h.name]))
           for h in space.discrete}
    both = None
    for h in space.discrete:
        both = match[h.name] if both is None else both & match[h.name]
    out["all"] = float(jnp.mean(both))
    out["power_gap"] = float(jnp.mean(gap))
    return out


def quantize_flat_trunk(p, bits=8):
    """Per-layer min-max int8 weight quantization of the f32 student
    (paper Eq. 1 applied to WEIGHTS: one (mn, mx) calibration pair per
    layer, via the same ``kernels.ops.quantize`` codes the feature
    compressor uses). Biases stay f32 — they are O(width) against the
    weights' O(width^2). The result feeds ``nets.flat_trunk_forward``
    (which routes through the fused dequant-matmul kernel) and
    ``stream.adapter.TrunkDispatcher``; ``bits`` rides along as static
    bookkeeping."""
    from repro.kernels import ops as kops
    qlayers = []
    for layer in p["layers"]:
        w = layer["w"]
        mn = jnp.asarray(jnp.min(w), jnp.float32)
        mx = jnp.asarray(jnp.max(w), jnp.float32)
        qlayers.append({"codes": kops.quantize(w, mn, mx, bits=bits),
                        "mn": mn, "mx": mx,
                        "b": jnp.asarray(layer["b"], jnp.float32)})
    return {"qlayers": qlayers, "bits": int(bits)}
