"""Streaming fine-tune: distill the occupancy-aware dispatch oracle.

MAHPPO trains the entity policy on the frame-synchronous MDP with the
Eq. 12 mean-overhead reward; deployment serves a *stream* judged on
deadline misses and p99 tails (``stream.qos``). The regimes genuinely
differ: in the frame MDP every UE transmits every frame, so the trained
equilibrium is interference-limited (conservative power, mid splits),
while a stream at serving loads is mostly collision-free — the frame
policy's zero-shot QoS is honest but poor, and adapting it is the point
of this module.

Two structural facts shape the method. First, score-function RL over
stream episodes has congestion-confounded credit: once a queue builds,
every decision made inside it inherits a terrible outcome whatever the
action, so whole-episode AND per-task REINFORCE both reduce to noise
exactly in the regime that needs fixing. Second, the frame observation
(``observe_entities`` over the bridged ``EnvState``) cannot even
represent live channel/server occupancy — the frame MDP has no such
concept — so no gradient signal could make the policy condition on it.

So the fine-tune is DAgger-style distillation instead: roll out the
SAMPLED entity policy as the live dispatcher, label every visited state
with the action of :class:`~repro.stream.adapter.StreamOracleDispatcher`
— the per-dispatch sweep that prices every feasible (split, channel,
server, power) candidate under the live interference and
processor-sharing load — and fit the actor to the labels through the
same ``entity_actor_forward`` + ``HybridActionSpace.log_prob`` path the
frame trainer differentiates (weighted to the deciding UE; continuous
labels pulled back through the sigmoid squash). Aggregating datasets
across iterations is classic DAgger; the supervised signal is immune to
the credit confounding above. Where the oracle's occupancy-dependent
choices hit states the observation aliases, the distilled policy learns
the label *marginals* — and the deployed dispatcher SAMPLES, so that
distribution becomes randomized load-spreading (the blind analog of
power-of-two-choices) rather than a deterministic pile-up. The one live
signal the runtime exposes to EVERY dispatcher — channel occupancy on
the chosen server, the same ``least_loaded_channel`` peek the
greedy/nearest baselines take at dispatch time — is applied as a
dispatch-time override (``live_channel=True``) in both the rollouts
here and deployment, so the policy owns exactly the heads the
baselines don't read from the runtime: split, power, and route.

Every iteration is scored by ``stream_reward`` over its rollout
episodes and the best-scoring actor (the frame-trained zero-shot
weights included) is returned — only the actor adapts; the frame critic
has no streaming value target and rides along untouched.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.env.mecenv import MECEnv
from repro.optim import adamw_init, adamw_update
from repro.rl import nets
from repro.stream.adapter import (EntityDispatcher, StreamOracleDispatcher,
                                  stream_env_state)
from repro.stream.events import StreamParams, StreamSim
from repro.stream.qos import StreamRewardConfig, stream_reward


@dataclasses.dataclass(frozen=True)
class StreamTuneConfig:
    """``epochs`` adamw steps per iteration over the aggregated (all
    iterations so far) labeled dataset — supervised, so sample reuse is
    free, unlike a policy gradient's."""
    iterations: int = 6
    episodes_per_iter: int = 2
    epochs: int = 10
    lr: float = 3e-3
    reward: StreamRewardConfig = StreamRewardConfig()


def _episode_logp(env: MECEnv, params, states, raws, w):
    """Differentiable weighted sum over T stacked decisions of the
    deciding UE's joint log-prob of ``raws`` (here: oracle labels).
    ``w``: (T, N), the deciding UE's one-hot (zero on padding)."""
    space = env.action_space
    n_ue = env.params.n_ue

    def one(s, raw, wt):
        masks = space.broadcast_masks(env.action_masks(s), n_ue)
        dist = nets.entity_actor_forward(params, space,
                                         env.observe_entities(s), masks)
        lp = jax.vmap(space.log_prob)(dist, raw)
        return (lp * wt).sum()

    return jax.vmap(one)(states, raws, w).sum()


def _bucket(n):
    """Smallest power of two >= n: stream episodes vary in decision
    count, and padding to buckets keeps the jitted grad fn at O(log T)
    distinct shapes instead of one retrace per episode."""
    b = 1
    while b < n:
        b *= 2
    return b


def _stack_decisions(env: MECEnv, decisions):
    """(states, labels, weights) pytrees stacked over one episode's
    (EnvState, label dict, ue) records, padded to a power-of-two length
    with repeats of the first record under ZERO weight."""
    t = len(decisions)
    pad = _bucket(t) - t
    decisions = decisions + [decisions[0]] * pad
    states = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[d[0] for d in decisions])
    labels = jax.tree.map(lambda *xs: jnp.stack(xs),
                          *[d[1] for d in decisions])
    w = np.eye(env.params.n_ue, dtype=np.float32)[
        [d[2] for d in decisions]]
    if pad:
        w[t:] = 0.0
    return states, labels, jnp.asarray(w)


class _DaggerDispatcher:
    """Acts with the SAMPLED entity policy (the deployment mode — its
    randomness is what load-spreads on occupancy-aliased states) while
    labeling every visited state with the oracle's action."""

    def __init__(self, env, agent, oracle, label_raw, seed):
        self.inner = EntityDispatcher(env, agent, deterministic=False,
                                      live_channel=True, seed=seed)
        self.oracle = oracle
        self.label_raw = label_raw
        self.data = []               # (EnvState, label raw dict, ue)

    def __call__(self, core, ue):
        s = stream_env_state(core)
        self.data.append((s, self.label_raw(self.oracle(core, ue)), ue))
        return self.inner(core, ue)


def finetune_streaming(env: MECEnv, agent, sp=None,
                       cfg: StreamTuneConfig = None, *, seed=0,
                       log_cb=None):
    """Adapt a frame-trained entity ``agent`` to the stream scenario
    ``sp`` — a single :class:`StreamParams` or a sequence of them, cycled
    across each iteration's episodes so one fine-tune covers several load
    points (the oracle's labels are load-dependent: it spreads servers
    harder at saturation, so training only at mid load undertrains
    exactly the regime the saturation gate scores). Returns (agent,
    history); each history row carries the iteration's mean episode
    reward and QoS aggregates, measured on the rollouts of the actor the
    row's update starts from."""
    sps = (sp if isinstance(sp, (list, tuple)) else
           [sp or StreamParams()])
    cfg = cfg or StreamTuneConfig()
    t0 = float(env.params.t0)
    actor = agent["entity_actor"]
    opt = adamw_init(actor)
    oracle = StreamOracleDispatcher(
        env, tail_weight=cfg.reward.tail_weight,
        energy_weight=cfg.reward.energy_weight)
    space = env.action_space
    n_ue = env.params.n_ue

    def label_raw(lab):
        """Physical oracle action (deciding UE) -> full-(N,) raw pytree
        for ``log_prob``: discrete indices pass through, continuous pull
        back through the sigmoid squash (u = logit(p / high))."""
        out = {}
        for h in space.discrete:
            out[h.name] = jnp.full((n_ue,), int(lab.get(h.name, 0)),
                                   jnp.int32)
        for h in space.continuous:
            frac = float(np.clip(lab[h.name] / h.high, 1e-4, 1 - 1e-4))
            out[h.name] = jnp.full((n_ue,), np.log(frac / (1.0 - frac)),
                                   jnp.float32)
        return out

    grad_fn = jax.jit(jax.grad(
        lambda p, st, raw, w: -_episode_logp(env, p, st, raw, w)))

    history = []
    batches = []                     # DAgger: aggregate across iterations
    best = (-np.inf, actor)
    ep_seed = seed
    for it in range(cfg.iterations):
        rewards, reports = [], []
        for ep in range(cfg.episodes_per_iter):
            ep_seed += 1
            disp = _DaggerDispatcher(env, {**agent, "entity_actor": actor},
                                     oracle, label_raw, ep_seed)
            rep = StreamSim(env, disp, sps[ep % len(sps)],
                            seed=ep_seed).run()
            reports.append(rep)
            rewards.append(stream_reward(rep, cfg.reward, t0=t0))
            if disp.data:
                batches.append(_stack_decisions(env, disp.data))
        r_mean = float(np.mean(rewards))
        if r_mean > best[0]:
            best = (r_mean, actor)
        denom = sum(float(b[2].sum()) for b in batches) or 1.0
        before = actor
        for _ in range(cfg.epochs if batches else 0):
            grads = None
            for st, raw, w in batches:
                g = grad_fn(actor, st, raw, w / denom)
                grads = g if grads is None \
                    else jax.tree.map(jnp.add, grads, g)
            actor, opt = adamw_update(grads, opt, actor, cfg.lr,
                                      weight_decay=0.0)
        row = {"iteration": it, "reward_mean": r_mean,
               "miss_rate": float(np.mean([r["miss_rate"]
                                           for r in reports])),
               "p99": float(np.mean([r["sojourn_p99"] for r in reports])),
               # how far this iteration's distillation moved the actor —
               # 0.0 means the update was a no-op (no decisions labeled)
               "actor_delta": max((float(jnp.abs(a - b).max()) for a, b in
                                   zip(jax.tree.leaves(actor),
                                       jax.tree.leaves(before))),
                                  default=0.0)}
        history.append(row)
        if log_cb:
            log_cb(row)

    # the last update is never scored inside the loop — score it, then
    # return the best actor seen (zero-shot weights included)
    rewards = []
    for ep in range(cfg.episodes_per_iter):
        ep_seed += 1
        disp = EntityDispatcher(env, {**agent, "entity_actor": actor},
                                deterministic=False, live_channel=True,
                                seed=ep_seed)
        rep = StreamSim(env, disp, sps[ep % len(sps)], seed=ep_seed).run()
        rewards.append(stream_reward(rep, cfg.reward, t0=t0))
    if float(np.mean(rewards)) > best[0]:
        best = (float(np.mean(rewards)), actor)
    return {**agent, "entity_actor": best[1]}, history
