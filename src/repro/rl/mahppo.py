"""MAHPPO (paper §5, Algorithm 1): multi-actor hybrid-action PPO with one
global critic. Fully-jitted iteration: vectorized rollout (lax.scan over the
horizon, vmap over parallel envs) + K-epoch minibatch updates.

Generic over the env's HybridActionSpace: actions are a dict pytree
({head: (..., N) array}) sampled/scored by ``env.action_space`` — no head
is named here, so the single-server (split, channel, power) env and the
multi-server (split, channel, route, power) env train through the same
code path.

Three actor modes, selected by ``MAHPPOConfig.shared_policy`` /
``entity_policy`` (init / sampling / loss / update are generic over all):

* per-UE actors (default): N distinct parameter sets over the flat global
  observation — the paper's setup, bit-for-bit unchanged.
* shared policy: ONE parameter set applied to every UE's featurized
  observation row (``env.observe_per_ue``) via vmap, per-actor feasibility
  masks flowing through unchanged. Parameters are O(1) in the fleet size
  and the feature dimension is independent of N/E, so the trained policy
  transfers zero-shot across fleet sizes, device mixes, and pool layouts
  (benchmarks/bench_generalization.py). The critic pools the feature rows
  (mean over the fleet — permutation-invariant), so the whole agent is
  fleet-size-agnostic.
* entity policy: the structured entity-set observation
  (``env.observe_entities``) through a shared per-server route scorer
  (``nets.entity_actor_forward``) — route logits are computed per (UE,
  server) pair, so the SAME parameters run on pools of any size E
  (train on 2 servers, evaluate zero-shot on 3-4). Pair it with
  ``randomize_pool=True`` (an env built with ``pool_ranges``) so each
  episode draws a fresh pool geometry and the route head actually
  receives pool-feature gradients — single-pool training leaves pool
  features constant, which is why the mean-field shared policy cannot
  transfer across layouts.

Paper defaults: ||M||=1024, B=256, K reuse, gamma=0.95, lambda=0.95,
eps=0.2, zeta=0.001, lr=1e-4.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.env.mecenv import MECEnv
from repro.optim import adamw_init, adamw_update
from repro.rl import nets
from repro.rl.gae import gae


@dataclasses.dataclass(frozen=True)
class MAHPPOConfig:
    horizon: int = 1024          # ||M|| (split across n_envs)
    batch: int = 256
    reuse: int = 10              # K
    gamma: float = 0.95
    lam: float = 0.95
    clip: float = 0.2
    ent_coef: float = 0.001      # zeta
    lr: float = 1e-4
    n_envs: int = 8
    iterations: int = 50
    norm_adv: bool = True
    shared_policy: bool = False  # one weight-shared actor over per-UE rows
    entity_policy: bool = False  # entity-set obs + per-server route scorer
    randomize_pool: bool = False  # resample EdgePool geometry per episode
    n_shards: int = 1            # devices to shard the env axis across
    fused_scorer: bool = False   # fused pair-scorer kernel (entity mode)

    def __post_init__(self):
        if self.shared_policy and self.entity_policy:
            raise ValueError("pick one of shared_policy / entity_policy")
        if self.horizon % self.n_envs != 0:
            # collect() runs T = horizon // n_envs scan steps per env; a
            # non-divisible horizon would silently DROP the remainder
            # frames (horizon=1000, n_envs=8 trains on 1000 - 1000 % 8 =
            # 1000 frames, but horizon=1026 would train on 1024) — make
            # the truncation an error instead of a quiet budget cut
            raise ValueError(
                f"horizon={self.horizon} is not divisible by "
                f"n_envs={self.n_envs}: collect() would silently drop "
                f"the {self.horizon % self.n_envs} remainder frames — "
                f"pick horizon as a multiple of n_envs")
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.n_envs % self.n_shards != 0:
            raise ValueError(
                f"n_envs={self.n_envs} must be divisible by "
                f"n_shards={self.n_shards}: rollouts shard whole envs "
                f"across devices")
        if self.fused_scorer and not self.entity_policy:
            raise ValueError("fused_scorer fuses the entity route "
                             "scorer — set entity_policy=True")
        if self.randomize_pool and not self.entity_policy:
            # flat observations (observe / observe_per_ue) describe the
            # CONSTRUCTION-time pool only; training them on resampled
            # geometry would silently learn from state that contradicts
            # the physics. Only observe_entities follows EnvState.geom.
            raise ValueError("randomize_pool trains on resampled pool "
                             "geometry that only the entity observation "
                             "exposes — set entity_policy=True")


def _env_mesh(n_shards):
    """A 1-D device mesh over the env axis (named "env"). Raises early —
    at trace-fn build time, not inside jit — when the host doesn't expose
    enough devices (on CPU hosts set
    XLA_FLAGS=--xla_force_host_platform_device_count=N before importing
    jax to split the host into N virtual devices)."""
    devs = jax.devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"n_shards={n_shards} but only {len(devs)} device(s) "
            f"visible; on CPU export XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_shards}")
    return Mesh(np.array(devs[:n_shards]), ("env",))


def init_agent(key, env: MECEnv, *, shared_policy=False,
               entity_policy=False):
    """Per-UE actors ({"actors": stacked params}); with ``shared_policy``,
    ONE actor over `env.observe_per_ue` feature rows ({"actor": params})
    with a mean-pooled critic; with ``entity_policy``, the entity-set
    actor + set critic ({"entity_actor": params}) over
    `env.observe_entities` pytrees. The default path's key stream is
    untouched — bit-for-bit the pre-shared-policy init."""
    if shared_policy and entity_policy:
        raise ValueError("pick one of shared_policy / entity_policy")
    ka, kc = jax.random.split(key)
    if entity_policy:
        actor = nets.init_entity_actor(ka, env.entity_dims,
                                       env.action_space)
        critic = nets.init_entity_critic(kc)
        return {"entity_actor": actor, "critic": critic}
    if shared_policy:
        actor = nets.init_actor(ka, env.ue_feat_dim, env.action_space)
        critic = nets.init_critic(kc, env.ue_feat_dim)
        return {"actor": actor, "critic": critic}
    n = env.params.n_ue
    actor_keys = jax.random.split(ka, n)
    actors = jax.vmap(lambda k: nets.init_actor(
        k, env.obs_dim, env.action_space))(actor_keys)
    critic = nets.init_critic(kc, env.obs_dim)
    return {"actors": actors, "critic": critic}


def _policy_all(actors, space, obs, masks):
    """obs: (obs_dim,); masks: {head: (N, n)} per-actor feasibility ->
    per-head distribution stacks with a leading actor axis (N, ...)."""
    return jax.vmap(lambda a, m: nets.actor_forward(a, space, obs, m),
                    in_axes=(0, 0))(actors, masks)


def _sample_all(space, keys, dist, masks, mask_axis=None):
    """keys/dist: (E, N, ...); masks: {head: (N, n)} shared across envs, or
    (E, N, n) leaves when mask_axis=0 (dynamic fleets)."""
    per_env = jax.vmap(space.sample)                # over UEs, masks (N, n)
    return jax.vmap(per_env, in_axes=(0, 0, mask_axis))(keys, dist, masks)


def make_train_fns(env: MECEnv, cfg: MAHPPOConfig):
    space = env.action_space
    masks0 = env.action_masks()                     # {head: (N, n)} per-UE
    n_ue = env.params.n_ue
    shared = cfg.shared_policy
    entity = cfg.entity_policy
    # shared/entity actors are vmapped over actor rows with in_axes=(0, 0),
    # so their mask pytree must be complete (every discrete head, (N, n))
    masks0_full = space.broadcast_masks(masks0, n_ue) \
        if (shared or entity) else None

    def _dist(agent, obs, masks):
        """Per-head distribution stacks (N, ...) for ONE env's observation
        — (obs_dim,) through N per-UE actors, (N, F) feature rows through
        the weight-shared actor, or the entity-set pytree through the
        per-server route scorer."""
        if entity:
            return nets.entity_actor_forward(agent["entity_actor"], space,
                                             obs, masks)
        if shared:
            return nets.shared_actor_forward(agent["actor"], space, obs,
                                             masks)
        return _policy_all(agent["actors"], space, obs, masks)

    def _value(agent, obs):
        """Critic input: the flat global observation, (shared mode) the
        mean-pooled feature rows, or (entity mode) the mean-pooled shared-
        trunk embeddings — permutation-invariant and O(1) in N either
        way."""
        if entity:
            return nets.entity_value_forward(agent["entity_actor"],
                                             agent["critic"], obs)
        return nets.critic_forward(agent["critic"],
                                   obs.mean(axis=0) if shared else obs)

    def _policy_value(agent, obs, masks):
        """Entity-mode (dist, value) in ONE trunk pass — the value head
        reads the same embeddings the scorer routes with, and the jitted
        step pays for one encoder evaluation, not two."""
        return nets.entity_policy_value(agent["entity_actor"],
                                        agent["critic"], space, obs, masks)

    def _observe(states):
        fn = (env.observe_entities_raw if cfg.fused_scorer
              else env.observe_entities) if entity \
            else env.observe_per_ue if shared else env.observe
        return jax.vmap(fn)(states)

    def sample_step(agent, key, states):
        """states: batched EnvState over E envs."""
        obs = _observe(states)      # (E, D) / rows (E, N, F) / entity tree
        n_envs_b = states.k.shape[0]
        active = states.active.astype(jnp.float32)                # (E, N)
        value = None
        if env.dynamic:
            # state-dependent masks: inactive actors pinned to full-local
            masks = jax.vmap(env.action_masks)(states)            # (E,N,n)
            if shared or entity:
                masks = jax.vmap(
                    lambda m: space.broadcast_masks(m, n_ue))(masks)
            if entity:
                dist, value = jax.vmap(
                    lambda o, m: _policy_value(agent, o, m))(obs, masks)
            else:
                dist = jax.vmap(lambda o, m: _dist(agent, o, m))(obs,
                                                                 masks)
        else:
            masks = masks0_full if (shared or entity) else masks0
            if entity:
                dist, value = jax.vmap(
                    lambda o: _policy_value(agent, o, masks))(obs)
            else:
                dist = jax.vmap(lambda o: _dist(agent, o, masks))(obs)
        keys = jax.random.split(key, n_envs_b * n_ue).reshape(
            n_envs_b, n_ue, 2)
        actions = _sample_all(space, keys, dist, masks,
                              mask_axis=0 if env.dynamic else None)
        logp = jax.vmap(jax.vmap(space.log_prob))(dist, actions, active)
        if value is None:
            value = jax.vmap(lambda o: _value(agent, o))(obs)
        phys = space.execute(actions)
        nstates, reward, done, info = jax.vmap(env.step)(states, phys)
        tr = {"obs": obs, "actions": actions, "logp": logp,
              "reward": reward, "done": done, "value": value,
              "active": active,
              "completed": info["completed"], "energy": info["energy"]}
        return nstates, tr

    def collect(agent, key, states):
        T = cfg.horizon // cfg.n_envs

        def body(carry, _):
            states, key = carry
            key, sub = jax.random.split(key)
            states, tr = sample_step(agent, sub, states)
            return (states, key), tr

        (states, key), traj = jax.lax.scan(body, (states, key), None, length=T)
        last_obs = _observe(states)
        last_v = jax.vmap(lambda o: _value(agent, o))(last_obs)
        return states, key, traj, last_v

    # ---- sharded rollouts: the SAME collect body, shard_mapped over the
    # env axis. Each shard folds its mesh index into the rollout key
    # (decorrelated streams without any cross-device key plumbing) and
    # steps only its local n_envs / n_shards envs; auto-reset is already
    # batched inside env.step (a jnp.where over the done mask), so a
    # sharded step never syncs per-env or cross-shard. The update step
    # consumes the env-sharded trajectory as-is — GSPMD inserts the
    # gathers for the fleet-global minibatch draws. Built only when
    # cfg.n_shards > 1: the single-device iteration below traces exactly
    # the pre-sharding graph (key stream included).
    if cfg.n_shards > 1:
        mesh = _env_mesh(cfg.n_shards)

        def _collect_local(agent, key, states):
            key = jax.random.fold_in(key, jax.lax.axis_index("env"))
            states, _, traj, last_v = collect(agent, key, states)
            return states, traj, last_v

        collect_sharded = shard_map(
            _collect_local, mesh=mesh,
            in_specs=(P(), P(), P("env")),
            out_specs=(P("env"), P(None, "env"), P("env")),
            check_rep=False)

    def loss_fn(agent, batch):
        obs, actions = batch["obs"], batch["actions"]
        adv, ret, logp_old = batch["adv"], batch["ret"], batch["logp"]
        act = batch["active"]                                     # (B, N)
        if entity:
            dist, v = jax.vmap(
                lambda o: _policy_value(agent, o, masks0_full))(obs)
        else:
            dist = jax.vmap(lambda o: _dist(
                agent, o, masks0_full if shared else masks0))(obs)
        logp = jax.vmap(jax.vmap(space.log_prob))(dist, actions, act)
        ratio = jnp.exp(logp - logp_old)                          # (B, N)
        a = adv[:, None]
        surr = jnp.minimum(ratio * a,
                           jnp.clip(ratio, 1 - cfg.clip, 1 + cfg.clip) * a)
        ent = jax.vmap(jax.vmap(space.entropy))(dist, act)
        # per-actor mean over the samples where that actor was ACTIVE: dead
        # agents contribute neither surrogate nor entropy, and a mostly-
        # inactive actor's few live samples aren't diluted by its dead ones
        n_act = jnp.maximum(act.sum(axis=0), 1.0)                 # (N,)
        actor_loss = -(((surr * act).sum(axis=0) / n_act).sum()
                       + cfg.ent_coef * ((ent * act).sum(axis=0) / n_act).sum())
        if not entity:
            v = jax.vmap(lambda o: _value(agent, o))(obs)
        critic_loss = jnp.mean((v - ret) ** 2)
        total = actor_loss + critic_loss
        return total, {"actor_loss": actor_loss, "value_loss": critic_loss,
                       "entropy": ent.mean(), "ratio": ratio.mean()}

    def update(agent, opt, key, traj, last_v):
        adv, ret = gae(traj["reward"], traj["value"], traj["done"], last_v,
                       gamma=cfg.gamma, lam=cfg.lam)
        T, E = adv.shape
        M = T * E
        flat = {
            # flatten (T, E) -> M on every obs leaf: the flat (M, D)
            # observation, the shared mode's (M, N, F) rows, and the
            # entity mode's {"ue"/"server"/"edge"} pytree alike
            "obs": jax.tree_util.tree_map(
                lambda x: x.reshape((M,) + x.shape[2:]), traj["obs"]),
            "actions": jax.tree_util.tree_map(
                lambda x: x.reshape(M, n_ue), traj["actions"]),
            "logp": traj["logp"].reshape(M, n_ue),
            "active": traj["active"].reshape(M, n_ue),
            "adv": adv.reshape(M), "ret": ret.reshape(M)}
        if cfg.norm_adv:
            a = flat["adv"]
            flat["adv"] = (a - a.mean()) / (a.std() + 1e-8)
        # replace=False draws can't exceed the population: tiny horizons
        # (M < cfg.batch) clamp the minibatch instead of crashing
        bsz = min(cfg.batch, M)
        n_updates = cfg.reuse * max(M // bsz, 1)

        def epoch_body(carry, sub):
            agent, opt = carry
            idx = jax.random.choice(sub, M, (bsz,), replace=False)
            mb = jax.tree_util.tree_map(lambda x: x[idx], flat)
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(agent, mb)
            agent, opt = adamw_update(grads, opt, agent, cfg.lr,
                                      weight_decay=0.0)
            return (agent, opt), metrics

        keys = jax.random.split(key, n_updates)
        (agent, opt), metrics = jax.lax.scan(epoch_body, (agent, opt), keys)
        metrics = jax.tree_util.tree_map(lambda x: x[-1], metrics)
        return agent, opt, metrics

    @jax.jit
    def iteration(agent, opt, key, states):
        key, k1, k2 = jax.random.split(key, 3)
        if cfg.n_shards > 1:
            states, traj, last_v = collect_sharded(agent, k1, states)
        else:
            states, key, traj, last_v = collect(agent, k1, states)
        agent, opt, metrics = update(agent, opt, k2, traj, last_v)
        metrics = dict(metrics,
                       reward_mean=traj["reward"].mean(),
                       completed=traj["completed"].mean(),
                       energy=traj["energy"].mean())
        return agent, opt, key, states, metrics

    return iteration


def init_states(env: MECEnv, cfg: MAHPPOConfig, key):
    """Batched initial states for training: with ``cfg.randomize_pool``
    every parallel env draws its own pool geometry (and redraws it on
    each auto-reset), so one training run sees n_envs layouts at a time
    instead of one forever."""
    keys = jax.random.split(key, cfg.n_envs)
    if cfg.randomize_pool:
        return jax.vmap(lambda k: env.reset(k, randomize=True))(keys)
    return jax.vmap(env.reset)(keys)


def train_mahppo(env: MECEnv, cfg: MAHPPOConfig, seed=0,
                 log_cb: Callable = None):
    key = jax.random.PRNGKey(seed)
    key, ki, kr = jax.random.split(key, 3)
    agent = init_agent(ki, env, shared_policy=cfg.shared_policy,
                       entity_policy=cfg.entity_policy)
    opt = adamw_init(agent)
    states = init_states(env, cfg, kr)
    iteration = make_train_fns(env, cfg)
    history = []
    for it in range(cfg.iterations):
        agent, opt, key, states, metrics = iteration(agent, opt, key, states)
        rec = {k: float(v) for k, v in metrics.items()}
        rec["iteration"] = it
        rec["env_steps"] = (it + 1) * cfg.horizon
        history.append(rec)
        if log_cb:
            log_cb(rec)
    return agent, history


# ----------------------------------------------------------------- eval
def evaluate_policy(env: MECEnv, agent, *, frames=64, seed=0,
                    deterministic=True, fused_scorer=False, n_envs=1,
                    n_shards=1):
    """Run eval-mode episodes; report per-task latency/energy (Eq. 7/8
    realized under the learned policy) plus cumulative reward. On dynamic
    fleets the per-task overhead is aggregated over ACTIVE UEs only —
    standby slots neither transmit nor weigh into t_task/e_task.

    Dispatches on the agent pytree: a weight-shared agent ({"actor": ...},
    from shared_policy training) is applied to `env.observe_per_ue` rows —
    including envs of a DIFFERENT fleet size or pool layout than it was
    trained on (zero-shot transfer), since the feature dimension is
    N/E-independent. An entity agent ({"entity_actor": ...}) runs on
    `env.observe_entities` pytrees — transferring across pool SIZE too,
    since its route logits are scored per server rather than emitted by a
    fixed-width branch.

    ``n_envs`` > 1 averages over that many independent eval episodes
    (vmapped rollouts, each with its own key); ``n_shards`` > 1
    additionally shard_maps the batch over devices (see `_env_mesh`).
    The default ``n_envs=1`` path traces exactly the single-rollout
    graph. ``fused_scorer`` routes an entity agent through the fused
    pair-scorer kernel (``env.observe_entities_raw``)."""
    space = env.action_space
    n_ue = env.params.n_ue
    shared = "actor" in agent
    entity = "entity_actor" in agent
    # a distilled deployment trunk ({"flat_trunk": ...}, f32 or int8 —
    # see rl/distill.py) evaluates on the same observe_per_ue rows as the
    # shared policy, through one fused MLP pass
    trunk = "flat_trunk" in agent
    if fused_scorer and not entity:
        raise ValueError("fused_scorer needs an entity agent")
    obs_entities = env.observe_entities_raw if fused_scorer \
        else env.observe_entities

    def rollout(key):
        s = env.reset(key, eval_mode=True)

        def body(carry, sub):
            s = carry
            masks = env.action_masks(s)      # state-dependent when dynamic
            if entity:
                masks = space.broadcast_masks(masks, n_ue)
                dist = nets.entity_actor_forward(
                    agent["entity_actor"], space, obs_entities(s), masks)
            elif trunk:
                masks = space.broadcast_masks(masks, n_ue)
                dist = nets.flat_trunk_forward(
                    agent["flat_trunk"], space, env.observe_per_ue(s),
                    masks)
            elif shared:
                masks = space.broadcast_masks(masks, n_ue)
                dist = nets.shared_actor_forward(
                    agent["actor"], space, env.observe_per_ue(s), masks)
            else:
                dist = _policy_all(agent["actors"], space, env.observe(s),
                                   masks)
            if deterministic:
                actions = jax.vmap(space.mode)(dist, masks)
            else:
                actions = jax.vmap(space.sample)(
                    jax.random.split(sub, n_ue), dist, masks)
            phys = space.execute(actions)
            s2, reward, done, info = env.step(s, phys)
            # realized per-task overhead under this frame's interference
            t_task, e_task = env.task_overhead(s, phys)
            # completion-weighted per-task overhead: a UE finishing 18 fast
            # offloaded tasks counts 18x, one slow local task counts once.
            # Inactive UEs carry zero weight.
            w = jnp.where(t_task > 0, env.params.t0 / t_task, 0.0) \
                * (s.k > 0) * s.active
            return s2, {"reward": reward,
                        "t_sum": (t_task * w).sum(), "e_sum": (e_task * w).sum(),
                        "w_sum": w.sum(), "completed": info["completed"],
                        "n_active": info["n_active"], "done": done}

        _, out = jax.lax.scan(body, s, jax.random.split(key, frames))
        return out

    if n_envs == 1 and n_shards == 1:
        out = jax.jit(rollout)(jax.random.PRNGKey(seed))
    else:
        # batched eval: independent episodes under vmapped rollouts,
        # optionally shard_mapped over the env axis. Each episode's
        # computation depends only on its own key, so the sharded and
        # unsharded batched paths produce identical per-env outputs (the
        # aggregation below is numpy, outside any reduction-order change)
        if n_envs % n_shards != 0:
            raise ValueError(f"n_envs={n_envs} must be divisible by "
                             f"n_shards={n_shards}")
        fn = jax.vmap(rollout)
        if n_shards > 1:
            fn = shard_map(fn, mesh=_env_mesh(n_shards),
                           in_specs=(P("env"),), out_specs=P("env"),
                           check_rep=False)
        out = jax.jit(fn)(jax.random.split(jax.random.PRNGKey(seed),
                                           n_envs))
    res = {k: float(np.asarray(v).mean()) for k, v in out.items()}
    res["t_task"] = res.pop("t_sum") / max(res["w_sum"], 1e-9)
    res["e_task"] = res.pop("e_sum") / max(res.pop("w_sum"), 1e-9)
    return res
