from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo
from repro.rl.baselines import local_policy_eval
