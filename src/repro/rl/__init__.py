"""MAHPPO scheduler stack.

``repro.env.mecenv`` consumes ``repro.rl.actionspace`` for its
declarative action space, so this package init must not import the
training stack eagerly (mecenv -> rl -> mahppo -> mecenv would be a
circular import). The historical conveniences (``from repro.rl import
train_mahppo`` etc.) are kept working via lazy PEP-562 attribute access;
add new re-exports to ``_LAZY``, never as top-level imports.
"""
_LAZY = {
    "MAHPPOConfig": "repro.rl.mahppo",
    "train_mahppo": "repro.rl.mahppo",
    "evaluate_policy": "repro.rl.mahppo",
    "local_policy_eval": "repro.rl.baselines",
    "HybridActionSpace": "repro.rl.actionspace",
    "DiscreteHead": "repro.rl.actionspace",
    "ContinuousHead": "repro.rl.actionspace",
}


def __getattr__(name):
    if name in _LAZY:
        import importlib
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module 'repro.rl' has no attribute {name!r}")
