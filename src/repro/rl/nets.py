"""Actor/critic networks (paper §5.1, Fig. 3).

Each UE has an actor: a shared trunk (256, 128) encoding the global state,
and three output branches (64 units each) for the hybrid action:
  * split point b   — categorical over B+2 (masked by feasibility)
  * channel c       — categorical over C
  * transmit power  — Gaussian (mu, sigma) over a pre-squash variable u;
                      executed power = sigmoid(u) * p_max
One global critic (256, 128, 64, 1) predicts the state value.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

LOG_STD_MIN, LOG_STD_MAX = -3.0, 1.0


def _linear_init(key, nin, nout, scale=np.sqrt(2.0)):
    w = jax.random.orthogonal(key, max(nin, nout))[:nin, :nout] * scale
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((nout,))}


def _mlp_init(key, sizes, out_scale=0.01):
    ks = jax.random.split(key, len(sizes) - 1)
    layers = []
    for i in range(len(sizes) - 1):
        scale = out_scale if i == len(sizes) - 2 else np.sqrt(2.0)
        layers.append(_linear_init(ks[i], sizes[i], sizes[i + 1], scale))
    return layers


def _mlp(layers, x):
    for i, p in enumerate(layers):
        x = x @ p["w"] + p["b"]
        if i < len(layers) - 1:
            x = jnp.tanh(x)
    return x


def init_actor(key, obs_dim, n_b, n_c):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {"trunk": _mlp_init(k1, (obs_dim, 256, 128), out_scale=np.sqrt(2.0)),
            "head_b": _mlp_init(k2, (128, 64, n_b)),
            "head_c": _mlp_init(k3, (128, 64, n_c)),
            "head_p": _mlp_init(k4, (128, 64, 2))}


def actor_forward(p, obs, mask):
    """obs: (obs_dim,). Returns (logits_b, logits_c, mu, log_std)."""
    h = jnp.tanh(_mlp(p["trunk"], obs))
    logits_b = _mlp(p["head_b"], h) + jnp.where(mask, 0.0, -1e9)
    logits_c = _mlp(p["head_c"], h)
    mu, raw = jnp.split(_mlp(p["head_p"], h), 2, axis=-1)
    log_std = jnp.clip(raw, LOG_STD_MIN, LOG_STD_MAX)
    return logits_b, logits_c, mu[..., 0], log_std[..., 0]


def init_critic(key, obs_dim):
    return _mlp_init(key, (obs_dim, 256, 128, 64, 1), out_scale=1.0)


def critic_forward(p, obs):
    return _mlp(p, obs)[..., 0]


def sample_hybrid(key, logits_b, logits_c, mu, log_std, mask=None):
    """mask: optional (n_b,) bool feasibility for THIS actor. actor_forward
    already buries infeasible logits at -1e9; re-masking here guarantees
    padded/infeasible splits are never sampled even from raw logits."""
    if mask is not None:
        logits_b = jnp.where(mask, logits_b, -1e9)
    kb, kc, kp = jax.random.split(key, 3)
    b = jax.random.categorical(kb, logits_b)
    c = jax.random.categorical(kc, logits_c)
    u = mu + jnp.exp(log_std) * jax.random.normal(kp, mu.shape)
    return b, c, u


def log_prob_hybrid(logits_b, logits_c, mu, log_std, b, c, u, active=None):
    """active: optional () / broadcastable activity weight for dynamic
    fleets — an inactive actor contributes exactly zero log-prob, so its
    (ignored-by-the-env) action can't steer the policy gradient."""
    lb = jax.nn.log_softmax(logits_b)[..., b] if logits_b.ndim == 1 else \
        jnp.take_along_axis(jax.nn.log_softmax(logits_b), b[..., None], -1)[..., 0]
    lc = jax.nn.log_softmax(logits_c)[..., c] if logits_c.ndim == 1 else \
        jnp.take_along_axis(jax.nn.log_softmax(logits_c), c[..., None], -1)[..., 0]
    var = jnp.exp(2 * log_std)
    lp = -0.5 * ((u - mu) ** 2 / var + 2 * log_std + jnp.log(2 * jnp.pi))
    out = lb + lc + lp
    if active is not None:
        out = out * active
    return out


def entropy_hybrid(logits_b, logits_c, log_std, active=None):
    """active: optional activity weight — inactive actors contribute zero
    entropy (no bonus for dithering while off-fleet)."""
    pb = jax.nn.softmax(logits_b)
    pc = jax.nn.softmax(logits_c)
    hb = -jnp.sum(pb * jnp.log(pb + 1e-12), axis=-1)
    hc = -jnp.sum(pc * jnp.log(pc + 1e-12), axis=-1)
    hp = 0.5 * jnp.log(2 * jnp.pi * jnp.e) + log_std
    out = hb + hc + hp
    if active is not None:
        out = out * active
    return out


def exec_power(u, p_max):
    return jax.nn.sigmoid(u) * p_max
