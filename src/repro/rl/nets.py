"""Actor/critic networks (paper §5.1, Fig. 3), generic over the env's
:class:`~repro.rl.actionspace.HybridActionSpace`.

Each UE has an actor: a shared trunk (256, 128) encoding the global state
and one output branch (64 units) per action-space head — a categorical
branch per discrete head (masked by that actor's feasibility), a
(mu, log_std) Gaussian branch per bounded continuous head. The heads are
*data*: nets.py never names a specific decision; the paper's
(split, channel, power) tuple and the multi-server (split, channel,
route, power) tuple train through the identical code path.

One global critic (256, 128, 64, 1) predicts the state value.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.rl.actionspace import HybridActionSpace


def _linear_init(key, nin, nout, scale=np.sqrt(2.0)):
    w = jax.random.orthogonal(key, max(nin, nout))[:nin, :nout] * scale
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((nout,))}


def _mlp_init(key, sizes, out_scale=0.01):
    ks = jax.random.split(key, len(sizes) - 1)
    layers = []
    for i in range(len(sizes) - 1):
        scale = out_scale if i == len(sizes) - 2 else np.sqrt(2.0)
        layers.append(_linear_init(ks[i], sizes[i], sizes[i + 1], scale))
    return layers


def _mlp(layers, x):
    for i, p in enumerate(layers):
        x = x @ p["w"] + p["b"]
        if i < len(layers) - 1:
            x = jnp.tanh(x)
    return x


def init_actor(key, obs_dim, space: HybridActionSpace):
    """Trunk + one branch per head. Keys are consumed trunk-first then in
    head declaration order, so the (split, channel, power) space
    reproduces the pre-actionspace init stream exactly."""
    ks = jax.random.split(key, 1 + len(space.heads))
    return {"trunk": _mlp_init(ks[0], (obs_dim, 256, 128),
                               out_scale=np.sqrt(2.0)),
            "heads": space.init_heads(ks[1:], 128, _mlp_init)}


def actor_forward(p, space: HybridActionSpace, obs, masks=None):
    """obs: (obs_dim,). Returns the per-head distribution dict (see
    HybridActionSpace.forward); masks: {head: (n,)} for THIS actor."""
    h = jnp.tanh(_mlp(p["trunk"], obs))
    return space.forward(p["heads"], h, _mlp, masks)


def shared_actor_forward(p, space: HybridActionSpace, feats, masks):
    """ONE actor parameter set applied to every fleet row via vmap — the
    weight-shared fleet-generalist policy. ``feats``: (N, F) per-UE
    feature rows (``env.observe_per_ue``); ``masks``: per-actor dict with
    (N, n) leaves (``space.broadcast_masks`` builds a complete one).
    Returns per-head distribution stacks with a leading actor axis — the
    same pytree shape as vmapping N distinct actors, so everything
    downstream (sample/log_prob/entropy/mode) is mode-agnostic."""
    return jax.vmap(lambda o, m: actor_forward(p, space, o, m),
                    in_axes=(0, 0))(feats, masks)


def param_count(tree) -> int:
    """Total parameter count of an agent/actor pytree. The shared-policy
    actor is O(1) in the fleet size; per-UE actors are O(N) — the
    generalization benchmark reports both."""
    return sum(int(np.prod(x.shape))
               for x in jax.tree_util.tree_leaves(tree))


def init_critic(key, obs_dim):
    return _mlp_init(key, (obs_dim, 256, 128, 64, 1), out_scale=1.0)


def critic_forward(p, obs):
    return _mlp(p, obs)[..., 0]
