"""Actor/critic networks (paper §5.1, Fig. 3), generic over the env's
:class:`~repro.rl.actionspace.HybridActionSpace`.

Each UE has an actor: a shared trunk (256, 128) encoding the global state
and one output branch (64 units) per action-space head — a categorical
branch per discrete head (masked by that actor's feasibility), a
(mu, log_std) Gaussian branch per bounded continuous head. The heads are
*data*: nets.py never names a specific decision; the paper's
(split, channel, power) tuple and the multi-server (split, channel,
route, power) tuple train through the identical code path.

One global critic (256, 128, 64, 1) predicts the state value.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.rl.actionspace import (LOG_STD_MAX, LOG_STD_MIN,
                                  HybridActionSpace, _mask_logits)


def _linear_init(key, nin, nout, scale=np.sqrt(2.0)):
    w = jax.random.orthogonal(key, max(nin, nout))[:nin, :nout] * scale
    return {"w": w.astype(jnp.float32), "b": jnp.zeros((nout,))}


def _mlp_init(key, sizes, out_scale=0.01):
    ks = jax.random.split(key, len(sizes) - 1)
    layers = []
    for i in range(len(sizes) - 1):
        scale = out_scale if i == len(sizes) - 2 else np.sqrt(2.0)
        layers.append(_linear_init(ks[i], sizes[i], sizes[i + 1], scale))
    return layers


def _mlp(layers, x):
    for i, p in enumerate(layers):
        x = x @ p["w"] + p["b"]
        if i < len(layers) - 1:
            x = jnp.tanh(x)
    return x


def init_actor(key, obs_dim, space: HybridActionSpace):
    """Trunk + one branch per head. Keys are consumed trunk-first then in
    head declaration order, so the (split, channel, power) space
    reproduces the pre-actionspace init stream exactly."""
    ks = jax.random.split(key, 1 + len(space.heads))
    return {"trunk": _mlp_init(ks[0], (obs_dim, 256, 128),
                               out_scale=np.sqrt(2.0)),
            "heads": space.init_heads(ks[1:], 128, _mlp_init)}


def actor_forward(p, space: HybridActionSpace, obs, masks=None):
    """obs: (obs_dim,). Returns the per-head distribution dict (see
    HybridActionSpace.forward); masks: {head: (n,)} for THIS actor."""
    h = jnp.tanh(_mlp(p["trunk"], obs))
    return space.forward(p["heads"], h, _mlp, masks)


def shared_actor_forward(p, space: HybridActionSpace, feats, masks):
    """ONE actor parameter set applied to every fleet row via vmap — the
    weight-shared fleet-generalist policy. ``feats``: (N, F) per-UE
    feature rows (``env.observe_per_ue``); ``masks``: per-actor dict with
    (N, n) leaves (``space.broadcast_masks`` builds a complete one).
    Returns per-head distribution stacks with a leading actor axis — the
    same pytree shape as vmapping N distinct actors, so everything
    downstream (sample/log_prob/entropy/mode) is mode-agnostic."""
    return jax.vmap(lambda o, m: actor_forward(p, space, o, m),
                    in_axes=(0, 0))(feats, masks)


# --------------------------------------------------- entity-set networks
# The pool-generalist policy (PR 5): instead of flattening the edge pool
# into mean-field aggregates, the actor consumes the env's entity-set
# observation {"ue": (N, d_u), "server": (E, d_s), "edge": (N, E, d_e)}
# and scores every (UE, server) pair with ONE shared MLP — route logits
# are (N, E) with E free at inference time (train on 2 servers, evaluate
# zero-shot on 3-4), and the policy is permutation-equivariant over both
# UEs and servers. The scorer's softmax doubles as attention weights that
# pool the server embeddings into a per-UE pool context feeding the other
# heads; the critic mean-pools encoded entity sets (both poolings are
# permutation-invariant over UEs and servers).

SRV_EMBED = 32               # server embedding width (route scorer input)


def init_entity_actor(key, dims, space: HybridActionSpace):
    """dims: the env's ``entity_dims`` {"ue", "server", "edge"} feature
    widths. The route head gets NO fixed-width branch (``skip``) — its
    logits come from the shared per-server scorer — so the parameter set
    is independent of the pool size E as well as the fleet size N. The
    server encoder is a single tanh layer: server rows are 4 raw geometry
    features, and keeping the encoder shallow keeps the entity iteration
    within the parity budget of the flat shared policy."""
    n_branch = len([h for h in space.heads if h.name != "route"])
    ks = jax.random.split(key, 3 + n_branch)
    return {
        "ue_enc": _mlp_init(ks[0], (dims["ue"], 192, 128),
                            out_scale=np.sqrt(2.0)),
        "srv_enc": _linear_init(ks[1], dims["server"], SRV_EMBED),
        "scorer": _mlp_init(ks[2], (128 + SRV_EMBED + dims["edge"], 48, 1),
                            out_scale=0.01),
        "heads": space.init_heads(ks[3:], 128 + SRV_EMBED, _mlp_init,
                                  skip=("route",)),
    }


def entity_trunk(p, obs):
    """The shared entity encoder: (ue_embed (N, 128), srv_embed (E, S),
    route_logits (N, E), ctx (N, S)). Policy heads AND the value head
    read these — one encoding per step (XLA CSE merges the actor and
    critic passes inside a jitted step), and the value gradient shapes
    the same representations the scorer routes with.

    An obs pytree carrying a "raw" block (``env.observe_entities_raw``,
    selected by the ``fused_scorer`` flag) routes the pair scorer through
    the fused kernel (``kernels.ops.pair_scorer``): the edge-feature
    build, the per-(server, channel) occupancy reduction, the server
    embedding, and the pair MLP run as one fused op and the (N, E, ·)
    intermediates never materialize. The default entity obs takes the
    path below unchanged."""
    ue = jnp.tanh(_mlp(p["ue_enc"], obs["ue"]))                # (N, 128)
    if "raw" in obs:
        from repro.kernels import ops as _kops
        route_logits, srv = _kops.pair_scorer(ue, obs["raw"],
                                              p["srv_enc"], p["scorer"])
        ctx = jax.nn.softmax(route_logits, axis=-1) @ srv      # (N, S)
        return ue, srv, route_logits, ctx
    srv = jnp.tanh(obs["server"] @ p["srv_enc"]["w"]
                   + p["srv_enc"]["b"])                        # (E, S)
    n, e = obs["edge"].shape[:2]
    pair = jnp.concatenate([
        jnp.broadcast_to(ue[:, None, :], (n, e, ue.shape[-1])),
        jnp.broadcast_to(srv[None, :, :], (n, e, srv.shape[-1])),
        obs["edge"],
    ], axis=-1)
    route_logits = _mlp(p["scorer"], pair)[..., 0]             # (N, E)
    ctx = jax.nn.softmax(route_logits, axis=-1) @ srv          # (N, S)
    return ue, srv, route_logits, ctx


def entity_actor_forward(p, space: HybridActionSpace, obs, masks):
    """obs: one env's entity-set pytree; masks: complete per-actor dict
    with (N, n) leaves (``space.broadcast_masks``). Returns per-head
    distribution stacks with a leading actor axis — the same pytree shape
    as `shared_actor_forward`, so sampling/log-prob/entropy/mode are
    mode-agnostic downstream.

    Route logits: scorer([ue_embed ‖ server_embed ‖ edge_feats]) applied
    to every (UE, server) pair -> (N, E), permutation-equivariant over
    servers. The scorer softmax attention-pools the server embeddings
    into each UE's pool context for the remaining heads."""
    ue, _, route_logits, ctx = entity_trunk(p, obs)
    h = jnp.concatenate([ue, ctx], axis=-1)
    return jax.vmap(
        lambda hh, rl, m: space.forward(p["heads"], hh, _mlp, m,
                                        provided={"route": rl}),
        in_axes=(0, 0, 0))(h, route_logits, masks)


def init_entity_critic(key):
    """The entity value HEAD: a small MLP over the mean-pooled trunk
    embeddings (`entity_value_forward`). The encoders live on the actor
    and are shared — pooling happens after the nonlinearity (pooling raw
    feature rows instead demonstrably cripples the value signal under
    geometry randomization), and the whole agent stays O(1) in N and E."""
    return _mlp_init(key, (128 + SRV_EMBED, 64, 1), out_scale=1.0)


def entity_value_forward(actor_p, head_p, obs):
    """Permutation-invariant state value from the shared trunk: mean-pool
    the UE and server embeddings and regress."""
    ue, srv, _, _ = entity_trunk(actor_p, obs)
    h = jnp.concatenate([ue.mean(axis=0), srv.mean(axis=0)], axis=-1)
    return _mlp(head_p, h)[..., 0]


def entity_policy_value(actor_p, head_p, space, obs, masks):
    """(dist, value) from ONE trunk pass — the training hot path. The
    separate `entity_actor_forward` / `entity_value_forward` entry points
    trace the identical math for callers that only need one of the two
    (evaluation, bootstrap values); this fused form keeps the jitted
    sample/loss steps at one encoder evaluation per state."""
    ue, srv, route_logits, ctx = entity_trunk(actor_p, obs)
    h = jnp.concatenate([ue, ctx], axis=-1)
    dist = jax.vmap(
        lambda hh, rl, m: space.forward(actor_p["heads"], hh, _mlp, m,
                                        provided={"route": rl}),
        in_axes=(0, 0, 0))(h, route_logits, masks)
    hv = jnp.concatenate([ue.mean(axis=0), srv.mean(axis=0)], axis=-1)
    return dist, _mlp(head_p, hv)[..., 0]


# ------------------------------------------------ distilled flat trunk
# The serve-small deployment net (ROADMAP item 5): the entity teacher is
# distilled (rl/distill.py) into ONE small MLP over observe_per_ue's
# constant-width rows that emits every HybridActionSpace head in a
# single fused pass — no per-head branches, no per-pair scorer, no
# attention pooling. The output row is the concatenation of all discrete
# head logits (declaration order) followed by (mu, raw_log_std) pairs
# for the continuous heads; `trunk_head_dist` splits it into the exact
# distribution pytree `space.forward` produces, so sample / mode /
# log_prob / execute are shared with every other policy mode. The route
# head is a FIXED-width slice here: the student trades the teacher's
# any-E generality for microsecond batch-1 latency on one deployment
# pool (the train-big/serve-small contract).

def trunk_width(space: HybridActionSpace) -> int:
    """Output columns of the flat trunk: one logit per discrete choice
    plus (mu, log_std) per continuous head."""
    return sum(h.n for h in space.discrete) + 2 * len(space.continuous)


def init_flat_trunk(key, obs_dim, space: HybridActionSpace,
                    hidden=(64, 64)):
    """The distillation student: a plain tanh MLP
    (obs_dim, *hidden, trunk_width). ~2 orders of magnitude fewer
    parameters than the entity teacher it is distilled from."""
    return {"layers": _mlp_init(key, (obs_dim, *hidden,
                                      trunk_width(space)))}


def trunk_head_dist(space: HybridActionSpace, out, masks=None):
    """Split the trunk's (N, W) output columns into the standard
    distribution pytree (masked logits per discrete head, clipped
    {"mu", "log_std"} per continuous head — identical post-processing to
    `HybridActionSpace.forward`, shared by the f32 and int8 paths)."""
    dist = {}
    i = 0
    for h in space.discrete:
        logits = out[..., i:i + h.n]
        i += h.n
        m = None if masks is None else masks.get(h.name)
        dist[h.name] = _mask_logits(logits, m)
    for h in space.continuous:
        dist[h.name] = {"mu": out[..., i],
                        "log_std": jnp.clip(out[..., i + 1], LOG_STD_MIN,
                                            LOG_STD_MAX)}
        i += 2
    return dist


def flat_trunk_forward(p, space: HybridActionSpace, feats, masks=None):
    """feats: (N, F) per-UE rows (``env.observe_per_ue``); masks: complete
    per-actor dict with (N, n) leaves. Returns the same leading-actor-axis
    distribution pytree as `shared_actor_forward`, from ONE batched MLP
    pass over the rows (no vmap, no per-head branch dispatch).

    Accepts either the f32 student ({"layers": ...}) or its int8
    weight-quantized form ({"qlayers": ..., "bits": n}, from
    ``rl.distill.quantize_flat_trunk``) — the latter routes through the
    fused dequant-matmul kernel (``kernels.ops.flat_trunk``)."""
    if "qlayers" in p:
        from repro.kernels import ops as _kops
        out = _kops.flat_trunk(feats, p["qlayers"], bits=int(p["bits"]))
    else:
        out = _mlp(p["layers"], feats)
    return trunk_head_dist(space, out, masks)


def param_count(tree) -> int:
    """Total parameter count of an agent/actor pytree. The shared-policy
    actor is O(1) in the fleet size; per-UE actors are O(N) — the
    generalization benchmark reports both."""
    return sum(int(np.prod(np.shape(x)))
               for x in jax.tree_util.tree_leaves(tree))


def param_bytes(tree) -> int:
    """Serving-weight footprint in bytes, from the ACTUAL leaf dtypes —
    an int8-quantized trunk counts 1 byte per weight code (plus its f32
    biases and per-layer calibration scalars), the f32 nets 4."""
    return sum(int(np.prod(a.shape)) * a.dtype.itemsize
               for a in map(np.asarray, jax.tree_util.tree_leaves(tree)))


def init_critic(key, obs_dim):
    return _mlp_init(key, (obs_dim, 256, 128, 64, 1), out_scale=1.0)


def critic_forward(p, obs):
    return _mlp(p, obs)[..., 0]
