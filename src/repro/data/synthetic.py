"""Synthetic data pipelines.

* Images: a procedural 101-class stand-in for Caltech-101 (offline container).
  Each class is a fixed random frequency/phase pattern; samples add noise,
  random shifts and amplitude jitter — enough signal for the compression /
  accuracy trade-off experiments to be meaningful.
* Tokens: an order-k Markov-chain language over a configurable vocab, giving
  a learnable next-token distribution (loss decreases materially within a
  few hundred steps for ~100M-param models).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def _class_basis(n_classes: int, size: int):
    rng = np.random.RandomState(1234)
    fx = rng.uniform(0.5, 6.0, (n_classes, 3))
    fy = rng.uniform(0.5, 6.0, (n_classes, 3))
    ph = rng.uniform(0, 2 * np.pi, (n_classes, 3))
    xx, yy = np.meshgrid(np.linspace(0, 1, size), np.linspace(0, 1, size))
    basis = np.sin(2 * np.pi * (fx[:, :, None, None] * xx
                                + fy[:, :, None, None] * yy)
                   + ph[:, :, None, None])
    return jnp.asarray(basis, jnp.float32)           # (n_classes, 3, S, S)


_BASIS_CACHE = {}


def synthetic_image_batch(key, batch, size, n_classes=101, noise=0.3):
    """Returns (x (B,3,S,S) f32, labels (B,) int32)."""
    ck = (n_classes, size)
    if ck not in _BASIS_CACHE:
        _BASIS_CACHE[ck] = _class_basis(n_classes, size)
    basis = _BASIS_CACHE[ck]
    kl, kn, ka, ks = jax.random.split(key, 4)
    labels = jax.random.randint(kl, (batch,), 0, n_classes)
    amp = jax.random.uniform(ka, (batch, 1, 1, 1), minval=0.7, maxval=1.3)
    x = basis[labels] * amp
    shift = jax.random.randint(ks, (batch,), 0, size)
    x = jax.vmap(lambda img, s: jnp.roll(img, s, axis=-1))(x, shift)
    x = x + noise * jax.random.normal(kn, x.shape)
    return x, labels


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab_size: int = 8192
    seq_len: int = 256
    batch: int = 8
    order: int = 1
    n_modes: int = 64      # sparsity of the transition rows


def _markov_table(vocab, n_modes, seed=7):
    rng = np.random.RandomState(seed)
    nexts = rng.randint(0, vocab, (vocab, n_modes)).astype(np.int32)
    logits = rng.gumbel(size=(vocab, n_modes)).astype(np.float32)
    return jnp.asarray(nexts), jnp.asarray(logits)


_TOKEN_CACHE = {}


def token_batch_stream(cfg: TokenPipelineConfig, seed=0):
    """Generator of {"tokens", "labels"} batches from a Markov language."""
    ck = (cfg.vocab_size, cfg.n_modes)
    if ck not in _TOKEN_CACHE:
        _TOKEN_CACHE[ck] = _markov_table(cfg.vocab_size, cfg.n_modes)
    nexts, logits = _TOKEN_CACHE[ck]

    @jax.jit
    def make_batch(key):
        k0, key = jax.random.split(key)
        cur = jax.random.randint(k0, (cfg.batch,), 0, cfg.vocab_size)

        def step(cur, k):
            idx = jax.random.categorical(k, logits[cur])
            nxt = nexts[cur, idx]
            return nxt, nxt

        keys = jax.random.split(key, cfg.seq_len)
        _, toks = jax.lax.scan(step, cur, keys)
        toks = toks.T                                    # (B, S)
        tokens = jnp.concatenate([cur[:, None], toks[:, :-1]], axis=1)
        return {"tokens": tokens, "labels": toks}

    key = jax.random.PRNGKey(seed)
    while True:
        key, sub = jax.random.split(key)
        yield make_batch(sub)
