from repro.data.synthetic import (synthetic_image_batch, token_batch_stream,
                                  TokenPipelineConfig)
