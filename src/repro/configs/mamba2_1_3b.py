"""mamba2-1.3b [SSM, SSD state-space duality; arXiv:2405.21060].

Attention-free: 48 SSD mixer layers, d_model=2048, d_state=128. Decode is
O(1)-state, so long_500k runs natively.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=16,          # unused (attention-free); kept for config uniformity
    n_kv_heads=1,
    d_ff=0,
    vocab_size=50280,
    block_pattern=("mamba2",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
    seq_parallel_residual=True,
)
