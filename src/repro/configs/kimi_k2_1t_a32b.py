"""kimi-k2-1t-a32b [trillion-param MoE, paper-table; arXiv:2501.kimi2].

61L, d_model=7168, 64 heads (GQA kv=8), 384 experts top-8 with d_expert=2048
plus one shared expert, vocab=163840. XL config: FSDP param sharding and
Adafactor states (AdamW f32 states for 1T params cannot fit the assigned
meshes; see DESIGN.md / EXPERIMENTS.md memory analysis).
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=2048,
    vocab_size=163840,
    rope_theta=50000.0,
    block_pattern=("moe",),
    moe=MoEConfig(n_experts=384, top_k=8, d_expert=2048, n_shared_experts=1),
    fsdp=True,
    optimizer="adafactor",
)
