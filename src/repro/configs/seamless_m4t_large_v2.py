"""seamless-m4t-large-v2 [audio enc-dec, arXiv:2308.11596].

Transformer backbone only: 24L encoder + 24L decoder, d_model=1024, 16 heads
(kv=16, MHA), d_ff=8192, vocab=256206. The speech frontend (mel-spectrogram +
conv feature extractor) is stubbed: input_specs() feeds precomputed frame
embeddings of shape (batch, n_frames, d_model) to the encoder.
"""
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    norm="layernorm",
    act="gelu",
    block_pattern=("decx",),
    encoder=EncoderConfig(n_layers=24, n_frames=1024),
    n_aux_tokens=1024,
    rope_theta=10000.0,
)
