"""Model/config system for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``. Block layout is
given by ``block_pattern`` (repeated cyclically over ``n_layers``), which lets
one assembly routine cover dense, MoE, SSM, hybrid (RG-LRU + local attention),
encoder-decoder (audio) and cross-attention (VLM) families while keeping the
compiled HLO depth-independent (scan over stacked per-pattern-group params).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int            # per-expert FFN hidden dim
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128       # N
    d_conv: int = 4
    expand: int = 2          # d_inner = expand * d_model
    head_dim: int = 64       # P;  n_heads = d_inner // head_dim
    chunk: int = 256         # SSD chunk length
    n_groups: int = 1        # B/C groups


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec (audio) archs. Frontend is stubbed: the
    encoder consumes precomputed frame embeddings (see input_specs)."""
    n_layers: int = 24
    n_frames: int = 1024     # stub frontend output length
    d_frontend: int = 0      # 0 => frames already at d_model


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0          # 0 => d_model // n_heads
    norm: str = "rmsnorm"    # rmsnorm | layernorm
    act: str = "swiglu"      # swiglu | gelu
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0
    tie_embeddings: bool = False
    # Block layout. Entries: "dense" (attn+mlp), "moe" (attn+moe),
    # "mamba2", "rec" (RG-LRU+mlp), "lattn" (local attn+mlp),
    # "xattn" (cross-attn+mlp, VLM), "decx" (self+cross, enc-dec decoder).
    block_pattern: Tuple[str, ...] = ("dense",)
    window: int = 0          # local-attention window (hybrid archs)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    n_aux_tokens: int = 0    # VLM image tokens / audio frames fed via cross-attn
    # serving
    long_context_window: int = 8192   # sliding-window variant for long_500k
    # numerics / distribution
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    fsdp: bool = False       # additionally shard params over the data axis
    seq_parallel_residual: bool = False  # Megatron-style sequence parallelism
    remat: bool = True
    optimizer: str = "adamw"  # adamw | adafactor (XL archs)
    attn_chunk: int = 1024   # flash-attention KV chunk
    # paper technique defaults for this arch
    bottleneck_ratio: int = 4   # R_c = d_model / (d_model // ratio)
    quant_bits: int = 8
    # beyond-paper: the paper's Eq.1 quantizer applied to the KV cache
    # (int8 symmetric, per-(slot, kv-head) scales). 0 = off.
    kv_quant_bits: int = 0
    # route the SSD intra-chunk computation through the Pallas kernel
    # (kernels/ssd_intra.py). Off by default: on CPU the kernel runs in
    # interpret mode (correct but slow); flip on for TPU deployments.
    use_pallas_ssd: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head else self.d_model // self.n_heads

    @property
    def pattern_len(self) -> int:
        return len(self.block_pattern)

    def block_types(self) -> Tuple[str, ...]:
        """Block type of each of the n_layers layers."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to this paper (public pool).
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            vocab: int = 512) -> ModelConfig:
    """Reduced variant of the same family for CPU smoke tests
    (<=2 layers, d_model<=512, <=4 experts)."""
    d_model = min(d_model, 512)
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    kw = dict(
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        d_head=d_model // n_heads, d_ff=2 * d_model, vocab_size=vocab,
        param_dtype="float32", compute_dtype="float32", fsdp=False,
        attn_chunk=64, window=min(cfg.window, 64) if cfg.window else 0,
        long_context_window=128,
    )
    if cfg.moe is not None:
        # capacity_factor = n_experts => capacity == t*top_k: no token is ever
        # dropped, keeping reduced-config tests deterministic across batching.
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2, d_expert=d_model // 2,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
            capacity_factor=4.0)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=16, head_dim=32, chunk=16)
    if cfg.encoder is not None:
        kw["encoder"] = dataclasses.replace(cfg.encoder, n_layers=2, n_frames=16)
    if cfg.n_aux_tokens:
        kw["n_aux_tokens"] = 16
    # keep the pattern but make sure n_layers covers it
    kw["n_layers"] = max(n_layers, len(cfg.block_pattern))
    return cfg.replace(**kw)
