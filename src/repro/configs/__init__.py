"""Architecture registry: ``--arch <id>`` resolves here."""
from __future__ import annotations

import importlib

from repro.configs.base import (INPUT_SHAPES, EncoderConfig, InputShape,
                                ModelConfig, MoEConfig, SSMConfig, reduced)

_MODULES = {
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "qwen2-7b": "qwen2_7b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "qwen3-1.7b": "qwen3_1_7b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "stablelm-1.6b": "stablelm_1_6b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "mamba2-1.3b": "mamba2_1_3b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
}

# the 10 assigned architectures (drives --all sweeps and smoke tests)
ARCH_IDS = tuple(_MODULES)

# extra variants (selectable via --arch, excluded from ARCH_IDS)
_MODULES["qwen2-7b-kv8"] = "qwen2_7b_kv8"


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
