"""recurrentgemma-9b [hybrid RG-LRU + local attention, 1:2; arXiv:2402.19427].

38 layers in the Griffin pattern (rec, rec, local-attn): 12 full groups plus a
(rec, rec) tail. MQA (kv=1) local attention with a 2048-token window — this
arch runs long_500k natively (recurrent state + bounded window cache).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256000,
    block_pattern=("rec", "rec", "lattn"),
    window=2048,
    long_context_window=2048,
    rope_theta=10000.0,
)
