"""qwen2-7b with int8 KV cache (beyond-paper: the paper's Eq. 1 quantizer
applied to the serving cache — halves the decode memory-roofline term).
Extra config, not part of the 10 assigned architectures."""
from repro.configs.qwen2_7b import CONFIG as _BASE

CONFIG = _BASE.replace(name="qwen2-7b-kv8", kv_quant_bits=8)
