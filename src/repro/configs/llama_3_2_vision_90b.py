"""llama-3.2-vision-90b [VLM, cross-attn image layers;
hf:meta-llama/Llama-3.2-11B-Vision scaled per assignment].

100 layers, every 5th a gated cross-attention layer over image-patch
embeddings (vision encoder stubbed: input_specs() provides precomputed patch
embeddings (batch, 1600, d_model)). FSDP + Adafactor (90B params).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=5e5,
    block_pattern=("dense", "dense", "dense", "dense", "xattn"),
    n_aux_tokens=1600,
    fsdp=True,
    optimizer="adafactor",
)
