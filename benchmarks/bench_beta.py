"""Fig. 12: impact of the latency/energy trade-off hyperparameter beta."""
from __future__ import annotations

import numpy as np

from repro.core.cnn import make_resnet18
from repro.core.split import cnn_split_table
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo


def run(quick=True, betas=None):
    iters = 50 if quick else 150
    betas = betas or ((0.01, 1.0, 100.0) if quick
                      else (0.01, 0.1, 1.0, 10.0, 100.0, 1000.0))
    plan = cnn_split_table(make_resnet18(101), 224)
    rows = []
    for beta in betas:
        env = MECEnv(make_env_params(plan, n_ue=5, n_channels=2, beta=beta))
        cfg = MAHPPOConfig(iterations=iters, horizon=1024, n_envs=8)
        agent, _ = train_mahppo(env, cfg, seed=0)
        ev = evaluate_policy(env, agent, frames=64)
        rows.append({"beta": beta, "t_ms": 1e3 * ev["t_task"],
                     "e_mJ": 1e3 * ev["e_task"]})
    return {"rows": rows}


if __name__ == "__main__":
    for r in run()["rows"]:
        print(r)
