"""Fig. 10 + Fig. 11: convergence and averaged inference overhead vs UE
number (N = 3..10) on ResNet18."""
from __future__ import annotations

import numpy as np

from repro.core.cnn import make_resnet18
from repro.core.split import cnn_split_table
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl.baselines import local_policy_eval
from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo


def run(quick=True, ue_numbers=None):
    iters = 60 if quick else 200
    ue_numbers = ue_numbers or ((3, 5, 8) if quick else tuple(range(3, 11)))
    plan = cnn_split_table(make_resnet18(101), 224)
    rows = []
    for n in ue_numbers:
        env = MECEnv(make_env_params(plan, n_ue=n, n_channels=2))
        cfg = MAHPPOConfig(iterations=iters, horizon=1024, n_envs=8)
        agent, hist = train_mahppo(env, cfg, seed=0)
        ev = evaluate_policy(env, agent, frames=64)
        lo = local_policy_eval(env, frames=64)
        beta = float(env.params.beta)
        rows.append({
            "n_ue": n,
            "final_reward": float(np.mean([h["reward_mean"] for h in hist[-5:]])),
            "t_ms": 1e3 * ev["t_task"], "e_mJ": 1e3 * ev["e_task"],
            "local_t_ms": 1e3 * lo["t_task"], "local_e_mJ": 1e3 * lo["e_task"],
            "overhead": ev["t_task"] + beta * ev["e_task"],
            "local_overhead": lo["t_task"] + beta * lo["e_task"],
        })
    return {"rows": rows}


if __name__ == "__main__":
    for r in run()["rows"]:
        print({k: round(v, 4) if isinstance(v, float) else v
               for k, v in r.items()})
