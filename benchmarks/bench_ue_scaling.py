"""Giant-fleet scaling bench: per-UE iteration cost N=16 -> 1024 and the
fused pair-scorer kernel vs its naive reference.

The seed-era version of this file trained full MAHPPO runs at the paper's
N=3..10 and reported no timing at all. This one measures what the
ROADMAP's metro-scale axis actually needs:

* ``iter_us`` of ONE jitted entity-policy MAHPPO iteration at each rung
  of an N ladder (16 / 64 / 256 / 1024), timed on the shared
  ``_timing.paired_iter_samples`` interleaved harness. Every rung gets
  the SAME sample budget: 4096 agent-frames collected per iteration and
  1024 agent-rows per minibatch (a fleet of N UEs yields N transitions
  per env frame, so ``horizon = 4096 / N`` — the bigger the fleet, the
  faster it fills the budget). The headline number is **per-UE cost**
  ``iter_us / N``: the entity agent is O(1) in params over N and E and
  the per-frame work batches across the fleet, so the cost of an
  equal-experience iteration stays near-flat in N and the per-UE cost
  must FALL — the run.py ledger enforces per_ue(256) <= 0.5 *
  per_ue(16).
* the fused pair scorer (``kernels.ops.pair_scorer`` — decomposed first
  layer, no materialized (N, E, 163) pair concat) raced against the
  naive XLA reference (``kernels.ref.pair_scorer_ref`` — the default
  entity path's op-for-op build), interleaved rounds, median of
  per-round ratios. The ledger enforces parity (fp32 tolerance) and a
  call_us win at N >= 256.

Ladders: ``--smoke`` (CI) times {16, 256}; quick (default) and ``--full``
time {16, 64, 256, 1024} — the fixed sample budget keeps even the
N=1024 rung at roughly the N=16 iteration cost. Kernel rows always
include the enforced N=256 point.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._timing import paired_iter_samples, paired_ratio, tail_stats
from repro.core.cnn import make_resnet18
from repro.core.fleets import make_edge_pool
from repro.core.split import cnn_split_table
from repro.env.mecenv import MECEnv, make_env_params
from repro.kernels import ops, ref
from repro.rl.mahppo import MAHPPOConfig

# the ledger-enforced comparison rungs: per-UE cost at N_HI must be at
# most SUBLINEAR_LIMIT x the per-UE cost at N_LO
N_LO, N_HI = 16, 256
SUBLINEAR_LIMIT = 0.5

# equal-experience budget per timed iteration: every rung collects this
# many agent-frames (UE transitions) and draws minibatches of this many
# agent-rows, so the rungs compare the cost of the SAME amount of
# learning signal at different fleet sizes
AGENT_FRAMES = 4096
ROWS_PER_MINIBATCH = 1024


def _env(plan, n):
    return MECEnv(make_env_params(plan, n_ue=n, n_channels=2,
                                  pool=make_edge_pool(2)))


def _cfg(n):
    # horizon = AGENT_FRAMES / n env frames fills the fixed sample
    # budget (8 minibatch updates of ROWS_PER_MINIBATCH agent-rows at
    # every rung: reuse * horizon/batch = 2 * 4). The ladder runs the
    # fused-scorer path: the default entity obs stores (T, n_envs, N,
    # E, 3) edge tensors in the trajectory and the loss re-materializes
    # (batch, N, E, 163) pair concats — both scale as N x E and are
    # exactly what the fused kernel path eliminates.
    horizon = max(AGENT_FRAMES // n, 1)
    return MAHPPOConfig(iterations=1, horizon=horizon,
                        n_envs=min(8, horizon), reuse=2,
                        batch=max(ROWS_PER_MINIBATCH // n, 1),
                        entity_policy=True, fused_scorer=True)


def _scorer_inputs(key, n, n_srv=3):
    """Representative pair-scorer inputs at fleet size n: magnitudes
    mirror a live env (distances 1..100 m, edge-tail work ~1e8 FLOP,
    ~70% active fleet, paper-default physics consts)."""
    ks = jax.random.split(key, 8)
    ue_emb = jnp.tanh(jax.random.normal(ks[0], (n, 128)))
    d = jax.random.uniform(ks[1], (n,), minval=1.0, maxval=100.0)
    work = jax.random.uniform(ks[2], (n,), minval=5e7, maxval=5e8)
    active = (jax.random.uniform(ks[3], (n,)) < 0.7).astype(jnp.float32)
    geom = jax.random.uniform(ks[4], (n_srv, 3), minval=0.5, maxval=2.0)
    consts = jnp.asarray([3.0, 0.5, 1e-9, 1e6 / 1e7, 0.5,
                          n_srv * 2.0, 100.0, 1e12], jnp.float32)
    srv_enc = {"w": jax.random.normal(ks[5], (4, 32)) * 0.5,
               "b": jnp.zeros((32,))}
    scorer = [{"w": jax.random.normal(ks[6], (163, 48)) * 0.1,
               "b": jnp.zeros((48,))},
              {"w": jax.random.normal(ks[7], (48, 1)) * 0.01,
               "b": jnp.zeros((1,))}]
    raw = {"d": d, "work": work, "active": active, "geom": geom,
           "consts": consts}
    return ue_emb, raw, srv_enc, scorer


def _paired_call_us(fns_args, rounds=12):
    """Interleaved per-call timing of several (fn, args) candidates —
    the kernel-level analogue of ``paired_iter_samples``. Returns
    seconds-per-call sample lists, one per candidate."""
    for fn, args in fns_args:
        jax.block_until_ready(fn(*args))        # compile + warm-up
    times = [[] for _ in fns_args]
    for _ in range(rounds):
        for i, (fn, args) in enumerate(fns_args):
            t0 = time.time()
            jax.block_until_ready(fn(*args))
            times[i].append(time.time() - t0)
    return times


def run_kernel(quick=True, smoke=False):
    """Fused pair scorer vs naive reference: numeric parity (pallas
    interpret AND decomposed XLA vs the oracle) plus an interleaved
    call_us race of the fused fast path against the jitted reference."""
    ns = (64, N_HI) if (smoke or quick) else (64, N_HI, 1024)
    fused = jax.jit(lambda ue, raw, se, sc: ops.pair_scorer(ue, raw, se,
                                                            sc))
    naive = jax.jit(lambda ue, raw, se, sc: ref.pair_scorer_ref(
        ue, raw["d"], raw["work"], raw["active"], raw["geom"],
        raw["consts"], se["w"], se["b"], sc[0]["w"], sc[0]["b"],
        sc[1]["w"], sc[1]["b"]))
    rows, parity = [], []
    for n in ns:
        args = _scorer_inputs(jax.random.PRNGKey(n), n)
        lf, sf = fused(*args)
        lr, sr = naive(*args)
        max_diff = float(jnp.abs(lf - lr).max())
        lp, _ = ops.pair_scorer(*args[:4], impl="pallas")
        pallas_diff = float(jnp.abs(lp - lr).max())
        tf, tr = _paired_call_us([(fused, args), (naive, args)],
                                 rounds=6 if smoke else 12)
        ratio = paired_ratio(tf, tr)
        rows.append({"n": n, "fused_us": 1e6 * float(np.median(tf)),
                     "ref_us": 1e6 * float(np.median(tr)),
                     "ratio": ratio, "max_diff": max_diff,
                     "pallas_max_diff": pallas_diff})
        if n >= N_HI:
            # fp32 tolerance: logits are O(0.1); 1e-4 absolute is ~1e3 ulp
            parity.append({"name": f"pair_scorer_parity_n{n}",
                           "ratio": max_diff / 1e-4, "limit": 1.0})
            parity.append({"name": f"pair_scorer_pallas_parity_n{n}",
                           "ratio": pallas_diff / 1e-4, "limit": 1.0})
            parity.append({"name": f"pair_scorer_vs_ref_call_n{n}",
                           "ratio": ratio, "limit": 1.0})
    return rows, parity


def run(quick=True, smoke=False):
    ladder = (N_LO, N_HI) if smoke else (N_LO, 64, N_HI, 1024)
    plan = cnn_split_table(make_resnet18(101), 224)
    candidates = [(_env(plan, n), _cfg(n)) for n in ladder]
    samples = paired_iter_samples(candidates, n_timed=3 if smoke else 5)
    rows = []
    for (n, ts, (_, cfg)) in zip(ladder, samples, candidates):
        iter_us = 1e6 * float(np.median(ts))
        rows.append({"n_ue": n, "frames": cfg.horizon,
                     "agent_frames": cfg.horizon * n,
                     "iter_us": iter_us, "per_ue_us": iter_us / n,
                     # tail of the per-round samples, same percentiles as
                     # the streaming QoS monitor (shared tail_stats)
                     **{f"iter_{k}_us": 1e6 * v
                        for k, v in tail_stats(ts).items()}})
    i_lo, i_hi = ladder.index(N_LO), ladder.index(N_HI)
    # per-UE sublinearity from PAIRED rounds: median over rounds of
    # (t_hi/N_HI) / (t_lo/N_LO)
    sub_ratio = paired_ratio(samples[i_hi], samples[i_lo]) * N_LO / N_HI
    parity = [{"name": f"per_ue_sublinear_n{N_HI}_vs_n{N_LO}",
               "ratio": sub_ratio, "limit": SUBLINEAR_LIMIT}]
    kernel_rows, kernel_parity = run_kernel(quick=quick, smoke=smoke)
    return {"rows": rows, "kernel_rows": kernel_rows,
            "per_ue_sublinear": sub_ratio,
            "parity": parity + kernel_parity}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"n_ue={r['n_ue']:5d}  iter_us={r['iter_us']:>12.0f}  "
              f"per_ue_us={r['per_ue_us']:>9.1f}")
    for r in out["kernel_rows"]:
        print(f"pair_scorer n={r['n']:5d}  fused_us={r['fused_us']:.0f}  "
              f"ref_us={r['ref_us']:.0f}  ratio={r['ratio']:.2f}  "
              f"max_diff={r['max_diff']:.2e}")
    for p in out["parity"]:
        ok = "OK " if p["ratio"] <= p["limit"] else "FAIL"
        print(f"{ok} {p['name']}: {p['ratio']:.3f} (limit {p['limit']})")
