"""Fig. 4 + Fig. 5: compression rate of the AE compressor vs JALAD at the 4
ResNet18 partitioning points, and the xi ablation.

Offline stand-in for Caltech-101: procedural 101-class images (see
repro.data.synthetic); ResNet18 at width 0.5 / 32px for CPU budget. For each
split point we train AEs at increasing channel-reduction ratios and report
the best rate whose fine-tuned accuracy stays within 2% of the no-AE
baseline (the paper's selection rule), alongside JALAD's entropy-rate.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cnn as cnn_lib
from repro.core.compressor import (accuracy_with_ae, measure_rate_distortion,
                                   train_autoencoder)
from repro.core.jalad import jalad_compress_size_bits
from repro.data.synthetic import synthetic_image_batch

IMG, NCLS, WIDTH = 32, 101, 0.5


def _data_iter(batch=32, seed0=0):
    k = seed0
    while True:
        yield synthetic_image_batch(jax.random.PRNGKey(k), batch, IMG, NCLS)
        k += 1


def _pretrain_backbone(model, steps=60):
    from repro.optim import adamw_init, adamw_update
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)

    def loss(p, x, y):
        logits = cnn_lib.forward(model, p, x)
        lse = jax.nn.logsumexp(logits, -1)
        tgt = jnp.take_along_axis(logits, y[:, None], -1)[:, 0]
        return jnp.mean(lse - tgt)

    @jax.jit
    def step(p, o, x, y):
        l, g = jax.value_and_grad(loss)(p, x, y)
        p, o = adamw_update(g, o, p, 3e-3, weight_decay=0.0)
        return p, o, l

    it = _data_iter()
    for _ in range(steps):
        x, y = next(it)
        params, opt, l = step(params, opt, x, y)
    return params


def _accuracy(model, params, n_batches=4):
    accs = []
    for s in range(n_batches):
        x, y = synthetic_image_batch(jax.random.PRNGKey(10_000 + s), 64, IMG,
                                     NCLS)
        logits = cnn_lib.forward(model, params, x)
        accs.append(float(jnp.mean((jnp.argmax(logits, -1) == y))))
    return float(np.mean(accs))


def run(quick=True, smoke=False):
    model = cnn_lib.make_resnet18(NCLS, width=WIDTH)
    t0 = time.time()
    bb = _pretrain_backbone(model,
                            steps=40 if smoke else (150 if quick else 400))
    base_acc = _accuracy(model, bb)
    # the paper's 2%-rule sweep lives in core.compressor so measured
    # SplitPlans (core.split.measured_cnn_split_table) can reuse it
    rd = measure_rate_distortion(
        model, bb,
        data_iter_fn=lambda pi: _data_iter(seed0=500 + pi),
        eval_batch_fn=lambda pi: synthetic_image_batch(
            jax.random.PRNGKey(20_000 + pi), 64, IMG, NCLS),
        ratios=(8,) if smoke else ((4, 8, 16) if quick
                                   else (2, 4, 8, 16, 32)),
        steps=8 if smoke else (30 if quick else 150), lr=3e-3,
        base_acc=base_acc)
    rows = []
    for pi, (k, r) in enumerate(zip(model.split_after, rd)):
        # JALAD entropy rate on the same feature
        x, _ = synthetic_image_batch(jax.random.PRNGKey(30_000 + pi), 16, IMG,
                                     NCLS)
        feat = cnn_lib.forward(model, bb, x, upto=k + 1)
        _, jrate = jalad_compress_size_bits(feat, 8)
        rows.append({"point": pi + 1, "channels": r["channels"],
                     "ch_prime": r["ch_prime"],
                     "ae_rate": float(r["rate"]), "ae_acc": r["acc"],
                     "jalad_rate": float(jrate), "base_acc": base_acc})
    return {"rows": rows, "seconds": time.time() - t0}


def run_xi_ablation(quick=True, smoke=False):
    """Fig. 5: xi in {0, 0.01, 0.1, 1.0} at each split point."""
    model = cnn_lib.make_resnet18(NCLS, width=WIDTH)
    bb = _pretrain_backbone(model,
                            steps=40 if smoke else (150 if quick else 400))
    shapes = model.feature_shapes(IMG)
    xis = (0.0, 0.1) if smoke else (0.0, 0.01, 0.1, 1.0)
    rows = []
    for pi, k in enumerate(model.split_after[:1] if smoke
                           else (model.split_after[:2] if quick
                                 else model.split_after)):
        ch = shapes[k][0]
        for xi in xis:
            ae, _, _ = train_autoencoder(
                jax.random.PRNGKey(42), model, bb, k,
                _data_iter(seed0=900), ch=ch, ch_prime=max(1, ch // 8),
                steps=8 if smoke else (25 if quick else 100), lr=3e-3, xi=xi)
            x, y = synthetic_image_batch(jax.random.PRNGKey(40_000), 64, IMG,
                                         NCLS)
            acc = float(accuracy_with_ae(model, bb, ae, k, x, y, bits=8))
            rows.append({"point": pi + 1, "xi": xi, "acc": acc})
    return {"rows": rows}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(r)
    for r in run_xi_ablation()["rows"]:
        print(r)
