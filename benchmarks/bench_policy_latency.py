"""Train big, serve small: policy-latency bench for the distilled trunk.

At production scale the scheduler is itself a serving workload — the
policy prices a dispatch decision for every task arrival, so actor-
forward microseconds sit on the hot path of every Eq. 7/8 service (the
PR-8 streaming runtime measures them live as ``dispatch_us``). This
bench builds the full train-big/serve-small pipeline and prices it:

  1. TRAIN BIG — entity teacher on randomized pool geometries (the
     generalist recipe of ``bench_streaming``), then streaming-tuned by
     oracle distillation (quick/full; smoke skips the tune),
  2. SERVE SMALL — ``rl.distill`` DAgger-distills the teacher into the
     flat trunk (one fused MLP pass over ``observe_per_ue`` rows), then
     int8 weight-quantizes it for the fused dequant-matmul kernel
     (``kernels/flat_trunk.py``),
  3. PRICE IT — ``forward_us`` (the shared interleaved best-of-k
     harness) sweeps µs/decision at batch 1 and batch 10k for
     {entity teacher, distilled f32, distilled int8}, plus
     end-overhead fidelity on the deployment pool and a live
     ``TrunkDispatcher`` stream at mid load.

Batch semantics: a batch-1 "decision" is ONE dispatch — for the teacher
that is one entity forward over the live state (its N rows are
intrinsic to pricing a single dispatch, exactly how EntityDispatcher
runs it); for the trunk it is one feature row. At batch 10k the teacher
prices ceil(10k/N) vmapped states; the trunk streams a (10k, F) row
block through one fused pass — the serving-throughput regime where the
quantized kernel's resident weights pay off.

Ledger gates (quick/full): distilled-trunk/teacher overhead ratio
<= 1.05 on the deployment pool, distilled f32 batch-1 forward
<= 0.5x the teacher's µs, int8 kernel parity vs ``ref.flat_trunk_ref``,
trunk-dispatcher p99 <= nearest-server at mid-load streaming, and
student params <= 25% of the teacher's. Smoke keeps the training budget
tiny and gates only the training-free half: kernel parity, the param
ratio, and trunk-completes-tasks stream sanity.
"""
from __future__ import annotations

import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.fleets import (make_edge_pool, make_mixed_fleet,
                               random_pool_ranges)
from repro.env.mecenv import MECEnv, make_env_params
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.rl import nets
from repro.rl.distill import (DistillConfig, action_agreement,
                              distill_entity_policy, quantize_flat_trunk)
from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo
from repro.rl.streaming import StreamTuneConfig, finetune_streaming
from repro.stream.adapter import NearestServerDispatcher, TrunkDispatcher
from repro.stream.events import StreamParams, StreamSim

try:
    from benchmarks._timing import forward_us
except ImportError:                 # run directly as a script
    from _timing import forward_us

N_UE = 8
N_SERVERS = 2
MID_RATE = 4.0                      # bench_streaming's mid-load gate point
TUNE_RATES = (6.0, 14.0)
KERNEL_TOL = 1e-4                   # |fused - ref| bound (f32 accumulate)


def make_env(randomized=False) -> MECEnv:
    pool = make_edge_pool(N_SERVERS)
    ranges = random_pool_ranges(N_SERVERS) if randomized else None
    return MECEnv(make_env_params(make_mixed_fleet(n_ue=N_UE), n_channels=2,
                                  pool=pool, pool_ranges=ranges))


def _mode_actions(space, dist, masks):
    return jax.vmap(space.mode)(dist, masks)


def _kernel_parity(env, qstudent, student):
    """Training-free int8 checks: fused-impl-vs-oracle max |logit| error
    (xla AND interpret-mode pallas), int8-vs-f32 student logit error and
    mode-action agreement on a mixed real + random row batch."""
    space = env.action_space
    key = jax.random.PRNGKey(42)
    rows_env = env.observe_per_ue(env.reset(key))
    rows = jnp.concatenate([
        rows_env,
        jax.random.normal(key, (256, rows_env.shape[-1]))])
    ql, bits = qstudent["qlayers"], qstudent["bits"]
    args = ([l["codes"] for l in ql], [l["mn"] for l in ql],
            [l["mx"] for l in ql], [l["b"] for l in ql])
    out_ref = kref.flat_trunk_ref(rows, *args, bits)
    diffs = {}
    for impl in ("xla", "pallas"):
        out = kops.flat_trunk(rows, ql, bits=bits, impl=impl)
        diffs[impl] = float(jnp.abs(out - out_ref).max())
    out_q = kops.flat_trunk(rows, ql, bits=bits)
    out_f = nets._mlp(student["layers"], rows)
    masks = space.broadcast_masks(None, rows.shape[0])
    mq = _mode_actions(space, nets.trunk_head_dist(space, out_q, masks),
                       masks)
    mf = _mode_actions(space, nets.trunk_head_dist(space, out_f, masks),
                       masks)
    agree = np.mean([np.mean(np.asarray(mq[h.name] == mf[h.name]))
                     for h in space.discrete])
    return {"kernel_max_diff": diffs, "n_rows": int(rows.shape[0]),
            "int8_vs_f32_logit_err": float(jnp.abs(out_q - out_f).max()),
            "int8_vs_f32_mode_agree": float(agree)}


def _latency_cells(env, teacher, student, qstudent, batches):
    """Zero-arg jitted thunks for every (candidate, batch) cell. Params
    are closed over (frozen deployment weights — and the quantized form's
    static ``bits`` must not become a tracer)."""
    space = env.action_space
    n_ue = env.params.n_ue
    t_actor = teacher["entity_actor"]
    s0 = env.reset(jax.random.PRNGKey(0), eval_mode=True)
    rows0 = env.observe_per_ue(s0)
    masks0 = space.broadcast_masks(env.action_masks(s0), n_ue)

    def teacher_one(s):
        masks = space.broadcast_masks(env.action_masks(s), n_ue)
        dist = nets.entity_actor_forward(t_actor, space,
                                         env.observe_entities(s), masks)
        return _mode_actions(space, dist, masks)

    def student_fwd(p, rows, masks):
        return _mode_actions(
            space, nets.flat_trunk_forward(p, space, rows, masks), masks)

    cells, meta = {}, {}
    for b in batches:
        n_states = max(1, -(-b // n_ue))
        ss = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (n_states,) + x.shape), s0)
        t_fn = jax.jit(lambda ss=ss: jax.vmap(teacher_one)(ss)) \
            if b > 1 else jax.jit(lambda s=s0: teacher_one(s))
        reps = -(-b // n_ue)
        rows_b = jnp.tile(rows0, (reps, 1))[:b]
        masks_b = jax.tree.map(lambda m: jnp.tile(m, (reps, 1))[:b], masks0)
        # one teacher forward prices b decisions: ONE dispatch at batch 1
        # (the EntityDispatcher reality — its N rows are intrinsic), the
        # full stacked batch in throughput mode
        cells[f"teacher@{b}"] = t_fn
        meta[f"teacher@{b}"] = ("teacher", b, b)
        for name, p in (("student_f32", student), ("student_int8",
                                                   qstudent)):
            cells[f"{name}@{b}"] = jax.jit(
                lambda p=p, r=rows_b, m=masks_b: student_fwd(p, r, m))
            meta[f"{name}@{b}"] = (name, b, b)
    return cells, meta


def _stream_eval(env, mk_disp, sp, seeds):
    reps = []
    for seed in seeds:
        reps.append(StreamSim(env, mk_disp(seed), sp, seed=seed).run())
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")     # all-NaN tails at full drop
        agg = {k: float(np.nanmean([r[k] for r in reps]))
               for k in ("miss_rate", "sojourn_p50", "sojourn_p99",
                         "throughput")}
    agg["completed"] = int(sum(r["completed"] for r in reps))
    return agg


def run(quick=True, smoke=False):
    frame_iters = 3 if smoke else (30 if quick else 100)
    tune_iters = 0 if smoke else (14 if quick else 20)
    dcfg = DistillConfig(
        iterations=1 if smoke else 3, frames=8 if smoke else 64,
        n_envs=2 if smoke else 4, label_samples=2 if smoke else 4,
        epochs=10 if smoke else 150)
    eval_frames = 16 if smoke else 64
    eval_envs = 1 if smoke else 4
    seeds = (7,) if smoke else ((7, 8, 9, 10, 11) if quick
                                else tuple(range(7, 15)))
    horizon = 4.0 if smoke else 12.0
    batches = (1, 1000) if smoke else (1, 10_000)
    n_timed = 5 if smoke else 20

    # 1. train big: randomized-pool entity teacher, then streaming tune
    t0 = time.time()
    teacher, _ = train_mahppo(
        make_env(randomized=True),
        MAHPPOConfig(iterations=frame_iters, horizon=512, n_envs=4,
                     reuse=4, entity_policy=True, randomize_pool=True),
        seed=0)
    train_s = time.time() - t0
    env = make_env()
    t0 = time.time()
    if tune_iters:
        teacher, _ = finetune_streaming(
            env, teacher,
            [StreamParams(rate=r, horizon=8.0) for r in TUNE_RATES],
            StreamTuneConfig(iterations=tune_iters), seed=100)
    tune_s = time.time() - t0

    # 2. serve small: distill + int8-quantize
    t0 = time.time()
    student, hist = distill_entity_policy(env, teacher, dcfg, seed=0)
    distill_s = time.time() - t0
    qstudent = quantize_flat_trunk(student)

    # parameter accounting (satellite: the ledger asserts the student is
    # actually small)
    t_params = nets.param_count(teacher["entity_actor"])
    s_params = nets.param_count(student)
    params = {"teacher": t_params, "student": s_params,
              "ratio": s_params / t_params,
              "teacher_bytes": nets.param_bytes(teacher["entity_actor"]),
              "student_bytes_f32": nets.param_bytes(student),
              "student_bytes_int8": nets.param_bytes(qstudent)}

    # 3a. end-overhead fidelity on the deployment pool
    beta = float(env.params.beta)
    ovh = {}
    for name, agent in (("teacher", teacher),
                        ("student_f32", {"flat_trunk": student}),
                        ("student_int8", {"flat_trunk": qstudent})):
        ev = evaluate_policy(env, agent, frames=eval_frames, seed=1,
                             n_envs=eval_envs)
        ovh[name] = {"t_task": float(ev["t_task"]),
                     "e_task": float(ev["e_task"]),
                     "overhead": float(ev["t_task"] + beta * ev["e_task"])}
    fidelity = {"overheads": ovh,
                "ratio_f32": ovh["student_f32"]["overhead"]
                / ovh["teacher"]["overhead"],
                "ratio_int8": ovh["student_int8"]["overhead"]
                / ovh["teacher"]["overhead"],
                "agreement": action_agreement(env, teacher, student,
                                              states=256, seed=9)}

    # 3b. training-free kernel parity
    kernel = _kernel_parity(env, qstudent, student)

    # 3c. µs/decision sweep through the shared interleaved harness
    cells, meta = _latency_cells(env, teacher, student, qstudent, batches)
    fwd = forward_us(cells, n_timed=n_timed)
    lat_rows = []
    for label, stats in fwd.items():
        cand, b, decisions = meta[label]
        lat_rows.append({"candidate": cand, "batch": b,
                         "best_us": stats["best_us"],
                         "us_per_decision": stats["best_us"] / decisions,
                         "p50_us": stats["tail"]["p50"],
                         "p99_us": stats["tail"]["p99"]})
    by_lat = {(r["candidate"], r["batch"]): r for r in lat_rows}
    b1 = batches[0]
    # the DEPLOYED trunk's batch-1 latency win: best of f32/int8 (the
    # serving artifact is whichever the deployment picks; both are the
    # distilled trunk)
    batch1_ratio = min(by_lat[("student_f32", b1)]["best_us"],
                       by_lat[("student_int8", b1)]["best_us"]) \
        / by_lat[("teacher", b1)]["best_us"]

    # 3d. the int8 trunk as the live mid-load dispatcher
    sp = StreamParams(rate=MID_RATE, horizon=horizon)
    stream = {
        "trunk": _stream_eval(
            env, lambda s: TrunkDispatcher(env, qstudent, seed=s), sp,
            seeds),
        "nearest": _stream_eval(
            env, lambda s: NearestServerDispatcher(env), sp, seeds)}
    eps = 1e-3
    stream["p99_ratio"] = (stream["trunk"]["sojourn_p99"] + eps) \
        / (stream["nearest"]["sojourn_p99"] + eps)

    # ledger: training-free gates always; fidelity/latency/QoS gates once
    # the training budget is real (quick/full)
    parity = [
        {"name": "policy_int8_kernel_parity",
         "ratio": max(kernel["kernel_max_diff"].values()) / KERNEL_TOL,
         "limit": 1.0},
        {"name": "policy_student_param_ratio",
         "ratio": params["ratio"], "limit": 0.25}]
    if smoke:
        done = stream["trunk"]["completed"]
        parity.append({"name": "policy_trunk_completes_tasks",
                       "ratio": 0.0 if done > 0 else 2.0, "limit": 1.0})
    else:
        parity += [
            {"name": "policy_distill_overhead",
             "ratio": fidelity["ratio_f32"], "limit": 1.05},
            {"name": "policy_batch1_speedup",
             "ratio": batch1_ratio, "limit": 0.5},
            {"name": "policy_trunk_vs_nearest_p99_mid",
             "ratio": stream["p99_ratio"], "limit": 1.0}]

    return {"rows": lat_rows, "params": params, "fidelity": fidelity,
            "kernel": kernel, "stream": stream,
            "batch1_speedup": batch1_ratio, "batches": list(batches),
            "train_s": train_s, "tune_s": tune_s, "distill_s": distill_s,
            "distill_history": hist, "mid_rate": MID_RATE,
            "parity": parity}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"{r['candidate']:>13s}@{r['batch']:<6d}: "
              f"{r['best_us']:9.1f}us  "
              f"{r['us_per_decision']:8.3f}us/decision")
    print(f"params: student/teacher = {out['params']['ratio']:.3f} "
          f"({out['params']['student']}/{out['params']['teacher']}), "
          f"int8 bytes {out['params']['student_bytes_int8']}")
    print(f"overhead ratios: f32 {out['fidelity']['ratio_f32']:.3f} "
          f"int8 {out['fidelity']['ratio_int8']:.3f}")
    print(f"stream p99 trunk/nearest: {out['stream']['p99_ratio']:.3f}")
    for p in out["parity"]:
        flag = "OK" if p["ratio"] <= p["limit"] else "FAIL"
        print(f"{p['name']}: {p['ratio']:.3f} (limit {p['limit']}) {flag}")
