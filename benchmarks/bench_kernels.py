"""Kernel micro-harness: wall time per call (interpret mode on CPU — the
numbers are correctness-path timings, not TPU perf; TPU perf comes from the
roofline terms) plus the compressor's analytic TPU-side cost."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

try:
    from benchmarks._timing import call_us as _time
except ImportError:        # run directly as a script
    from _timing import call_us as _time


def run():
    rows = []
    x = jax.random.normal(jax.random.PRNGKey(0), (1024, 2048), jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (2048, 512), jnp.bfloat16) * 0.02
    us = _time(lambda a: ops.quantize(a, -4.0, 4.0), x)
    # analytic TPU latency: memory bound, read bf16 + write u8
    tpu_us = (x.size * 3) / HBM_BW * 1e6
    rows.append({"name": "kernel_quantize_1024x2048", "us_per_call": us,
                 "derived": f"tpu_roofline_us={tpu_us:.2f}"})
    us = _time(lambda a, b: ops.bottleneck_encode(a, b, -4.0, 4.0), x, w)
    fl = 2 * 1024 * 2048 * 512
    tpu_us = max(fl / PEAK_FLOPS_BF16, (x.size * 2 + w.size * 2) / HBM_BW) * 1e6
    rows.append({"name": "kernel_bottleneck_1024x2048x512", "us_per_call": us,
                 "derived": f"tpu_roofline_us={tpu_us:.2f}"})
    q = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 128))
    k = jax.random.normal(jax.random.PRNGKey(3), (4, 2048, 2, 128))
    v = jax.random.normal(jax.random.PRNGKey(4), (4, 2048, 2, 128))
    pos = jnp.broadcast_to(jnp.arange(2048), (4, 2048))
    us = _time(lambda a, b, c: ops.decode_attention(a, b, c, pos, 2047),
               q, k, v)
    tpu_us = (k.size + v.size) * 4 / HBM_BW * 1e6
    rows.append({"name": "kernel_decode_attn_b4_s2048", "us_per_call": us,
                 "derived": f"tpu_roofline_us={tpu_us:.2f}"})
    return {"rows": rows}


if __name__ == "__main__":
    for r in run()["rows"]:
        print(r)
