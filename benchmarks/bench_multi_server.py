"""Multi-server edge benchmark: MAHPPO learning to load-balance a 2-server
pool (TPU-v5e near the cell center + a farther edge-GPU tier) against the
fixed-routing references:

* nearest-server greedy — every UE routes to the closest server; the whole
  fleet shares its two channels and pays the interference
* load-aware round-robin — balanced UE counts, interference-oblivious
* route-aware greedy — per-UE best (split, server) under a clean channel
  (collapses to nearest-server here: the near v5e dominates every
  independent comparison, which is exactly the trap)
* all-local

Also times the jitted MAHPPO iteration on the pool env vs the
single-server env of the same fleet: the route head adds one categorical
branch and a (N,)-gather — the guard keeps it within `PARITY_LIMIT`x.
"""
from __future__ import annotations

import time

from repro.core.cnn import make_resnet18
from repro.core.fleets import make_edge_pool
from repro.core.split import cnn_split_table
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl.baselines import (load_aware_eval, local_policy_eval,
                                nearest_server_eval)
from repro.rl.heuristics import greedy_eval
from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo

PARITY_LIMIT = 1.2
# wall-clock ratios from a handful of timed iterations are noisy on
# shared CI runners; the smoke gate only guards gross regressions
PARITY_LIMIT_SMOKE = 1.5
N_UE = 4


def make_pool_env(n_servers: int = 2, n_ue: int = N_UE) -> MECEnv:
    plan = cnn_split_table(make_resnet18(101), 224)
    pool = make_edge_pool(n_servers) if n_servers > 1 else None
    return MECEnv(make_env_params(plan, n_ue=n_ue, n_channels=2, pool=pool))


def run(quick=True, smoke=False):
    iters = 3 if smoke else (30 if quick else 100)
    env = make_pool_env(2)
    beta = float(env.params.beta)

    t0 = time.time()
    cfg = MAHPPOConfig(iterations=iters, horizon=512, n_envs=4, reuse=4)
    agent, hist = train_mahppo(env, cfg, seed=0)
    train_s = time.time() - t0

    ev = evaluate_policy(env, agent, frames=64)
    mahppo_ovh = ev["t_task"] + beta * ev["e_task"]
    near = nearest_server_eval(env)
    load = load_aware_eval(env)
    gr = greedy_eval(env)
    lo = local_policy_eval(env, frames=64)
    rows = [
        {"policy": "mahppo", "t_task": ev["t_task"], "e_task": ev["e_task"],
         "overhead": mahppo_ovh, "reward": ev["reward"]},
        {"policy": "nearest_server", "t_task": near["t_task"],
         "e_task": near["e_task"], "overhead": near["overhead"],
         "route": near["route"]},
        {"policy": "load_aware", "t_task": load["t_task"],
         "e_task": load["e_task"], "overhead": load["overhead"],
         "route": load["route"]},
        {"policy": "greedy", "t_task": gr["t_task"], "e_task": gr["e_task"],
         "overhead": gr["overhead"], "route": gr["route"]},
        {"policy": "local", "t_task": lo["t_task"], "e_task": lo["e_task"],
         "overhead": lo["t_task"] + beta * lo["e_task"],
         "reward": lo["reward"]},
    ]

    # hot-path regression guard: pool env vs single-server env, same fleet
    try:
        from benchmarks.bench_hetero_fleet import _iter_us
    except ImportError:        # run directly as a script
        from bench_hetero_fleet import _iter_us
    tcfg = MAHPPOConfig(horizon=512, n_envs=4, reuse=2)
    us_single = _iter_us(make_pool_env(1), tcfg)
    us_multi = _iter_us(env, tcfg)
    ratio = us_multi / max(us_single, 1e-9)
    limit = PARITY_LIMIT_SMOKE if smoke else PARITY_LIMIT
    return {"rows": rows, "train_s": train_s,
            "beats_nearest": bool(mahppo_ovh <= near["overhead"]),
            "iter_us_single": us_single, "iter_us_multi": us_multi,
            "iter_ratio": ratio,
            "parity": [{"name": "multi_vs_single_iteration",
                        "ratio": ratio, "limit": limit}]}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        extra = f" route={r['route']}" if "route" in r else ""
        print(f"{r['policy']:>14s}: overhead {r['overhead']:.4f} "
              f"(t {1e3*r['t_task']:.1f} ms, e {1e3*r['e_task']:.1f} mJ)"
              f"{extra}")
    print(f"MAHPPO {'BEATS' if out['beats_nearest'] else 'LOSES TO'} "
          f"nearest-server greedy")
    print(f"iteration: single {out['iter_us_single']/1e3:.1f} ms, "
          f"pool {out['iter_us_multi']/1e3:.1f} ms "
          f"(ratio {out['iter_ratio']:.2f}, limit {PARITY_LIMIT})")
