"""Multi-server edge benchmark: MAHPPO learning to load-balance a 2-server
pool (TPU-v5e near the cell center + a farther edge-GPU tier) against the
fixed-routing references:

* nearest-server greedy — every UE routes to the closest server; the whole
  fleet shares its two channels and pays the interference
* load-aware round-robin — balanced UE counts, interference-oblivious
* route-aware greedy — per-UE best (split, server) under a clean channel
  (collapses to nearest-server here: the near v5e dominates every
  independent comparison, which is exactly the trap)
* all-local

Also times the jitted MAHPPO iteration on the pool env vs the
single-server env of the same fleet: the route head adds one categorical
branch and a (N,)-gather — the guard keeps it within `PARITY_LIMIT`x.

``run_churn_routing`` is the ROADMAP PR-3 follow-up — routing coupled
with membership dynamics: a policy trained on the 2-server pool WITH UE
churn is probed at a sparse membership (2 live UEs — the near v5e's two
channels fit them interference-free, so piling on is optimal) and at a
flash crowd (every standby UE joins at once). The route head must
REBALANCE: the crowd's offloads may not all pile onto one server, gated
through the ledger as max-server-share ≤ REBALANCE_LIMIT.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cnn import make_resnet18
from repro.core.fleets import make_edge_pool
from repro.core.split import cnn_split_table
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl.baselines import (load_aware_eval, local_policy_eval,
                                nearest_server_eval)
from repro.rl.heuristics import greedy_eval
from repro.rl.mahppo import (MAHPPOConfig, _policy_all, evaluate_policy,
                             train_mahppo)

PARITY_LIMIT = 1.2
# wall-clock ratios from a handful of timed iterations are noisy on
# shared CI runners; the smoke gate only guards gross regressions
PARITY_LIMIT_SMOKE = 1.5
N_UE = 4
# flash-crowd offloads may not pile onto one server: with ≥ 2 of the 6
# crowd UEs offloading, ≤ 0.9 forces at least one onto another server.
# A 3-iteration smoke policy hasn't learned to route yet — report-only.
REBALANCE_LIMIT = 0.9
REBALANCE_LIMIT_SMOKE = 1.01
CHURN_N_UE = 6


def make_pool_env(n_servers: int = 2, n_ue: int = N_UE,
                  churn_rate: float = 0.0,
                  leave_rate: float = 0.0) -> MECEnv:
    plan = cnn_split_table(make_resnet18(101), 224)
    pool = make_edge_pool(n_servers) if n_servers > 1 else None
    return MECEnv(make_env_params(plan, n_ue=n_ue, n_channels=2, pool=pool,
                                  churn_rate=churn_rate,
                                  leave_rate=leave_rate))


def run(quick=True, smoke=False):
    iters = 3 if smoke else (30 if quick else 100)
    env = make_pool_env(2)
    beta = float(env.params.beta)

    t0 = time.time()
    cfg = MAHPPOConfig(iterations=iters, horizon=512, n_envs=4, reuse=4)
    agent, hist = train_mahppo(env, cfg, seed=0)
    train_s = time.time() - t0

    ev = evaluate_policy(env, agent, frames=64)
    mahppo_ovh = ev["t_task"] + beta * ev["e_task"]
    near = nearest_server_eval(env)
    load = load_aware_eval(env)
    gr = greedy_eval(env)
    lo = local_policy_eval(env, frames=64)
    rows = [
        {"policy": "mahppo", "t_task": ev["t_task"], "e_task": ev["e_task"],
         "overhead": mahppo_ovh, "reward": ev["reward"]},
        {"policy": "nearest_server", "t_task": near["t_task"],
         "e_task": near["e_task"], "overhead": near["overhead"],
         "route": near["route"]},
        {"policy": "load_aware", "t_task": load["t_task"],
         "e_task": load["e_task"], "overhead": load["overhead"],
         "route": load["route"]},
        {"policy": "greedy", "t_task": gr["t_task"], "e_task": gr["e_task"],
         "overhead": gr["overhead"], "route": gr["route"]},
        {"policy": "local", "t_task": lo["t_task"], "e_task": lo["e_task"],
         "overhead": lo["t_task"] + beta * lo["e_task"],
         "reward": lo["reward"]},
    ]

    # hot-path regression guard: pool env vs single-server env, same fleet
    try:
        from benchmarks._timing import iter_us as _iter_us
    except ImportError:        # run directly as a script
        from _timing import iter_us as _iter_us
    tcfg = MAHPPOConfig(horizon=512, n_envs=4, reuse=2)
    us_single = _iter_us(make_pool_env(1), tcfg)
    us_multi = _iter_us(env, tcfg)
    ratio = us_multi / max(us_single, 1e-9)
    limit = PARITY_LIMIT_SMOKE if smoke else PARITY_LIMIT
    return {"rows": rows, "train_s": train_s,
            "beats_nearest": bool(mahppo_ovh <= near["overhead"]),
            "iter_us_single": us_single, "iter_us_multi": us_multi,
            "iter_ratio": ratio,
            "parity": [{"name": "multi_vs_single_iteration",
                        "ratio": ratio, "limit": limit}]}


def _mode_routes(env, agent, active):
    """Deterministic (split, route) decisions at an eval-mode state with a
    planted membership mask; returns the offloading mask and per-server
    offload counts (full-local UEs touch no server)."""
    space = env.action_space
    s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
    s = s._replace(active=jnp.asarray(active))
    masks = env.action_masks(s)
    dist = _policy_all(agent["actors"], space, env.observe(s), masks)
    a = jax.vmap(space.mode)(dist, masks)
    b = np.asarray(a["split"])
    route = np.asarray(a["route"])
    offl = np.asarray(active) & (b != env.n_actions_b - 1)
    counts = np.bincount(route[offl], minlength=env.n_servers)
    return {"splits": b.tolist(), "routes": route.tolist(),
            "offloading": int(offl.sum()), "counts": counts.tolist(),
            "max_share": float(counts.max() / max(counts.sum(), 1))}


def run_churn_routing(quick=True, smoke=False):
    """Routing under churn: train on the churning 2-server pool, then
    probe the learned route head at sparse membership vs a flash crowd
    (see module docstring). The rebalance gate rides the same ledger as
    the parity guards."""
    iters = 3 if smoke else (30 if quick else 100)
    env = make_pool_env(2, n_ue=CHURN_N_UE, churn_rate=0.4, leave_rate=0.1)
    t0 = time.time()
    cfg = MAHPPOConfig(iterations=iters, horizon=512, n_envs=4, reuse=4)
    agent, _ = train_mahppo(env, cfg, seed=0)
    train_s = time.time() - t0

    sparse = _mode_routes(env, agent, [True, True] + [False]
                          * (CHURN_N_UE - 2))
    flash = _mode_routes(env, agent, [True] * CHURN_N_UE)
    limit = REBALANCE_LIMIT_SMOKE if smoke else REBALANCE_LIMIT
    # the gate needs the probe's premise: at least 2 crowd UEs offloading.
    # Fewer means the trained policy stopped offloading under load — a
    # scheduler collapse, not a rebalance — so the ratio pins to 1.0 and
    # FAILS the quick/full ledger instead of passing vacuously (0
    # offloaders would otherwise score 0.0, one would score 1.0 by
    # arithmetic accident).
    ratio = flash["max_share"] if flash["offloading"] >= 2 else 1.0
    return {"train_s": train_s, "sparse": sparse, "flash": flash,
            "rebalances": bool(flash["max_share"] < 1.0
                               and flash["offloading"] >= 2),
            "parity": [{"name": "flash_crowd_max_server_share",
                        "ratio": ratio, "limit": limit}]}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        extra = f" route={r['route']}" if "route" in r else ""
        print(f"{r['policy']:>14s}: overhead {r['overhead']:.4f} "
              f"(t {1e3*r['t_task']:.1f} ms, e {1e3*r['e_task']:.1f} mJ)"
              f"{extra}")
    print(f"MAHPPO {'BEATS' if out['beats_nearest'] else 'LOSES TO'} "
          f"nearest-server greedy")
    print(f"iteration: single {out['iter_us_single']/1e3:.1f} ms, "
          f"pool {out['iter_us_multi']/1e3:.1f} ms "
          f"(ratio {out['iter_ratio']:.2f}, limit {PARITY_LIMIT})")
    cr = run_churn_routing()
    print(f"churn routing: sparse counts={cr['sparse']['counts']} "
          f"(share {cr['sparse']['max_share']:.2f}) -> flash "
          f"counts={cr['flash']['counts']} "
          f"(share {cr['flash']['max_share']:.2f}) "
          f"[{'REBALANCES' if cr['rebalances'] else 'PILES UP'}]")
