"""Fig. 8 + Fig. 9: MAHPPO convergence vs Local / JALAD baselines on
ResNet18, plus the hyperparameter sweeps (lr, sample-reuse, memory size)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.cnn import make_resnet18
from repro.core.split import cnn_jalad_table, cnn_split_table
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl.baselines import local_policy_eval
from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo


def _train(plan, *, iterations, t0=0.5, n_ue=5, seed=0, **ppo_kw):
    env = MECEnv(make_env_params(plan, n_ue=n_ue, n_channels=2, t0=t0))
    cfg = MAHPPOConfig(iterations=iterations, **ppo_kw)
    agent, hist = train_mahppo(env, cfg, seed=seed)
    return env, agent, hist


def run(quick=True):
    iters = 70 if quick else 200
    model = make_resnet18(101)
    plan = cnn_split_table(model, 224)
    jplan = cnn_jalad_table(model, 224)
    t0 = time.time()

    env, agent, hist = _train(plan, iterations=iters, horizon=1024, n_envs=8)
    # JALAD baseline: same algorithm, JALAD tables, relaxed frame (paper: 3 s)
    jenv, jagent, jhist = _train(jplan, iterations=iters, t0=3.0,
                                 horizon=1024, n_envs=8)
    ev = evaluate_policy(env, agent, frames=64)
    jev = evaluate_policy(jenv, jagent, frames=64)
    lo = local_policy_eval(env, frames=64)
    # non-RL references: interference-oblivious greedy and (N<=5) the
    # exhaustive static-oracle joint policy
    from repro.rl.heuristics import greedy_eval, oracle_static_eval
    refs = {"greedy": greedy_eval(env)}
    try:
        refs["oracle_static"] = oracle_static_eval(env)
    except ValueError:
        pass
    return {
        "mahppo_curve": [h["reward_mean"] for h in hist],
        "jalad_curve": [h["reward_mean"] for h in jhist],
        "jalad_curve_scaled": [h["reward_mean"] / 6.0 for h in jhist],
        "eval": {"mahppo": ev, "jalad": jev, "local": lo},
        "refs": refs,
        "seconds": time.time() - t0,
    }


def run_hparams(quick=True):
    """Fig. 9: lr, reuse-time, memory-size sweeps (final rewards)."""
    iters = 25 if quick else 120
    plan = cnn_split_table(make_resnet18(101), 224)
    out = {}
    for lr in (1e-5, 1e-4, 1e-3):
        _, _, h = _train(plan, iterations=iters, horizon=1024, n_envs=8,
                         lr=lr)
        out[f"lr={lr}"] = float(np.mean([x["reward_mean"] for x in h[-5:]]))
    for reuse in (1, 10, 20, 80):
        _, _, h = _train(plan, iterations=iters, horizon=1024, n_envs=8,
                         reuse=reuse)
        out[f"reuse={reuse}"] = float(np.mean([x["reward_mean"] for x in h[-5:]]))
    for mem in (256, 1024, 4096):
        _, _, h = _train(plan, iterations=max(4, iters * 1024 // mem),
                         horizon=mem, n_envs=8, batch=mem // 4)
        out[f"mem={mem}"] = float(np.mean([x["reward_mean"] for x in h[-5:]]))
    return out


if __name__ == "__main__":
    out = run()
    print("mahppo last-5 reward:", np.mean(out["mahppo_curve"][-5:]))
    print("jalad  last-5 reward (x1/6):",
          np.mean(out["jalad_curve_scaled"][-5:]))
    print(out["eval"])
