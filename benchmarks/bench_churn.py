"""Dynamic-fleet benchmark: UE churn (join/leave mid-episode).

Trains MAHPPO on the same 4-UE CNN fleet under 0% / 10% / 30% churn and
compares the learned policy against the all-local baseline on each env.
Churn level x maps to leave_rate=x (geometric sessions) and churn_rate=2x
(Poisson re-joins at twice the leave intensity, so the steady-state fleet
stays mostly populated).

Also times the jitted training iteration on the static env vs the churning
env of the same size — the active-mask path must not regress the hot loop.
"""
from __future__ import annotations

import time

from repro.core.cnn import make_resnet18
from repro.core.split import cnn_split_table
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl.baselines import local_policy_eval
from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo

CHURN_LEVELS = (0.0, 0.1, 0.3)


def make_churn_env(level: float, n_ue: int = 4) -> MECEnv:
    plan = cnn_split_table(make_resnet18(101), 224)
    return MECEnv(make_env_params(plan, n_ue=n_ue, n_channels=2,
                                  churn_rate=2.0 * level, leave_rate=level))


def run(quick=True):
    iters = 25 if quick else 80
    rows = []
    t0 = time.time()
    for level in CHURN_LEVELS:
        env = make_churn_env(level)
        cfg = MAHPPOConfig(iterations=iters, horizon=512, n_envs=4, reuse=4)
        agent, hist = train_mahppo(env, cfg, seed=0)
        ev = evaluate_policy(env, agent, frames=64)
        lo = local_policy_eval(env, frames=64)
        rows.append({
            "churn": level,
            "mahppo_reward": ev["reward"], "local_reward": lo["reward"],
            "t_task": ev["t_task"], "e_task": ev["e_task"],
            "local_t_task": lo["t_task"], "local_e_task": lo["e_task"],
            "n_active_mean": ev["n_active"],
            "beats_local": bool(ev["reward"] > lo["reward"])})
    train_s = time.time() - t0

    # hot-path regression guard: churning env vs static env, same N. The
    # mask is data, not structure, so the jitted iteration should stay at
    # parity (the churn env adds 2N obs features + 4 per-step RNG draws).
    try:
        from benchmarks._timing import iter_us as _iter_us
    except ImportError:        # run directly as a script
        from _timing import iter_us as _iter_us
    tcfg = MAHPPOConfig(horizon=512, n_envs=4, reuse=2)
    us_static = _iter_us(make_churn_env(0.0), tcfg)
    us_churn = _iter_us(make_churn_env(0.1), tcfg)
    return {"rows": rows, "train_s": train_s,
            "iter_us_static": us_static, "iter_us_churn": us_churn,
            "iter_ratio": us_churn / max(us_static, 1e-9)}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"churn={r['churn']:.0%}: mahppo reward {r['mahppo_reward']:.4f}"
              f" vs local {r['local_reward']:.4f} "
              f"({'BEATS' if r['beats_local'] else 'loses to'} local), "
              f"latency {1e3*r['t_task']:.1f} ms, "
              f"mean fleet {r['n_active_mean']:.2f} UEs")
    print(f"iteration: static {out['iter_us_static']/1e3:.1f} ms, "
          f"churn {out['iter_us_churn']/1e3:.1f} ms "
          f"(ratio {out['iter_ratio']:.2f})")
