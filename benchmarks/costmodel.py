"""Analytic MODEL_FLOPS + memory-traffic model per (arch x shape), used by
the roofline analysis alongside the HLO-derived numbers.

MODEL_FLOPS convention (spec): 6*N*D for dense training, 6*N_active*D for
MoE; serve: 2*N(_active) per generated/processed token (+attention terms are
reported separately since they are context-length dependent).
"""
from __future__ import annotations

import jax

from repro.configs.base import INPUT_SHAPES, ModelConfig


def param_counts(cfg: ModelConfig):
    """(total_params, active_params) from the real parameter tree."""
    from repro.launch.steps import params_spec
    pstruct = params_spec(cfg)
    total = sum(int(l.size) for l in jax.tree_util.tree_leaves(pstruct))
    active = total
    if cfg.moe is not None:
        m = cfg.moe
        expert_params = 3 * cfg.d_model * m.d_expert  # wi+wg+wo per expert
        n_moe_layers = sum(1 for b in cfg.block_types() if b == "moe")
        dead = n_moe_layers * expert_params * (m.n_experts - m.top_k)
        active = total - dead
    return total, active


def embed_params(cfg: ModelConfig):
    n = cfg.vocab_size * cfg.d_model
    return n if cfg.tie_embeddings else 2 * n


def model_flops(cfg: ModelConfig, shape_name: str):
    """Global useful FLOPs of one step."""
    shape = INPUT_SHAPES[shape_name]
    total, active = param_counts(cfg)
    emb = embed_params(cfg)
    body = active - emb
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * body * tokens + 2.0 * tokens * cfg.d_model * cfg.vocab_size * 3
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * body * tokens + 2.0 * shape.global_batch * cfg.d_model * cfg.vocab_size
    # decode: one token per sequence
    tokens = shape.global_batch
    return 2.0 * body * tokens + 2.0 * tokens * cfg.d_model * cfg.vocab_size


def llm_serve_flops(cfg: ModelConfig, ctx_len: int, gen_tokens: int = 1):
    """MODEL_FLOPS-convention total for serving ONE request: 2*N_active
    per context token (prefill) + per generated token, + the lm head per
    generated token. Attention terms are excluded by convention — the
    cross-check against core.overhead's per-layer tables (which include
    them) in bench_llm_offload is expected to agree to O(1), not exactly."""
    _, active = param_counts(cfg)
    body = active - embed_params(cfg)
    head = 2.0 * cfg.d_model * cfg.vocab_size
    return 2.0 * body * ctx_len + gen_tokens * (2.0 * body + head)


def memory_bytes_per_device(rec: dict, shape_name: str):
    """Roofline memory traffic per device per step, from dry-run sizes:
    decode: params + cache read once; train: params read(fwd+bwd) + grads
    written + opt state read+write; prefill: params + cache written."""
    shape = INPUT_SHAPES[shape_name]
    p = rec.get("param_bytes_per_device", 0)
    if shape.kind == "train":
        o = rec.get("opt_bytes_per_device", 0)
        return 3.0 * p + 2.0 * o
    c = rec.get("cache_bytes_per_device", 0)
    return p + c
