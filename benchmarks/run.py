"""Benchmark harness entrypoint — one section per paper table/figure plus
the roofline analysis. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--full|--smoke] [--only SECTION]

Sections that guard a jitted-iteration parity ratio (hetero, churn,
multi_server, generalization) report it into a shared ledger; any ratio
above its limit makes the run EXIT NONZERO with a summary line, so CI
catches hot-path regressions instead of scrolling past them. ``--smoke``
runs the RL sections at tiny iteration counts (CI-sized) and still emits
the standardized ``artifacts/BENCH_multi_server.json``,
``artifacts/BENCH_generalization.json``, ``artifacts/BENCH_entity.json``,
``artifacts/BENCH_ue_scaling.json``, ``artifacts/BENCH_streaming.json``,
``artifacts/BENCH_compression.json``,
``artifacts/BENCH_llm_offload.json`` and
``artifacts/BENCH_policy_latency.json`` artifacts. The policy_latency
ledger enforces the train-big/serve-small story: the distilled trunk
within 5% of its entity teacher's mean overhead on the deployment pool,
distilled batch-1 forward at most 0.5x the teacher's µs, int8 fused
kernel parity vs the ``kernels/ref.py`` oracle, the trunk dispatcher
p99 at most nearest-server's at mid-load streaming, and student params
at most 25% of the teacher's (parity/params gated in smoke too). The ue_scaling ledger enforces the giant-fleet story: per-UE
jitted iteration cost at N=256 at most 0.5x the N=16 per-UE cost, and
the fused pair-scorer kernel beating its naive reference on call_us at
N>=256 while matching it numerically. The generalization ledger also
enforces the zero-shot WINS: shared/greedy at n8/n16, and the entity
policy vs nearest-server greedy on the inverted alt-pool layout and an
unseen E=3 pool. The streaming ledger enforces the QoS wins: the
streaming-fine-tuned entity dispatcher vs nearest-server on p99 sojourn
at mid load and deadline-miss rate at saturation (quick/full; smoke
enforces the training-free oracle on the same two gates). The
llm_offload ledger enforces the mixed CNN+LLM pool story: the entity
policy vs nearest-server greedy, and the long-context rung's realized
throughput vs its split table's Eq. 7/8 closed form (training-free —
gated in smoke too).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np


def _emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _section(name):
    print(f"# --- {name} ---", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale RL iteration counts (slow)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny iteration counts (CI smoke); artifacts are "
                         "still written")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    quick = not args.full
    smoke = args.smoke
    results = {}
    parity_checks = []   # (section, name, ratio, limit)

    def want(s):
        return args.only is None or args.only == s

    def guard(section, name, ratio, limit):
        parity_checks.append((section, name, float(ratio), float(limit)))

    print("name,us_per_call,derived")

    if want("kernels"):
        _section("kernels (interpret-mode timing + TPU roofline)")
        from benchmarks import bench_kernels
        out = bench_kernels.run()
        results["kernels"] = out
        for r in out["rows"]:
            _emit(r["name"], r["us_per_call"], r["derived"])

    if want("compression"):
        _section("fig4/5 compression (AE vs JALAD, xi ablation)")
        from benchmarks import bench_compression
        t0 = time.time()
        out = bench_compression.run(quick=quick, smoke=smoke)
        results["compression"] = out
        per = (time.time() - t0) * 1e6 / max(len(out["rows"]), 1)
        for r in out["rows"]:
            _emit(f"fig4_point{r['point']}", per,
                  f"ae_rate={r['ae_rate']:.0f};jalad_rate={r['jalad_rate']:.1f};"
                  f"acc={r['ae_acc']:.3f};base={r['base_acc']:.3f}")
        xi = bench_compression.run_xi_ablation(quick=quick, smoke=smoke)
        results["xi"] = xi
        for r in xi["rows"]:
            _emit(f"fig5_point{r['point']}_xi{r['xi']}", 0.0,
                  f"acc={r['acc']:.3f}")
        os.makedirs("artifacts", exist_ok=True)
        artifact = {"bench": "compression", "schema": 1,
                    "smoke": smoke, "quick": quick,
                    "rows": out["rows"], "xi_rows": xi["rows"]}
        with open("artifacts/BENCH_compression.json", "w") as f:
            json.dump(artifact, f, indent=1, default=float)
        print("# wrote artifacts/BENCH_compression.json", flush=True)

    if want("overhead"):
        _section("fig7 overhead tables + long-task throughput rungs")
        from benchmarks import bench_overhead
        out = bench_overhead.run()
        results["overhead"] = out
        for r in out["rows"]:
            if r["backbone"] in ("resnet18", "qwen3-1.7b"):
                _emit(f"fig7_{r['backbone']}_b{r['b']}", 0.0,
                      f"t_ms={r['t_local_ms']:.1f};e_mJ={r['e_local_mJ']:.1f};"
                      f"f_kbits={r['f_kbits']:.0f}")
        # long-task rungs: completion throughput vs the Eq. 7/8 closed
        # form once t_task exceeds the frame length (the pre-PR-7 restart
        # bug starved exactly these; the ledger keeps them honest)
        long_out = bench_overhead.run_long_tasks(smoke=smoke)
        results["overhead_long_tasks"] = long_out
        for r in long_out["rows"]:
            _emit(f"overhead_long_task_x{r['frames_per_task']:.1f}", 0.0,
                  f"t_task_ms={r['t_task_ms']:.1f};"
                  f"expected={r['expected_per_frame']:.4f};"
                  f"realized={r['realized_per_frame']:.4f};"
                  f"ratio={r['ratio']:.3f}")
        for p in long_out["parity"]:
            guard("overhead", p["name"], p["ratio"], p["limit"])
        os.makedirs("artifacts", exist_ok=True)
        artifact = {"bench": "overhead", "schema": 1,
                    "smoke": smoke, "quick": quick,
                    "fig7_rows": out["rows"],
                    "long_task_rows": long_out["rows"],
                    "parity": long_out["parity"]}
        with open("artifacts/BENCH_overhead.json", "w") as f:
            json.dump(artifact, f, indent=1, default=float)
        print("# wrote artifacts/BENCH_overhead.json", flush=True)

    if want("convergence"):
        _section("fig8 convergence (MAHPPO vs local vs JALAD)")
        from benchmarks import bench_convergence
        t0 = time.time()
        out = bench_convergence.run(quick=quick)
        results["convergence"] = out
        iters = len(out["mahppo_curve"])
        us = (time.time() - t0) * 1e6 / max(iters, 1)
        _emit("fig8_mahppo_final_reward", us,
              f"{np.mean(out['mahppo_curve'][-5:]):.4f}")
        # JALAD runs at T0=3s (paper relaxation); per-frame rewards are
        # throughput-normalized by the reward definition, so raw values
        # compare directly (more negative = worse).
        _emit("fig8_jalad_final_reward", us,
              f"{np.mean(out['jalad_curve'][-5:]):.4f}")
        ev = out["eval"]
        _emit("fig8_eval_t_ms", us,
              f"mahppo={1e3*ev['mahppo']['t_task']:.1f};"
              f"local={1e3*ev['local']['t_task']:.1f}")
        _emit("fig8_eval_e_mJ", us,
              f"mahppo={1e3*ev['mahppo']['e_task']:.1f};"
              f"local={1e3*ev['local']['e_task']:.1f}")
        for name, r in out.get("refs", {}).items():
            _emit(f"fig8_ref_{name}", 0.0,
                  f"t_ms={1e3*r['t_task']:.1f};e_mJ={1e3*r['e_task']:.1f};"
                  f"overhead={r['overhead']:.4f}")

    if want("hparams"):
        _section("fig9 hyperparameter sweeps (lr / reuse / memory)")
        from benchmarks import bench_convergence
        t0 = time.time()
        out = bench_convergence.run_hparams(quick=quick)
        results["hparams"] = out
        us = (time.time() - t0) * 1e6 / max(len(out), 1)
        for k, v in out.items():
            _emit(f"fig9_{k}", us, f"final_reward={v:.4f}")

    if want("ue_scaling"):
        _section("giant-fleet scaling (per-UE iteration cost N=16..1024 "
                 "+ fused pair-scorer kernel)")
        from benchmarks import bench_ue_scaling
        out = bench_ue_scaling.run(quick=quick, smoke=smoke)
        results["ue_scaling"] = out
        for r in out["rows"]:
            _emit(f"ue_scaling_n{r['n_ue']}", r["iter_us"],
                  f"per_ue_us={r['per_ue_us']:.1f};frames={r['frames']}")
        for r in out["kernel_rows"]:
            _emit(f"pair_scorer_n{r['n']}", r["fused_us"],
                  f"ref_us={r['ref_us']:.1f};ratio={r['ratio']:.2f};"
                  f"max_diff={r['max_diff']:.2e};"
                  f"pallas_max_diff={r['pallas_max_diff']:.2e}")
        _emit("ue_scaling_per_ue_sublinear", 0.0,
              f"ratio={out['per_ue_sublinear']:.3f};"
              f"limit={bench_ue_scaling.SUBLINEAR_LIMIT}")
        for p in out["parity"]:
            guard("ue_scaling", p["name"], p["ratio"], p["limit"])
        os.makedirs("artifacts", exist_ok=True)
        artifact = {"bench": "ue_scaling", "schema": 1,
                    "smoke": smoke, "quick": quick,
                    "rows": out["rows"],
                    "kernel_rows": out["kernel_rows"],
                    "per_ue_sublinear": out["per_ue_sublinear"],
                    "parity": out["parity"]}
        with open("artifacts/BENCH_ue_scaling.json", "w") as f:
            json.dump(artifact, f, indent=1, default=float)
        print("# wrote artifacts/BENCH_ue_scaling.json", flush=True)

    if want("beta"):
        _section("fig12 beta trade-off")
        from benchmarks import bench_beta
        t0 = time.time()
        out = bench_beta.run(quick=quick)
        results["beta"] = out
        us = (time.time() - t0) * 1e6 / max(len(out["rows"]), 1)
        for r in out["rows"]:
            _emit(f"fig12_beta{r['beta']}", us,
                  f"t_ms={r['t_ms']:.1f};e_mJ={r['e_mJ']:.1f}")

    if want("hetero"):
        _section("heterogeneous fleet (mixed backbones + device tiers)")
        from benchmarks import bench_hetero_fleet
        out = bench_hetero_fleet.run(quick=quick)
        results["hetero"] = out
        for r in out["rows"]:
            _emit(f"hetero_{r['policy']}", 0.0,
                  f"t_ms={1e3*r['t_task']:.1f};e_mJ={1e3*r['e_task']:.1f};"
                  f"overhead={r['overhead']:.4f};reward={r['reward']:.4f}")
        _emit("hetero_iter_us", out["iter_us_mixed"],
              f"homogeneous_us={out['iter_us_homogeneous']:.0f}")
        guard("hetero", "mixed_vs_homogeneous_iteration",
              out["iter_us_mixed"] / max(out["iter_us_homogeneous"], 1e-9),
              1.5)

    if want("churn"):
        _section("dynamic fleet (UE churn: join/leave mid-episode)")
        from benchmarks import bench_churn
        out = bench_churn.run(quick=quick)
        results["churn"] = out
        for r in out["rows"]:
            _emit(f"churn_{int(100*r['churn'])}pct", 0.0,
                  f"mahppo={r['mahppo_reward']:.4f};"
                  f"local={r['local_reward']:.4f};"
                  f"t_ms={1e3*r['t_task']:.1f};"
                  f"fleet={r['n_active_mean']:.2f};"
                  f"beats_local={r['beats_local']}")
        _emit("churn_iter_us", out["iter_us_churn"],
              f"static_us={out['iter_us_static']:.0f};"
              f"ratio={out['iter_ratio']:.2f}")
        guard("churn", "churn_vs_static_iteration", out["iter_ratio"], 1.5)

    if want("multi_server"):
        _section("multi-server edge pool (routed action space)")
        from benchmarks import bench_multi_server
        out = bench_multi_server.run(quick=quick, smoke=smoke)
        results["multi_server"] = out
        for r in out["rows"]:
            _emit(f"multi_server_{r['policy']}", 0.0,
                  f"overhead={r['overhead']:.4f};"
                  f"t_ms={1e3*r['t_task']:.1f};"
                  f"e_mJ={1e3*r['e_task']:.1f}"
                  + (f";route={''.join(map(str, r['route']))}"
                     if "route" in r else ""))
        _emit("multi_server_iter_us", out["iter_us_multi"],
              f"single_us={out['iter_us_single']:.0f};"
              f"ratio={out['iter_ratio']:.2f};"
              f"beats_nearest={out['beats_nearest']}")
        for p in out["parity"]:
            guard("multi_server", p["name"], p["ratio"], p["limit"])
        # routing under churn: sparse membership vs flash crowd
        churn_out = bench_multi_server.run_churn_routing(quick=quick,
                                                         smoke=smoke)
        results["multi_server_churn_routing"] = churn_out
        _emit("multi_server_churn_routing", 0.0,
              f"sparse_share={churn_out['sparse']['max_share']:.2f};"
              f"flash_share={churn_out['flash']['max_share']:.2f};"
              f"flash_counts="
              f"{''.join(map(str, churn_out['flash']['counts']))};"
              f"rebalances={churn_out['rebalances']}")
        for p in churn_out["parity"]:
            guard("multi_server", p["name"], p["ratio"], p["limit"])
        os.makedirs("artifacts", exist_ok=True)
        artifact = {"bench": "multi_server", "schema": 2,
                    "smoke": smoke, "quick": quick,
                    "rows": out["rows"],
                    "beats_nearest": out["beats_nearest"],
                    "iter_us_single": out["iter_us_single"],
                    "iter_us_multi": out["iter_us_multi"],
                    "iter_ratio": out["iter_ratio"],
                    "churn_routing": churn_out,
                    "parity": out["parity"] + churn_out["parity"]}
        with open("artifacts/BENCH_multi_server.json", "w") as f:
            json.dump(artifact, f, indent=1, default=float)
        print("# wrote artifacts/BENCH_multi_server.json", flush=True)

    if want("llm_offload"):
        _section("llm decode offloading (mixed CNN+LLM pool, context "
                 "rungs)")
        from benchmarks import bench_llm_offload
        out = bench_llm_offload.run(quick=quick, smoke=smoke)
        results["llm_offload"] = out
        for r in out["rows"]:
            _emit(f"llm_offload_{r['policy']}", 0.0,
                  f"overhead={r['overhead']:.4f};"
                  f"t_s={r['t_task']:.3f};"
                  f"e_mJ={1e3*r['e_task']:.1f}"
                  + (f";route={''.join(map(str, r['route']))}"
                     if "route" in r else ""))
        for m in out["modes"]["rows"]:
            _emit(f"llm_offload_mode_{m['ue']}", 0.0,
                  f"split={m['split']};local={m['local']};"
                  f"server={m['route']}")
        _emit("llm_offload_ctx_shift", 0.0,
              f"ctx_shift={out['ctx_shift']};"
              f"beats_nearest={out['beats_nearest']}")
        for r in out["flops_rows"]:
            _emit(f"llm_offload_flops_ctx{r['ctx']}", 0.0,
                  f"table={r['table_flops']:.3e};"
                  f"convention={r['convention_flops']:.3e};"
                  f"ratio={r['ratio']:.2f}")
        for p in out["parity"]:
            guard("llm_offload", p["name"], p["ratio"], p["limit"])
        cf = bench_llm_offload.run_closed_form(smoke=smoke)
        results["llm_offload_closed_form"] = cf
        for r in cf["rows"]:
            _emit(f"llm_offload_closed_form_{r['rung']}", 0.0,
                  f"t_task_s={r['t_task_s']:.1f};"
                  f"expected={r['expected_per_frame']:.4f};"
                  f"realized={r['realized_per_frame']:.4f};"
                  f"ratio={r['ratio']:.3f}")
        for p in cf["parity"]:
            guard("llm_offload", p["name"], p["ratio"], p["limit"])
        os.makedirs("artifacts", exist_ok=True)
        artifact = {"bench": "llm_offload", "schema": 1,
                    "smoke": smoke, "quick": quick,
                    "rows": out["rows"], "modes": out["modes"],
                    "ctx_shift": out["ctx_shift"],
                    "beats_nearest": out["beats_nearest"],
                    "flops_rows": out["flops_rows"],
                    "closed_form_rows": cf["rows"],
                    "train_s": out["train_s"],
                    "parity": out["parity"] + cf["parity"]}
        with open("artifacts/BENCH_llm_offload.json", "w") as f:
            json.dump(artifact, f, indent=1, default=float)
        print("# wrote artifacts/BENCH_llm_offload.json", flush=True)

    if want("generalization"):
        _section("fleet-generalist shared policy (zero-shot N / pool "
                 "transfer)")
        from benchmarks import bench_generalization
        out = bench_generalization.run(quick=quick, smoke=smoke)
        results["generalization"] = out
        for r in out["rows"]:
            _emit(f"generalization_{r['scenario']}", 0.0,
                  f"n_ue={r['n_ue']};"
                  f"shared={r['shared_overhead']:.4f};"
                  f"greedy={r['greedy_overhead']:.4f};"
                  f"beats_greedy={r['beats_greedy']}"
                  + (f";per_ue={r['per_ue_overhead']:.4f}"
                     if "per_ue_overhead" in r else ""))
        for r in out["entity_rows"]:
            _emit(f"generalization_{r['scenario']}", 0.0,
                  f"n_servers={r['n_servers']};"
                  f"entity={r['entity_overhead']:.4f};"
                  f"nearest={r['nearest_overhead']:.4f};"
                  f"greedy={r['greedy_overhead']:.4f};"
                  f"beats_nearest={r['beats_nearest']}")
        p = out["params"]
        _emit("generalization_params", 0.0,
              f"shared={p['shared']};entity={p['entity']};"
              + ";".join(f"per_ue_n{n}={c}"
                         for n, c in sorted(p["per_ue"].items()))
              + f";sublinear={out['param_sublinear']}")
        _emit("generalization_iter_us", out["iter_us_shared"],
              f"per_ue_us={out['iter_us_per_ue']:.0f};"
              f"entity_us={out['iter_us_entity']:.0f};"
              f"ratio={out['iter_ratio']:.2f};"
              f"entity_ratio={out['entity_iter_ratio']:.2f};"
              f"zero_shot_beats_greedy={out['zero_shot_beats_greedy']}")
        for pc in out["parity"]:
            guard("generalization", pc["name"], pc["ratio"], pc["limit"])
        os.makedirs("artifacts", exist_ok=True)
        artifact = {"bench": "generalization", "schema": 2,
                    "smoke": smoke, "quick": quick,
                    "rows": out["rows"], "params": out["params"],
                    "param_sublinear": out["param_sublinear"],
                    "zero_shot_beats_greedy":
                        out["zero_shot_beats_greedy"],
                    "iter_us_per_ue": out["iter_us_per_ue"],
                    "iter_us_shared": out["iter_us_shared"],
                    "iter_ratio": out["iter_ratio"],
                    "parity": out["parity"]}
        with open("artifacts/BENCH_generalization.json", "w") as f:
            json.dump(artifact, f, indent=1, default=float)
        print("# wrote artifacts/BENCH_generalization.json", flush=True)
        # standalone entity-policy artifact: the pool-transfer story
        # (alt-pool + unseen-E wins, scorer parity) in one place
        entity_artifact = {
            "bench": "entity", "schema": 1, "smoke": smoke, "quick": quick,
            "rows": out["entity_rows"],
            "entity_params": p["entity"],
            "entity_train_s": out["entity_train_s"],
            "iter_us_shared": out["iter_us_shared"],
            "iter_us_entity": out["iter_us_entity"],
            "iter_us_entity_randomized": out["iter_us_entity_randomized"],
            "entity_iter_ratio": out["entity_iter_ratio"],
            "parity": [g for g in out["parity"]
                       if g["name"].startswith("entity")]}
        with open("artifacts/BENCH_entity.json", "w") as f:
            json.dump(entity_artifact, f, indent=1, default=float)
        print("# wrote artifacts/BENCH_entity.json", flush=True)

    if want("streaming"):
        _section("streaming serve (continuous-time arrivals, deadline QoS, "
                 "policy-as-dispatcher)")
        from benchmarks import bench_streaming
        out = bench_streaming.run(quick=quick, smoke=smoke)
        results["streaming"] = out
        for r in out["rows"]:
            _emit(f"streaming_rate{r['rate']:g}_{r['dispatcher']}", 0.0,
                  f"miss={r['miss_rate']:.3f};p99={r['sojourn_p99']:.3f};"
                  f"thr={r['throughput']:.1f};spread={r['spread']:.2f};"
                  f"seeds={r['eval_seeds']}")
        lat = out["entity_dispatch_us"]
        if lat:
            _emit("streaming_entity_dispatch_us", lat["p50"],
                  f"p95={lat['p95']:.0f};p99={lat['p99']:.0f}")
        fwd = out["policy_forward_us"]
        _emit("streaming_policy_forward_us", fwd["best_us"],
              f"mean={fwd['mean_us']:.1f};p99={fwd['tail']['p99']:.1f}")
        _emit("streaming_train_s", out["train_s"] * 1e6,
              f"tune_s={out['tune_s']:.1f};"
              f"tune_final_miss={out['tune_history'][-1]['miss_rate']:.3f}")
        for p in out["parity"]:
            guard("streaming", p["name"], p["ratio"], p["limit"])
        os.makedirs("artifacts", exist_ok=True)
        artifact = {"bench": "streaming", "schema": 1,
                    "smoke": smoke, "quick": quick,
                    "rows": out["rows"],
                    "mid_rate": out["mid_rate"],
                    "sat_rate": out["sat_rate"],
                    "entity_dispatch_us": out["entity_dispatch_us"],
                    "policy_forward_us": out["policy_forward_us"],
                    "train_s": out["train_s"], "tune_s": out["tune_s"],
                    "tune_history": out["tune_history"],
                    "parity": out["parity"]}
        with open("artifacts/BENCH_streaming.json", "w") as f:
            json.dump(artifact, f, indent=1, default=float)
        print("# wrote artifacts/BENCH_streaming.json", flush=True)

    if want("policy_latency"):
        _section("policy latency (train big, serve small: distilled + "
                 "int8 trunk)")
        from benchmarks import bench_policy_latency
        out = bench_policy_latency.run(quick=quick, smoke=smoke)
        results["policy_latency"] = out
        for r in out["rows"]:
            _emit(f"policy_latency_{r['candidate']}_b{r['batch']}",
                  r["best_us"],
                  f"us_per_decision={r['us_per_decision']:.3f};"
                  f"p50={r['p50_us']:.1f};p99={r['p99_us']:.1f}")
        p = out["params"]
        _emit("policy_latency_params", 0.0,
              f"teacher={p['teacher']};student={p['student']};"
              f"ratio={p['ratio']:.3f};"
              f"bytes_f32={p['student_bytes_f32']};"
              f"bytes_int8={p['student_bytes_int8']}")
        fid = out["fidelity"]
        _emit("policy_latency_fidelity", 0.0,
              f"ratio_f32={fid['ratio_f32']:.3f};"
              f"ratio_int8={fid['ratio_int8']:.3f};"
              f"mode_agree={fid['agreement']['all']:.3f}")
        ker = out["kernel"]
        _emit("policy_latency_int8_kernel", 0.0,
              f"max_diff_xla={ker['kernel_max_diff']['xla']:.2e};"
              f"max_diff_pallas={ker['kernel_max_diff']['pallas']:.2e};"
              f"int8_vs_f32_agree={ker['int8_vs_f32_mode_agree']:.4f}")
        _emit("policy_latency_stream_mid", 0.0,
              f"trunk_p99={out['stream']['trunk']['sojourn_p99']:.3f};"
              f"nearest_p99={out['stream']['nearest']['sojourn_p99']:.3f};"
              f"ratio={out['stream']['p99_ratio']:.3f}")
        for pc in out["parity"]:
            guard("policy_latency", pc["name"], pc["ratio"], pc["limit"])
        os.makedirs("artifacts", exist_ok=True)
        artifact = {"bench": "policy_latency", "schema": 1,
                    "smoke": smoke, "quick": quick,
                    "rows": out["rows"], "params": out["params"],
                    "fidelity": out["fidelity"], "kernel": out["kernel"],
                    "stream": out["stream"],
                    "batch1_speedup": out["batch1_speedup"],
                    "batches": out["batches"],
                    "train_s": out["train_s"], "tune_s": out["tune_s"],
                    "distill_s": out["distill_s"],
                    "distill_history": out["distill_history"],
                    "parity": out["parity"]}
        with open("artifacts/BENCH_policy_latency.json", "w") as f:
            json.dump(artifact, f, indent=1, default=float)
        print("# wrote artifacts/BENCH_policy_latency.json", flush=True)

    if want("archs"):
        _section("fig13 other backbones (+ assigned archs)")
        from benchmarks import bench_archs
        t0 = time.time()
        out = bench_archs.run(quick=quick)
        results["archs"] = out
        us = (time.time() - t0) * 1e6 / max(len(out["rows"]), 1)
        for k, v in out["rows"].items():
            _emit(f"fig13_{k}", us,
                  f"t_ms={v['t_ms']:.1f};e_mJ={v['e_mJ']:.1f};"
                  f"local_t={v['local_t_ms']:.1f};local_e={v['local_e_mJ']:.1f}")

    if want("roofline"):
        _section("roofline (from dry-run artifacts)")
        from benchmarks import roofline
        rows = roofline.full_table(roofline.default_art_dir())
        if rows:
            for r in rows:
                if r["mesh"] == "16x16":
                    _emit(f"roofline_{r['arch']}_{r['shape']}", 0.0,
                          f"compute_s={r['t_compute_s']:.2e};"
                          f"memory_s={r['t_memory_s']:.2e};"
                          f"coll_s={r['t_collective_s']:.2e};"
                          f"dom={r['dominant']};useful={r['useful_ratio']:.2f}")
            with open("artifacts/roofline.json", "w") as f:
                json.dump(rows, f, indent=1)
        else:
            _emit("roofline_missing", 0.0,
                  "run `python -m repro.launch.dryrun --all` first")

    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/bench_results.json", "w") as f:
        json.dump(results, f, indent=1, default=float)
    print("# wrote artifacts/bench_results.json", flush=True)

    # fail LOUDLY on any jitted-iteration parity regression: a hot-path
    # slowdown must stop the build, not scroll past as a ratio.
    failures = [(s, n, r, lim) for s, n, r, lim in parity_checks if r > lim]
    for s, n, r, lim in parity_checks:
        status = "FAIL" if r > lim else "ok"
        print(f"# parity[{s}] {n}: ratio {r:.2f} (limit {lim:.2f}) "
              f"{status}", flush=True)
    if failures:
        print(f"# PARITY REGRESSION: {len(failures)}/{len(parity_checks)} "
              "guard(s) exceeded their limit", flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
