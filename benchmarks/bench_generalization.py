"""Fleet-generalist shared policy: train ONCE at N=4, deploy everywhere.

A weight-shared MAHPPO actor (``MAHPPOConfig(shared_policy=True)``) is
trained on the mixed 4-UE fleet over the 2-server demo pool, then
evaluated ZERO-SHOT — no retraining, the identical parameter set — on:

* an 8-UE and a 16-UE fleet of the same device mix (the per-UE feature
  rows are N-independent, so the actor just sees more rows), and
* a different 2-server pool LAYOUT (the v5e still primary but
  bandwidth-starved, the GPU tier moved in much closer),

each against the interference-oblivious greedy heuristic scored on that
same scenario, plus per-UE actors trained from scratch at N=4 as the
paper-style reference. Param counts are reported at N=4/8/16: the shared
actor is O(1) in the fleet size where per-UE actors grow linearly — the
scaling property the north-star "millions of users" needs.

Expected picture: fleet-SIZE transfer wins (the mean-field aggregates the
policy conditions on vary during training, so it has learned to respond
to them), while pool-LAYOUT transfer is a stress probe reported honestly
— the pool features are constant under single-pool training, so the
policy gets no gradient signal to condition its route head on them and
generally cannot beat a layout-aware heuristic zero-shot. Closing that
gap needs pool randomization during training or per-server route
encoders (see the ROADMAP PR-4 follow-ups); the scenario is here so the
number is tracked rather than assumed.

Parity guard: the jitted shared-policy iteration must cost no more than
the per-UE-actors iteration at N=4 (limit 1.0x — one small actor applied
N times does strictly less optimizer work than N actors).
"""
from __future__ import annotations

import dataclasses
import time

from repro.core import overhead as oh
from repro.core.fleets import EdgePool, make_edge_pool, make_mixed_fleet
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl import nets
from repro.rl.heuristics import greedy_eval
from repro.rl.mahppo import (MAHPPOConfig, evaluate_policy, init_agent,
                             train_mahppo)

import jax

PARITY_LIMIT = 1.0
# wall-clock ratios on shared CI runners are noisy; the smoke gate only
# guards gross regressions
PARITY_LIMIT_SMOKE = 1.3
TRAIN_N = 4
EVAL_NS = (8, 16)


def alt_pool() -> EdgePool:
    """A different 2-server layout, same E (the route head's width must
    match): the v5e keeps the primary slot but loses 40% of its uplink
    bandwidth, and the GPU tier moves in to 1.2x path-loss distance (from
    1.4x) — the relative attractiveness of the two routes flips without
    renumbering which slot is the near/primary server."""
    return EdgePool((oh.ServerProfile("tpu-v5e", oh.TPU_V5E, 1.0, 0.6,
                                      0.0),
                     oh.ServerProfile.from_device(oh.EDGE_GPU,
                                                  dist_scale=1.2)))


def make_gen_env(n_ue: int, pool: EdgePool = None) -> MECEnv:
    fleet = make_mixed_fleet(n_ue=n_ue)
    return MECEnv(make_env_params(fleet, n_channels=2,
                                  pool=pool or make_edge_pool(2)))


def _overhead(env, ev):
    return ev["t_task"] + float(env.params.beta) * ev["e_task"]


def run(quick=True, smoke=False):
    iters = 3 if smoke else (30 if quick else 100)
    env4 = make_gen_env(TRAIN_N)

    cfg = MAHPPOConfig(iterations=iters, horizon=512, n_envs=4, reuse=4,
                       shared_policy=True)
    t0 = time.time()
    shared, _ = train_mahppo(env4, cfg, seed=0)
    train_s = time.time() - t0
    per_ue, _ = train_mahppo(
        env4, dataclasses.replace(cfg, shared_policy=False), seed=0)

    scenarios = [("n4_train", env4),
                 ("n8_zero_shot", make_gen_env(EVAL_NS[0])),
                 ("n16_zero_shot", make_gen_env(EVAL_NS[1])),
                 ("alt_pool_zero_shot", make_gen_env(TRAIN_N, alt_pool()))]
    rows = []
    for name, env in scenarios:
        ev = evaluate_policy(env, shared, frames=64)
        gr = greedy_eval(env)
        row = {"scenario": name, "n_ue": int(env.params.n_ue),
               "shared_overhead": _overhead(env, ev),
               "shared_t_task": ev["t_task"], "shared_e_task": ev["e_task"],
               "greedy_overhead": gr["overhead"],
               "beats_greedy": bool(_overhead(env, ev) <= gr["overhead"])}
        if name == "n4_train":
            evp = evaluate_policy(env, per_ue, frames=64)
            row["per_ue_overhead"] = _overhead(env, evp)
        rows.append(row)

    # parameter scaling: shared is O(1) in N, per-UE actors are O(N)
    params = {"shared": nets.param_count(shared["actor"]), "per_ue": {}}
    for name, env in scenarios[:3]:
        pu = init_agent(jax.random.PRNGKey(0), env)
        params["per_ue"][int(env.params.n_ue)] = \
            nets.param_count(pu["actors"])

    # hot-path parity: shared vs per-UE-actors jitted iteration at N=4.
    # Wall-clock on a shared box is noisy, so each mode reports its
    # best-of-k single-iteration time (one compilation per mode).
    try:
        from benchmarks.bench_hetero_fleet import _iter_us
    except ImportError:        # run directly as a script
        from bench_hetero_fleet import _iter_us
    tcfg = MAHPPOConfig(horizon=512, n_envs=4, reuse=2)
    scfg = dataclasses.replace(tcfg, shared_policy=True)
    us_per_ue = _iter_us(env4, tcfg, n_timed=10, reduce="min")
    us_shared = _iter_us(env4, scfg, n_timed=10, reduce="min")
    ratio = us_shared / max(us_per_ue, 1e-9)
    limit = PARITY_LIMIT_SMOKE if smoke else PARITY_LIMIT

    # the acceptance gate is fleet-SIZE transfer (n8/n16); the alt-pool
    # probe is reported but not gated (see module docstring). The gate is
    # ENFORCED through the same ledger as the parity guard — a zero-shot
    # regression must fail the run, not scroll past as a False — phrased
    # as a ratio so the harness treats it uniformly: shared/greedy ≤ 1.0.
    gates = [{"name": f"{r['scenario']}_vs_greedy",
              "ratio": r["shared_overhead"] / max(r["greedy_overhead"],
                                                  1e-9),
              "limit": 1.0}
             for r in rows if r["scenario"].startswith("n")
             and r["scenario"].endswith("_zero_shot")]
    zero_shot_ok = all(g["ratio"] <= g["limit"] for g in gates)
    # "sublinear in N": deploying at 4x the fleet size leaves the shared
    # actor's size unchanged while per-UE actors grow 4x
    per_ue_counts = [params["per_ue"][n] for n in (TRAIN_N,) + EVAL_NS]
    return {"rows": rows, "train_s": train_s, "params": params,
            "param_sublinear": bool(
                params["shared"] < per_ue_counts[0]
                and per_ue_counts[0] < per_ue_counts[1] < per_ue_counts[2]),
            "zero_shot_beats_greedy": zero_shot_ok,
            "iter_us_per_ue": us_per_ue, "iter_us_shared": us_shared,
            "iter_ratio": ratio,
            "parity": [{"name": "shared_vs_per_ue_iteration",
                        "ratio": ratio, "limit": limit}] + gates}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        extra = f" per_ue={r['per_ue_overhead']:.4f}" \
            if "per_ue_overhead" in r else ""
        print(f"{r['scenario']:>20s} (N={r['n_ue']:2d}): "
              f"shared {r['shared_overhead']:.4f} vs greedy "
              f"{r['greedy_overhead']:.4f}"
              f" [{'BEATS' if r['beats_greedy'] else 'LOSES'}]{extra}")
    p = out["params"]
    print(f"actor params: shared {p['shared']} (constant in N); per-UE "
          + ", ".join(f"N={n}: {c}" for n, c in sorted(p["per_ue"].items())))
    print(f"iteration: per-UE {out['iter_us_per_ue']/1e3:.1f} ms, shared "
          f"{out['iter_us_shared']/1e3:.1f} ms "
          f"(ratio {out['iter_ratio']:.2f}, limit {PARITY_LIMIT})")
