"""Fleet- and pool-generalist policies: train ONCE at N=4, deploy
everywhere.

A weight-shared MAHPPO actor (``MAHPPOConfig(shared_policy=True)``) is
trained on the mixed 4-UE fleet over the 2-server demo pool, then
evaluated ZERO-SHOT — no retraining, the identical parameter set — on an
8-UE and a 16-UE fleet of the same device mix (the per-UE feature rows
are N-independent, so the actor just sees more rows), each against the
interference-oblivious greedy heuristic scored on that same scenario,
plus per-UE actors trained from scratch at N=4 as the paper-style
reference. Param counts are reported at N=4/8/16: the shared actor is
O(1) in the fleet size where per-UE actors grow linearly — the scaling
property the north-star "millions of users" needs.

The ENTITY policy (``MAHPPOConfig(entity_policy=True,
randomize_pool=True)``) closes the gap the shared policy's mean-field
pool aggregates honestly reported as a LOSS through PR 4: trained on
RANDOMIZED 2-server geometries (each episode draws every server's
[dist_scale, bw_scale, slowness], so the route head actually receives
pool-feature gradients), its shared per-server route scorer is evaluated
zero-shot on

* the inverted alt-pool layout (v5e bandwidth-starved, GPU tier moved
  in) — previously the reported loss, now a LEDGER-ENFORCED win over
  nearest-server greedy, and
* an unseen E=3 pool — a pool SIZE it never trained on (route logits are
  scored per server, so E is free at inference time), same enforced win.

Parity guards: the jitted shared-policy iteration must cost no more than
the per-UE-actors iteration at N=4 (limit 1.0x — one small actor applied
N times does strictly less optimizer work than N actors), and the entity
iteration at most ENTITY_PARITY_LIMIT x the shared one (the pair scorer
adds an (N, E) MLP sweep).
"""
from __future__ import annotations

import dataclasses
import time

from repro.core import overhead as oh
from repro.core.fleets import (EdgePool, make_edge_pool, make_mixed_fleet,
                               random_pool_ranges)
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl import nets
from repro.rl.baselines import nearest_server_eval
from repro.rl.heuristics import greedy_eval
from repro.rl.mahppo import (MAHPPOConfig, evaluate_policy, init_agent,
                             train_mahppo)

import jax

PARITY_LIMIT = 1.0
ENTITY_PARITY_LIMIT = 1.25
# wall-clock ratios on shared CI runners are noisy; the smoke gate only
# guards gross regressions
PARITY_LIMIT_SMOKE = 1.3
ENTITY_PARITY_LIMIT_SMOKE = 1.6
TRAIN_N = 4
EVAL_NS = (8, 16)


def alt_pool() -> EdgePool:
    """A different 2-server layout, same E (the route head's width must
    match): the v5e keeps the primary slot but loses 40% of its uplink
    bandwidth, and the GPU tier moves in to 1.2x path-loss distance (from
    1.4x) — the relative attractiveness of the two routes flips without
    renumbering which slot is the near/primary server."""
    return EdgePool((oh.ServerProfile("tpu-v5e", oh.TPU_V5E, 1.0, 0.6,
                                      0.0),
                     oh.ServerProfile.from_device(oh.EDGE_GPU,
                                                  dist_scale=1.2)))


def make_gen_env(n_ue: int, pool: EdgePool = None,
                 randomized: bool = False) -> MECEnv:
    fleet = make_mixed_fleet(n_ue=n_ue)
    pool = pool or make_edge_pool(2)
    ranges = random_pool_ranges(pool.n_servers) if randomized else None
    return MECEnv(make_env_params(fleet, n_channels=2, pool=pool,
                                  pool_ranges=ranges))


def _overhead(env, ev):
    return ev["t_task"] + float(env.params.beta) * ev["e_task"]


def run(quick=True, smoke=False):
    iters = 3 if smoke else (30 if quick else 100)
    env4 = make_gen_env(TRAIN_N)

    cfg = MAHPPOConfig(iterations=iters, horizon=512, n_envs=4, reuse=4,
                       shared_policy=True)
    t0 = time.time()
    shared, _ = train_mahppo(env4, cfg, seed=0)
    train_s = time.time() - t0
    per_ue, _ = train_mahppo(
        env4, dataclasses.replace(cfg, shared_policy=False), seed=0)

    # the pool-generalist entity policy: same fleet, same pool STRUCTURE,
    # but every training episode draws a fresh 2-server geometry
    env_rnd = make_gen_env(TRAIN_N, randomized=True)
    ecfg = dataclasses.replace(cfg, entity_policy=True, shared_policy=False,
                               randomize_pool=True)
    t0 = time.time()
    entity, _ = train_mahppo(env_rnd, ecfg, seed=0)
    entity_train_s = time.time() - t0

    scenarios = [("n4_train", env4),
                 ("n8_zero_shot", make_gen_env(EVAL_NS[0])),
                 ("n16_zero_shot", make_gen_env(EVAL_NS[1])),
                 ("alt_pool_zero_shot", make_gen_env(TRAIN_N, alt_pool()))]
    rows = []
    for name, env in scenarios:
        ev = evaluate_policy(env, shared, frames=64)
        gr = greedy_eval(env)
        row = {"scenario": name, "n_ue": int(env.params.n_ue),
               "shared_overhead": _overhead(env, ev),
               "shared_t_task": ev["t_task"], "shared_e_task": ev["e_task"],
               "greedy_overhead": gr["overhead"],
               "beats_greedy": bool(_overhead(env, ev) <= gr["overhead"])}
        if name == "n4_train":
            evp = evaluate_policy(env, per_ue, frames=64)
            row["per_ue_overhead"] = _overhead(env, evp)
        rows.append(row)

    # entity zero-shot: the inverted alt-pool layout (the probe PR 4 could
    # only report as a loss) and an UNSEEN pool size E=3. Scored against
    # nearest-server greedy — the routing-oblivious deployment default —
    # and full (split, server)-greedy for context.
    entity_rows = []
    for name, env in [
            ("entity_alt_pool_zero_shot", make_gen_env(TRAIN_N, alt_pool())),
            ("entity_e3_zero_shot",
             make_gen_env(TRAIN_N, make_edge_pool(3)))]:
        ev = evaluate_policy(env, entity, frames=64)
        near = nearest_server_eval(env)
        gr = greedy_eval(env)
        entity_rows.append({
            "scenario": name, "n_ue": int(env.params.n_ue),
            "n_servers": env.n_servers,
            "entity_overhead": _overhead(env, ev),
            "entity_t_task": ev["t_task"], "entity_e_task": ev["e_task"],
            "nearest_overhead": near["overhead"],
            "greedy_overhead": gr["overhead"],
            "beats_nearest": bool(_overhead(env, ev) <= near["overhead"])})

    # parameter scaling: shared is O(1) in N, per-UE actors are O(N)
    params = {"shared": nets.param_count(shared["actor"]), "per_ue": {}}
    for name, env in scenarios[:3]:
        pu = init_agent(jax.random.PRNGKey(0), env)
        params["per_ue"][int(env.params.n_ue)] = \
            nets.param_count(pu["actors"])

    # hot-path parity: shared vs per-UE-actors jitted iteration at N=4,
    # and entity vs shared — all timed at the section's ACTUAL training
    # configuration (horizon 512, reuse 4) so the ratio reflects what a
    # training run pays, with the entity policy on the SAME static env4
    # as the other two (isolating the policy-architecture cost; the
    # randomized-geometry variant is timed and reported alongside).
    # Wall-clock on a shared box is noisy, so the modes are timed
    # round-robin INTERLEAVED (one compilation per mode) and each parity
    # ratio is the MEDIAN of per-round paired ratios — a load burst
    # inflates the whole round and cancels, where a min-of-independent-
    # samples ratio flips whenever one mode alone catches a freak quiet
    # slice.
    try:
        from benchmarks._timing import paired_iter_samples, paired_ratio
    except ImportError:        # run directly as a script
        from _timing import paired_iter_samples, paired_ratio
    tcfg = MAHPPOConfig(horizon=512, n_envs=4, reuse=4)
    scfg = dataclasses.replace(tcfg, shared_policy=True)
    etcfg = dataclasses.replace(tcfg, entity_policy=True)
    ercfg = dataclasses.replace(tcfg, entity_policy=True,
                                randomize_pool=True)
    t_per_ue, t_shared, t_entity, t_entity_rnd = paired_iter_samples(
        [(env4, tcfg), (env4, scfg), (env4, etcfg), (env_rnd, ercfg)],
        n_timed=15)
    us_per_ue, us_shared, us_entity = (min(t_per_ue) * 1e6,
                                       min(t_shared) * 1e6,
                                       min(t_entity) * 1e6)
    us_entity_rnd = min(t_entity_rnd) * 1e6
    ratio = paired_ratio(t_shared, t_per_ue)
    entity_ratio = paired_ratio(t_entity, t_shared)
    limit = PARITY_LIMIT_SMOKE if smoke else PARITY_LIMIT
    entity_limit = ENTITY_PARITY_LIMIT_SMOKE if smoke \
        else ENTITY_PARITY_LIMIT

    # zero-shot acceptance gates, ENFORCED through the same ledger as the
    # parity guard — a regression must fail the run, not scroll past as a
    # False — phrased as ratios so the harness treats them uniformly:
    #  * fleet-SIZE transfer: shared/greedy ≤ 1.0 at n8/n16 (as in PR 4)
    #  * pool transfer: entity/nearest ≤ 1.0 on the inverted alt-pool
    #    layout AND the unseen E=3 pool — the probe PR 4 reported as a
    #    loss, flipped to an enforced win by randomized-pool training
    gates = [{"name": f"{r['scenario']}_vs_greedy",
              "ratio": r["shared_overhead"] / max(r["greedy_overhead"],
                                                  1e-9),
              "limit": 1.0}
             for r in rows if r["scenario"].startswith("n")
             and r["scenario"].endswith("_zero_shot")]
    # smoke runs train 3 iterations: the entity wins still hold by a wide
    # margin empirically (ratios ~0.25-0.35 — nearest-server is a LOW
    # bar), but a barely-trained route head shouldn't gate at exactly
    # 1.0, so CI smoke keeps a collapse guard while quick/full enforce
    # the true win (mirrors the *_SMOKE parity limits)
    zs_limit = 1.25 if smoke else 1.0
    gates += [{"name": f"{r['scenario']}_vs_nearest",
               "ratio": r["entity_overhead"] / max(r["nearest_overhead"],
                                                   1e-9),
               "limit": zs_limit}
              for r in entity_rows]
    # the reported "beats" flag stays strict (<= 1.0) even where a smoke
    # gate's enforcement limit is looser
    zero_shot_ok = all(g["ratio"] <= 1.0 for g in gates)
    # "sublinear in N": deploying at 4x the fleet size leaves the shared
    # actor's size unchanged while per-UE actors grow 4x
    per_ue_counts = [params["per_ue"][n] for n in (TRAIN_N,) + EVAL_NS]
    params["entity"] = nets.param_count(entity["entity_actor"])
    return {"rows": rows, "entity_rows": entity_rows, "train_s": train_s,
            "entity_train_s": entity_train_s, "params": params,
            "param_sublinear": bool(
                params["shared"] < per_ue_counts[0]
                and per_ue_counts[0] < per_ue_counts[1] < per_ue_counts[2]),
            "zero_shot_beats_greedy": zero_shot_ok,
            "iter_us_per_ue": us_per_ue, "iter_us_shared": us_shared,
            "iter_us_entity": us_entity,
            "iter_us_entity_randomized": us_entity_rnd,
            "iter_ratio": ratio, "entity_iter_ratio": entity_ratio,
            "parity": [{"name": "shared_vs_per_ue_iteration",
                        "ratio": ratio, "limit": limit},
                       {"name": "entity_vs_shared_iteration",
                        "ratio": entity_ratio, "limit": entity_limit}]
            + gates}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        extra = f" per_ue={r['per_ue_overhead']:.4f}" \
            if "per_ue_overhead" in r else ""
        print(f"{r['scenario']:>20s} (N={r['n_ue']:2d}): "
              f"shared {r['shared_overhead']:.4f} vs greedy "
              f"{r['greedy_overhead']:.4f}"
              f" [{'BEATS' if r['beats_greedy'] else 'LOSES'}]{extra}")
    for r in out["entity_rows"]:
        print(f"{r['scenario']:>26s} (E={r['n_servers']}): "
              f"entity {r['entity_overhead']:.4f} vs nearest "
              f"{r['nearest_overhead']:.4f} (greedy "
              f"{r['greedy_overhead']:.4f}) "
              f"[{'BEATS' if r['beats_nearest'] else 'LOSES'}]")
    p = out["params"]
    print(f"actor params: shared {p['shared']}, entity {p['entity']} "
          "(both constant in N); per-UE "
          + ", ".join(f"N={n}: {c}" for n, c in sorted(p["per_ue"].items())))
    print(f"iteration: per-UE {out['iter_us_per_ue']/1e3:.1f} ms, shared "
          f"{out['iter_us_shared']/1e3:.1f} ms "
          f"(ratio {out['iter_ratio']:.2f}, limit {PARITY_LIMIT}), entity "
          f"{out['iter_us_entity']/1e3:.1f} ms "
          f"(ratio {out['entity_iter_ratio']:.2f}, "
          f"limit {ENTITY_PARITY_LIMIT})")
