"""Fig. 13: the technique on other backbones — the paper's VGG11 /
MobileNetV2 plus two assigned transformer archs (the lifted scenario)."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.cnn import CNN_FACTORY
from repro.core.split import (cnn_jalad_table, cnn_split_table,
                              transformer_split_table)
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl.baselines import local_policy_eval
from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo


def _one(plan, *, iters, beta=0.47, n_ue=5, t0=0.5):
    env = MECEnv(make_env_params(plan, n_ue=n_ue, n_channels=2, t0=t0,
                                 beta=beta))
    cfg = MAHPPOConfig(iterations=iters, horizon=1024, n_envs=8)
    agent, hist = train_mahppo(env, cfg, seed=0)
    ev = evaluate_policy(env, agent, frames=64)
    lo = local_policy_eval(env, frames=64)
    return {
        "final_reward": float(np.mean([h["reward_mean"] for h in hist[-5:]])),
        "t_ms": 1e3 * ev["t_task"], "e_mJ": 1e3 * ev["e_task"],
        "local_t_ms": 1e3 * lo["t_task"], "local_e_mJ": 1e3 * lo["e_task"],
    }


def run(quick=True):
    iters = 50 if quick else 200
    rows = {}
    for name in ("vgg11", "mobilenetv2"):
        plan = cnn_split_table(CNN_FACTORY[name](101), 224)
        rows[name] = _one(plan, iters=iters)
        jplan = cnn_jalad_table(CNN_FACTORY[name](101), 224)
        rows[name + "-jalad"] = _one(jplan, iters=iters, t0=3.0)
    # assigned transformer archs: edge-serving of LLM prefixes. t0 scaled to
    # ~10x a full local inference (paper's rule); beta = latency/energy ratio.
    for arch in ("qwen3-1.7b", "mamba2-1.3b"):
        plan = transformer_split_table(get_config(arch))
        t_full = float(plan.t_local[-1])
        e_full = float(plan.e_local[-1])
        rows[arch] = _one(plan, iters=iters, t0=round(10 * t_full, 1),
                          beta=t_full / max(e_full, 1e-9))
    return {"rows": rows}


if __name__ == "__main__":
    for k, v in run()["rows"].items():
        print(k, {kk: round(vv, 3) for kk, vv in v.items()})
