"""Roofline analysis (deliverable g): per (arch x shape x mesh), the three
terms derived from the dry-run compiled artifacts:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s          (197 TF bf16, v5e)
  memory     = traffic_bytes_per_device / HBM_bw           (819 GB/s)
  collective = collective_bytes_per_device / link_bw       (~50 GB/s ICI)

HLO_FLOPs uses the while-trip-count-weighted dot parse (launch/hloanalysis);
the MODEL_FLOPS / HLO_FLOPs ratio exposes remat/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks import costmodel
from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def load_records(art_dir="artifacts/dryrun"):
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec):
    arch, shape = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    n_dev = rec["n_devices"]
    hlo_flops = rec.get("hlo_dot_flops") or rec.get(
        "cost_analysis", {}).get("flops", 0.0)
    coll = rec.get("collectives_weighted") or rec.get("collectives", {})
    # ring-cost moved bytes when available (group-size aware); for older
    # artifacts estimate from per-type result-byte totals with n=16 groups
    coll_bytes = coll.get("moved_bytes")
    if coll_bytes is None:
        f = 15.0 / 16.0
        coll_bytes = (2 * f * coll.get("all-reduce", 0)
                      + f * coll.get("all-gather", 0)
                      + 15.0 * coll.get("reduce-scatter", 0)
                      + f * coll.get("all-to-all", 0)
                      + coll.get("collective-permute", 0))

    t_compute = hlo_flops / PEAK_FLOPS_BF16
    mem_bytes = costmodel.memory_bytes_per_device(rec, shape)
    t_memory = mem_bytes / HBM_BW
    t_coll = coll_bytes / ICI_BW

    mf_global = costmodel.model_flops(cfg, shape)
    mf_per_dev = mf_global / n_dev
    ratio = mf_per_dev / hlo_flops if hlo_flops else 0.0

    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    return {
        "arch": arch, "shape": shape, "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "hlo_flops_per_dev": hlo_flops,
        "model_flops_per_dev": mf_per_dev,
        "useful_ratio": ratio,
        "coll_bytes_per_dev": coll_bytes,
        "mem_bytes_per_dev": mem_bytes,
        "param_bytes_per_dev": rec.get("param_bytes_per_device", 0),
        "peak_hbm_frac": (rec.get("param_bytes_per_device", 0)
                          + rec.get("opt_bytes_per_device", 0)
                          + rec.get("cache_bytes_per_device", 0)) / 16e9,
        "compile_s": rec.get("compile_s"),
    }


def full_table(art_dir="artifacts/dryrun", mesh=None):
    rows = [roofline_row(r) for r in load_records(art_dir)]
    if mesh:
        rows = [r for r in rows if r["mesh"] == mesh]
    return rows


def print_table(rows):
    hdr = (f"{'arch':24s} {'shape':12s} {'mesh':8s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'dominant':>10s} {'useful':>7s} {'hbm_frac':>8s}")
    print(hdr)
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        print(f"{r['arch']:24s} {r['shape']:12s} {r['mesh']:8s} "
              f"{r['t_compute_s']:10.2e} {r['t_memory_s']:10.2e} "
              f"{r['t_collective_s']:10.2e} {r['dominant']:>10s} "
              f"{r['useful_ratio']:7.2f} {r['peak_hbm_frac']:8.2f}")


def default_art_dir():
    return ("artifacts/dryrun_opt" if os.path.isdir("artifacts/dryrun_opt")
            and glob.glob("artifacts/dryrun_opt/*.json")
            else "artifacts/dryrun")


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=None)
    ap.add_argument("--baseline-dir", default="artifacts/dryrun")
    args = ap.parse_args()
    art = args.dir or default_art_dir()
    rows = full_table(art)
    print(f"== roofline from {art}")
    print_table(rows)
    out = "artifacts/roofline.json"
    with open(out, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\nwrote {out} ({len(rows)} rows)")
    # baseline-vs-optimized collective comparison
    if art != args.baseline_dir and os.path.isdir(args.baseline_dir):
        base = {(r["arch"], r["shape"], r["mesh"]): r
                for r in full_table(args.baseline_dir)}
        print("\n== collective term: baseline -> optimized (single-pod)")
        for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
            if r["mesh"] != "16x16":
                continue
            b = base.get((r["arch"], r["shape"], r["mesh"]))
            if not b or not b["t_collective_s"]:
                continue
            ratio = b["t_collective_s"] / max(r["t_collective_s"], 1e-12)
            print(f"{r['arch']:24s} {r['shape']:12s} "
                  f"{b['t_collective_s']:9.2e} -> {r['t_collective_s']:9.2e} "
                  f"({ratio:7.1f}x)")


if __name__ == "__main__":
    main()
