"""Shared benchmark timers. Every section that guards a parity ratio
times through these, so the estimator (and its noise-robustness story) is
defined exactly once instead of drifting per-benchmark.

``iter_us`` measures ONE jitted MAHPPO iteration in steady state;
``call_us`` is the generic per-call timer for kernels and other plain
callables. Both support ``reduce="min"`` — best-of-k is the noise-robust
estimator for a deterministic workload on a shared box, without paying a
second compilation the way repeating the whole call would.

``tail_stats`` (re-exported from ``repro.stream.qos`` — THE definition)
summarizes a sample array as p50/p95/p99: bench reports and the
streaming QoS monitor quote the same percentiles from the same code.
"""
from __future__ import annotations

import time

import jax

from repro.stream.qos import tail_stats  # noqa: F401  (re-export)


def iter_us(env, cfg, n_timed=3, reduce="mean"):
    """Steady-state wall time of ONE jitted MAHPPO iteration: reuse the
    same compiled `iteration` for warm-up and timing so compilation is
    excluded. Honors cfg.shared_policy / cfg.entity_policy /
    cfg.randomize_pool, so per-UE-actors, weight-shared, and entity-set
    agents all time through the identical harness."""
    from repro.optim import adamw_init
    from repro.rl.mahppo import init_agent, init_states, make_train_fns
    key = jax.random.PRNGKey(0)
    agent = init_agent(key, env, shared_policy=cfg.shared_policy,
                       entity_policy=cfg.entity_policy)
    opt = adamw_init(agent)
    states = init_states(env, cfg, key)
    iteration = make_train_fns(env, cfg)
    agent, opt, key, states, m = iteration(agent, opt, key, states)
    jax.block_until_ready(m)                # compile + first run
    if reduce == "min":
        best = float("inf")
        for _ in range(n_timed):
            t0 = time.time()
            agent, opt, key, states, m = iteration(agent, opt, key, states)
            jax.block_until_ready(m)
            best = min(best, time.time() - t0)
        return best * 1e6
    t0 = time.time()
    for _ in range(n_timed):
        agent, opt, key, states, m = iteration(agent, opt, key, states)
    jax.block_until_ready(m)
    return (time.time() - t0) * 1e6 / n_timed


def paired_iter_samples(candidates, n_timed=10):
    """Per-iteration wall times (seconds) of SEVERAL (env, cfg) MAHPPO
    iterations with the timed runs INTERLEAVED round by round (A, B, ...,
    A, B, ...) instead of sequential blocks. Returns an (n_candidates,
    n_timed) nested list: ``out[i][k]`` is candidate i's time in round k.

    Parity guards should divide PAIRED samples: within one round the
    candidates run back-to-back, so a load burst inflates both and
    mostly cancels in the per-round ratio — `paired_ratio` takes the
    median of those. A min-over-independent-samples ratio, by contrast,
    is skewed whenever one candidate alone catches a freak quiet (or
    busy) slice."""
    from repro.optim import adamw_init
    from repro.rl.mahppo import init_agent, init_states, make_train_fns
    runs = []
    for env, cfg in candidates:
        key = jax.random.PRNGKey(0)
        agent = init_agent(key, env, shared_policy=cfg.shared_policy,
                           entity_policy=cfg.entity_policy)
        opt = adamw_init(agent)
        states = init_states(env, cfg, key)
        iteration = make_train_fns(env, cfg)
        carry = iteration(agent, opt, key, states)
        jax.block_until_ready(carry[-1])        # compile + first run
        runs.append([iteration, carry])
    times = [[] for _ in runs]
    for _ in range(n_timed):
        for i, (iteration, carry) in enumerate(runs):
            t0 = time.time()
            carry = iteration(*carry[:4])
            jax.block_until_ready(carry[-1])
            runs[i][1] = carry
            times[i].append(time.time() - t0)
    return times


def paired_ratio(samples_a, samples_b):
    """Noise-robust parity ratio a/b from two same-length sample lists
    taken in the same interleaved rounds: median of per-round ratios."""
    ratios = sorted(a / max(b, 1e-12)
                    for a, b in zip(samples_a, samples_b))
    n = len(ratios)
    mid = n // 2
    return ratios[mid] if n % 2 else 0.5 * (ratios[mid - 1] + ratios[mid])


def forward_us(cells, n_timed=20):
    """Batch-sweep forward timer: ``cells`` maps a label (by convention
    "candidate@batch") to a ZERO-ARG jitted thunk. Every cell is warmed
    once (compile excluded), then timed over ``n_timed`` INTERLEAVED
    rounds (A, B, ..., A, B, ...) — `paired_iter_samples`' philosophy
    for plain forwards: a load burst lands on every cell in its round
    and mostly cancels out of cross-cell comparisons, where sequential
    blocks would let one candidate alone catch a quiet slice. Returns
    {label: {"best_us", "mean_us", "tail"}} with ``tail`` the shared
    `tail_stats` percentiles over the per-round samples — both
    `bench_policy_latency`'s µs/decision sweep and `bench_streaming`'s
    dispatch-latency quotes come from this one harness."""
    labels = list(cells)
    for lb in labels:
        jax.block_until_ready(cells[lb]())
    samples = {lb: [] for lb in labels}
    for _ in range(n_timed):
        for lb in labels:
            t0 = time.perf_counter()
            jax.block_until_ready(cells[lb]())
            samples[lb].append((time.perf_counter() - t0) * 1e6)
    return {lb: {"best_us": min(s), "mean_us": sum(s) / len(s),
                 "tail": tail_stats(s)}
            for lb, s in samples.items()}


def call_us(fn, *args, iters=3, reduce="mean"):
    """Wall time per call of ``fn(*args)`` (us), first call excluded as
    warm-up/compile. Blocks on whatever pytree the call returns."""
    jax.block_until_ready(fn(*args))
    if reduce == "min":
        best = float("inf")
        for _ in range(iters):
            t0 = time.time()
            jax.block_until_ready(fn(*args))
            best = min(best, time.time() - t0)
        return best * 1e6
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6
