"""Streaming serve: the frame-trained entity policy as a live
dispatcher, swept from underload to saturation.

The scenario is the 8-UE mixed fleet over the 2-server demo pool served
as a CONTINUOUS task stream (``repro.stream``): per-UE Poisson arrivals,
per-class deadlines, non-preemptive Eq. 7/8 service, lazy drops. A
frame-trained entity agent (same recipe as ``bench_generalization``'s
randomized-pool training) is streaming-fine-tuned by DAgger distillation
of the occupancy-aware dispatch oracle (``rl.streaming``; the tune
cycles a mid-load and a saturated scenario so the oracle's load-
dependent spreading is covered), then evaluated as a SAMPLED
``live_channel`` dispatcher — the deployment mode — against:

* ``oracle``  — :class:`StreamOracleDispatcher`, the distillation
  teacher: a per-dispatch sweep of every (split, channel, server, power)
  candidate under live interference + processor-sharing load. Training-
  free and the strongest baseline, but it pays a full candidate sweep
  per dispatch where the policy pays one forward pass (the
  ``dispatch_us`` tail stats quantify that gap).
* ``nearest`` — all load onto the closest server, best clean-channel
  split, least-loaded channel (the deployment default the ledger gates
  against).
* ``greedy``  — interference-oblivious per-UE argmin over the clean
  cost table (frame ``heuristics.greedy_eval`` in stream form).
* ``local``   — everything on-device.
* ``zero_shot`` — the UNtuned frame policy, argmax, no live channel:
  the honest transfer gap the fine-tune exists to close.

Ledger gates: at MID load the tuned entity dispatcher must beat
nearest-server on p99 sojourn, and at SATURATION on deadline-miss rate
(both ratio <= 1.0 in quick/full). Smoke trains 3+2 iterations — far
too few for the distillation to win (empirically miss ratios ~4x), so
CI smoke instead enforces the training-free half of the pipeline
strictly: the ORACLE must beat nearest on both gates, and the tuned
dispatcher must still serve a well-formed stream (completions > 0).
"""
from __future__ import annotations

import time
import warnings

import numpy as np

from repro.core.fleets import (make_edge_pool, make_mixed_fleet,
                               random_pool_ranges)
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl.mahppo import MAHPPOConfig, train_mahppo
from repro.rl.streaming import StreamTuneConfig, finetune_streaming
from repro.stream.adapter import (EntityDispatcher, GreedyDispatcher,
                                  LocalDispatcher, NearestServerDispatcher,
                                  StreamOracleDispatcher)
from repro.stream.events import StreamParams, StreamSim

try:
    from benchmarks._timing import forward_us, tail_stats
except ImportError:                 # run directly as a script
    from _timing import forward_us, tail_stats

N_UE = 8
N_SERVERS = 2
MID_RATE = 4.0                      # nearest still healthy (miss ~0.17)
SAT_RATE = 12.0                     # nearest saturated (miss ~0.38)
TUNE_RATES = (6.0, 14.0)            # cycled across each iteration's episodes
# aggregate QoS keys averaged across eval seeds
_KEYS = ("miss_rate", "drop_rate", "sojourn_p50", "sojourn_p99",
         "throughput", "energy_task")


def make_stream_env(randomized=False) -> MECEnv:
    pool = make_edge_pool(N_SERVERS)
    ranges = random_pool_ranges(N_SERVERS) if randomized else None
    return MECEnv(make_env_params(make_mixed_fleet(n_ue=N_UE), n_channels=2,
                                  pool=pool, pool_ranges=ranges))


def _eval(env, mk_disp, sp, seeds, timed=False):
    """Run one scenario over ``seeds`` fresh (dispatcher, sim) pairs and
    average the QoS report; ``timed`` wraps the dispatcher to collect
    per-decision wall-clock (the policy-latency satellite metric, quoted
    through the same ``tail_stats`` as the QoS tails)."""
    reps, spread, lat_us = [], [], []
    for seed in seeds:
        disp = mk_disp(seed)
        if timed:
            inner = disp

            def disp(core, ue, _inner=inner):
                t0 = time.perf_counter()
                a = _inner(core, ue)
                lat_us.append((time.perf_counter() - t0) * 1e6)
                return a
        sim = StreamSim(env, disp, sp, seed=seed)
        reps.append(sim.run())
        done = [r for r in sim.monitor.records if not r.dropped]
        spread.append(sum(1 for r in done if r.server != 0)
                      / max(len(done), 1))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")     # all-NaN tails at full drop
        agg = {k: float(np.nanmean([r[k] for r in reps])) for k in _KEYS}
    agg["completed"] = int(sum(r["completed"] for r in reps))
    agg["spread"] = float(np.mean(spread))  # completed share off server 0
    if timed and lat_us:
        agg["dispatch_us"] = tail_stats(lat_us)
    return agg


def run(quick=True, smoke=False):
    frame_iters = 3 if smoke else (30 if quick else 100)
    tune_cfg = StreamTuneConfig(
        iterations=2 if smoke else (14 if quick else 20))
    seeds = (7, 8) if smoke else ((7, 8, 9, 10, 11) if quick
                                  else tuple(range(7, 15)))
    horizon = 8.0 if smoke else 12.0
    rates = (MID_RATE, SAT_RATE) if smoke \
        else (1.5, MID_RATE, 8.0, SAT_RATE)

    # train on randomized pool geometries (the generalist recipe), serve
    # the static demo pool
    t0 = time.time()
    agent, _ = train_mahppo(
        make_stream_env(randomized=True),
        MAHPPOConfig(iterations=frame_iters, horizon=512, n_envs=4,
                     reuse=4, entity_policy=True, randomize_pool=True),
        seed=0)
    train_s = time.time() - t0

    env = make_stream_env()
    t0 = time.time()
    tuned, tune_hist = finetune_streaming(
        env, agent,
        [StreamParams(rate=r, horizon=4.0 if smoke else 8.0)
         for r in TUNE_RATES],
        tune_cfg, seed=100)
    tune_s = time.time() - t0

    dispatchers = {
        "entity": lambda s: EntityDispatcher(env, tuned, deterministic=False,
                                             live_channel=True, seed=s),
        "zero_shot": lambda s: EntityDispatcher(env, agent),
        "oracle": lambda s: StreamOracleDispatcher(env),
        "nearest": lambda s: NearestServerDispatcher(env),
        "greedy": lambda s: GreedyDispatcher(env),
        "local": lambda s: LocalDispatcher(env),
    }
    # the gate pair (entity, nearest) averages every eval seed; the
    # context rows settle for fewer sims — quote what was cut
    ctx_seeds = seeds[:1] if smoke else seeds[:2]
    rows = []
    by = {}
    for rate in rates:
        sp = StreamParams(rate=rate, horizon=horizon)
        for name, mk in dispatchers.items():
            full = name in ("entity", "nearest")
            agg = _eval(env, mk, sp, seeds if full else ctx_seeds,
                        timed=(name == "entity" and rate == MID_RATE))
            agg.update(rate=rate, dispatcher=name,
                       eval_seeds=len(seeds if full else ctx_seeds))
            rows.append(agg)
            by[(rate, name)] = agg
    print(f"# context dispatchers averaged over {len(ctx_seeds)} seed(s) "
          f"(gate pair over {len(seeds)})")

    def ratio(num_key, rate, a="entity", b="nearest", eps=1e-3):
        return (by[(rate, a)][num_key] + eps) / (by[(rate, b)][num_key]
                                                 + eps)

    # the acceptance gates: tuned entity vs nearest — p99 at mid load,
    # miss rate at saturation. Smoke's 3+2 training iterations cannot win
    # them, so there the ledger enforces the training-free teacher
    # (oracle vs nearest, same two gates, strict) plus stream sanity.
    parity = [{"name": "streaming_oracle_vs_nearest_p99_mid",
               "ratio": ratio("sojourn_p99", MID_RATE, a="oracle"),
               "limit": 1.0},
              {"name": "streaming_oracle_vs_nearest_miss_sat",
               "ratio": ratio("miss_rate", SAT_RATE, a="oracle"),
               "limit": 1.0}]
    if not smoke:
        parity += [{"name": "streaming_entity_vs_nearest_p99_mid",
                    "ratio": ratio("sojourn_p99", MID_RATE),
                    "limit": 1.0},
                   {"name": "streaming_entity_vs_nearest_miss_sat",
                    "ratio": ratio("miss_rate", SAT_RATE),
                    "limit": 1.0}]
    else:
        done = sum(by[(r, "entity")]["completed"] for r in rates)
        parity.append({"name": "streaming_entity_completes_tasks",
                       "ratio": 0.0 if done > 0 else 2.0, "limit": 1.0})

    # the tuned dispatcher's jitted policy forward on one live-state
    # snapshot, through the SAME interleaved best-of-k harness
    # bench_policy_latency sweeps (the live `dispatch_us` tails above add
    # bridge + host overhead on top of this)
    import jax
    from repro.stream.adapter import stream_env_state
    from repro.stream.events import StreamCore
    ent = dispatchers["entity"](0)
    s0 = stream_env_state(StreamCore(env, StreamParams(), seed=0))
    k0 = jax.random.PRNGKey(0)
    fwd = forward_us(
        {"entity@1": lambda: ent._act(ent.agent, s0, k0)},
        n_timed=5 if smoke else 20)

    return {"rows": rows, "train_s": train_s, "tune_s": tune_s,
            "tune_history": tune_hist,
            "mid_rate": MID_RATE, "sat_rate": SAT_RATE,
            "eval_seeds": len(seeds), "horizon": horizon,
            "entity_dispatch_us":
                by[(MID_RATE, "entity")].get("dispatch_us"),
            "policy_forward_us": fwd["entity@1"],
            "parity": parity}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        print(f"rate {r['rate']:5.1f} {r['dispatcher']:>10s}: "
              f"miss={r['miss_rate']:.3f} p99={r['sojourn_p99']:.3f} "
              f"thr={r['throughput']:.1f}/s spread={r['spread']:.2f}")
    lat = out["entity_dispatch_us"]
    if lat:
        print(f"entity dispatch latency: p50={lat['p50']:.0f}us "
              f"p99={lat['p99']:.0f}us")
    for p in out["parity"]:
        flag = "OK" if p["ratio"] <= p["limit"] else "FAIL"
        print(f"{p['name']}: {p['ratio']:.3f} (limit {p['limit']}) {flag}")
