"""Heterogeneous-fleet scheduling: learned MAHPPO policy vs heuristics on a
mixed 4-UE fleet (ResNet18 on Jetson, ResNet18 on an IoT-class SoC, and two
reduced-transformer UEs on phone NPUs), per-UE split tables throughout.

Also times the jitted training iteration on homogeneous vs mixed fleets of
the same size — the per-UE gather must not regress the hot path.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.fleets import make_mixed_fleet
from repro.core.cnn import make_resnet18
from repro.core.split import cnn_split_table, homogeneous_fleet
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl.baselines import local_policy_eval, random_policy_eval
from repro.rl.heuristics import greedy_eval
from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo

try:
    from benchmarks._timing import iter_us as _iter_us
except ImportError:        # run directly as a script
    from _timing import iter_us as _iter_us


def run(quick=True):
    iters = 30 if quick else 120
    fleet = make_mixed_fleet()
    env = MECEnv(make_env_params(fleet, n_channels=2))
    cfg = MAHPPOConfig(iterations=iters, horizon=1024, n_envs=8)

    t0 = time.time()
    agent, hist = train_mahppo(env, cfg, seed=0)
    train_s = time.time() - t0
    beta = float(env.params.beta)

    ev = evaluate_policy(env, agent, frames=64)
    rows = [{"policy": "mahppo", "t_task": ev["t_task"],
             "e_task": ev["e_task"],
             "overhead": ev["t_task"] + beta * ev["e_task"],
             "reward": ev["reward"]}]
    gr = greedy_eval(env)
    rows.append({"policy": "greedy", "t_task": gr["t_task"],
                 "e_task": gr["e_task"], "overhead": gr["overhead"],
                 "reward": float("nan")})
    lo = local_policy_eval(env, frames=64)
    rows.append({"policy": "local", "t_task": lo["t_task"],
                 "e_task": lo["e_task"],
                 "overhead": lo["t_task"] + beta * lo["e_task"],
                 "reward": lo["reward"]})
    ra = random_policy_eval(env, frames=64)
    rows.append({"policy": "random", "t_task": float("nan"),
                 "e_task": float("nan"), "overhead": float("nan"),
                 "reward": ra["reward"]})

    # hot-path regression guard: mixed fleet vs homogeneous fleet, same N
    tcfg = MAHPPOConfig(horizon=512, n_envs=4, reuse=2)
    homo = homogeneous_fleet(cnn_split_table(make_resnet18(101), 224), 4)
    us_homo = _iter_us(MECEnv(make_env_params(homo, n_channels=2)), tcfg)
    us_mixed = _iter_us(env, tcfg)
    return {"rows": rows, "train_s": train_s,
            "final_reward": float(np.mean([h["reward_mean"]
                                           for h in hist[-5:]])),
            "iter_us_homogeneous": us_homo, "iter_us_mixed": us_mixed}


if __name__ == "__main__":
    out = run()
    print(f"final_reward={out['final_reward']:.4f} "
          f"(train {out['train_s']:.1f}s)")
    for r in out["rows"]:
        print({k: round(v, 4) if isinstance(v, float) else v
               for k, v in r.items()})
    print(f"iteration: homogeneous {out['iter_us_homogeneous']/1e3:.1f} ms, "
          f"mixed {out['iter_us_mixed']/1e3:.1f} ms")
