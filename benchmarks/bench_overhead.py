"""Fig. 7: per-split-point local latency / energy (AE vs JALAD vs full
local) for the paper's CNNs and the assigned transformer archs — plus the
long-task rung ladder: completion throughput when a single task's
`t_task` is pushed past the frame length `t0` (the regime the pre-PR-7
frame model silently starved by discarding unfinished carry-over work)."""
from __future__ import annotations

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.cnn import CNN_FACTORY
from repro.core.split import (cnn_jalad_table, cnn_split_table,
                              transformer_split_table)

# expected/realized completion-throughput bound for every long-task rung:
# with exact carry the simulator tracks the Eq. 7/8 closed form to within
# one task of discretization, so ~1.0; the pre-fix restart bug drove the
# multi-frame rungs' realized throughput to zero (ratio -> infinity).
LONG_TASK_LIMIT = 1.1


def run_long_tasks(smoke=False):
    """Single-UE completion throughput at t_task/t0 from ~0.6x to ~5.7x.

    Each rung drives a fixed action for enough frames to complete
    ``target`` tasks at the closed-form rate, then reports realized
    throughput (completions per frame) against the expected t0/t_task.
    The last rung offloads, so its transmit phase also spans frames."""
    import jax
    import jax.numpy as jnp

    from repro.env.channel import channel_gain, uplink_rates
    from repro.env.mecenv import MECEnv, make_env_params

    plan = cnn_split_table(CNN_FACTORY["resnet18"](101), 224)
    target = 12 if smoke else 40
    rows, parity = [], []
    # (t0 seconds, split action or "local", tx power watts)
    rungs = [(0.1, "local", 0.05), (0.04, "local", 0.05),
             (0.02, "local", 0.05), (0.005, 1, 0.3)]
    for t0, split, p_tx in rungs:
        env = MECEnv(make_env_params(plan, n_ue=1, n_channels=2, t0=t0))
        prm = env.params
        b = env.n_actions_b - 1 if split == "local" else split
        s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
        # closed-form Eq. 7 latency for the lone clean-channel UE
        g = channel_gain(s.d, prm.pathloss)
        r = float(jnp.maximum(uplink_rates(
            jnp.asarray([p_tx]), jnp.asarray([0]), g, jnp.asarray([True]),
            omega=prm.omega, sigma=prm.sigma), 1.0)[0])
        t_task = float(prm.l_new[0, b]) + float(prm.n_new[0, b]) / r
        frames = int(np.ceil(target * t_task / t0))
        acts = {"split": jnp.asarray([b], jnp.int32),
                "channel": jnp.zeros((1,), jnp.int32),
                "power": jnp.asarray([p_tx], jnp.float32)}

        def body(carry, _):
            s2, _, _, info = env.step(carry, acts)
            return s2, info["completed"]

        _, comp = jax.jit(
            lambda s0: jax.lax.scan(body, s0, None, length=frames))(s)
        realized = float(np.asarray(comp).sum()) / frames
        expected = t0 / t_task
        ratio = expected / max(realized, 1e-9)
        fpt = t_task / t0
        rows.append({"t0_ms": 1e3 * t0, "b": b,
                     "frames_per_task": fpt, "frames": frames,
                     "t_task_ms": 1e3 * t_task,
                     "expected_per_frame": expected,
                     "realized_per_frame": realized, "ratio": ratio})
        parity.append({"name": f"long_task_throughput_x{fpt:.1f}",
                       "ratio": ratio, "limit": LONG_TASK_LIMIT})
    return {"rows": rows, "parity": parity}


def run():
    rows = []
    for name in ("resnet18", "vgg11", "mobilenetv2"):
        model = CNN_FACTORY[name](101)
        ae = cnn_split_table(model, 224)
        ja = cnn_jalad_table(model, 224)
        for b in range(ae.n_actions):
            rows.append({
                "backbone": name, "b": b,
                "t_local_ms": 1e3 * float(ae.t_local[b]),
                "t_comp_ms": 1e3 * float(ae.t_comp[b]),
                "e_local_mJ": 1e3 * float(ae.e_local[b] + ae.e_comp[b]),
                "f_kbits": float(ae.f_bits[b]) / 1e3,
                "jalad_t_comp_ms": 1e3 * float(ja.t_comp[b]),
                "jalad_f_kbits": float(ja.f_bits[b]) / 1e3,
            })
    for arch in ARCH_IDS:
        plan = transformer_split_table(get_config(arch))
        for b in range(plan.n_actions):
            rows.append({
                "backbone": arch, "b": b,
                "t_local_ms": 1e3 * float(plan.t_local[b]),
                "t_comp_ms": 1e3 * float(plan.t_comp[b]),
                "e_local_mJ": 1e3 * float(plan.e_local[b] + plan.e_comp[b]),
                "f_kbits": float(plan.f_bits[b]) / 1e3,
                "feasible": bool(plan.feasible[b]),
            })
    return {"rows": rows}


if __name__ == "__main__":
    for r in run()["rows"]:
        print(r)
