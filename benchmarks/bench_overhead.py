"""Fig. 7: per-split-point local latency / energy (AE vs JALAD vs full
local) for the paper's CNNs and the assigned transformer archs."""
from __future__ import annotations

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.cnn import CNN_FACTORY
from repro.core.split import (cnn_jalad_table, cnn_split_table,
                              transformer_split_table)


def run():
    rows = []
    for name in ("resnet18", "vgg11", "mobilenetv2"):
        model = CNN_FACTORY[name](101)
        ae = cnn_split_table(model, 224)
        ja = cnn_jalad_table(model, 224)
        for b in range(ae.n_actions):
            rows.append({
                "backbone": name, "b": b,
                "t_local_ms": 1e3 * float(ae.t_local[b]),
                "t_comp_ms": 1e3 * float(ae.t_comp[b]),
                "e_local_mJ": 1e3 * float(ae.e_local[b] + ae.e_comp[b]),
                "f_kbits": float(ae.f_bits[b]) / 1e3,
                "jalad_t_comp_ms": 1e3 * float(ja.t_comp[b]),
                "jalad_f_kbits": float(ja.f_bits[b]) / 1e3,
            })
    for arch in ARCH_IDS:
        plan = transformer_split_table(get_config(arch))
        for b in range(plan.n_actions):
            rows.append({
                "backbone": arch, "b": b,
                "t_local_ms": 1e3 * float(plan.t_local[b]),
                "t_comp_ms": 1e3 * float(plan.t_comp[b]),
                "e_local_mJ": 1e3 * float(plan.e_local[b] + plan.e_comp[b]),
                "f_kbits": float(plan.f_bits[b]) / 1e3,
                "feasible": bool(plan.feasible[b]),
            })
    return {"rows": rows}


if __name__ == "__main__":
    for r in run()["rows"]:
        print(r)
