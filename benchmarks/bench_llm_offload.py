"""LLM decode offloading on a mixed CNN + LLM edge pool.

The fleet is ``core.fleets.make_llm_mixed_fleet``: two ResNet18 UEs
(Jetson / IoT) whose feature payload SHRINKS with split depth, plus one
qwen3-1.7b decode UE per context rung (256 / 1024 / 4096) whose boundary
payload — compressed hidden states + the UE-side layers' KV cache —
GROWS with context (``core.split.llm_decode_split_table``). The pool is
a thin multi-tenant slice of a TPU-v5e (``V5E_UTILIZATION`` of peak) at
the cell center plus an interference-free edge-GPU tier at 1.4x the
path-loss distance.

The trap mirrors bench_multi_server but adds the context dimension:
nearest-server greedy piles all five UEs onto the v5e, whose
processor-sharing service time scales with the NUMBER of tenants — and
the ctx-4096 rung brings ~8x the prefill work of the short rung, so
keeping it on the v5e slows everyone. The best fixed-power assignment
(verified by exhaustive probe at these constants) routes the CNNs to the
edge GPU, offloads the short/mid rungs raw (b = 0) to the v5e, and keeps
the LONG-context rung local — the context-length-dependent split shift.
The trained policy also optimizes transmit power, so its learned optimum
can beat that assignment by other means; the per-rung mode report and
the ``ctx_shift`` flag record whether the shift has emerged (report-only
— the ledger gates are below). ``run`` gates entity-vs-nearest
through the ledger; ``run_closed_form`` gates the long rung's realized
per-frame throughput against the Eq. 7/8 closed form of its split table
(training-free, so it gates in smoke too), both at a local rung and at a
late split whose 1.5 Gbit KV payload spans ~67 frames of transmit.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import overhead as oh
from repro.core.fleets import EdgePool, LLM_CTX_RUNGS, make_llm_mixed_fleet
from repro.core.split import llm_decode_split_table
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl import nets
from repro.rl.baselines import (load_aware_eval, local_policy_eval,
                                nearest_server_eval)
from repro.rl.heuristics import greedy_eval
from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo

ARCH = "qwen3-1.7b"
N_CNN = 2
GEN_TOKENS = 16
KV_BITS = 8
# long rungs: full-local runs span multiple frames (ctx4096 ~3.9x t0)
T0 = 2.0
# the v5e slice: large enough that offloading CNNs and short-context
# prefills wins, small enough that the long rung's ~8x prefill work makes
# offloading it jointly expensive under count-proportional sharing
V5E_UTILIZATION = 0.025
BEATS_NEAREST_LIMIT = 1.0
# 3 smoke iterations can't learn the assignment; gross-sanity bound only
BEATS_NEAREST_LIMIT_SMOKE = 10.0
# same tolerance family as bench_overhead.LONG_TASK_LIMIT
CLOSED_FORM_LIMIT = 1.1


def ue_labels(n_cnn=N_CNN, ctx_rungs=LLM_CTX_RUNGS):
    devs = ("jetson", "iot")
    return [f"resnet18-{devs[i % 2]}" for i in range(n_cnn)] \
        + [f"{ARCH}-ctx{c}" for c in ctx_rungs]


def make_llm_pool_env() -> MECEnv:
    fleet = make_llm_mixed_fleet(ARCH, n_cnn=N_CNN,
                                 gen_tokens=GEN_TOKENS, kv_bits=KV_BITS)
    pool = EdgePool((
        oh.ServerProfile.from_device(oh.TPU_V5E,
                                     utilization=V5E_UTILIZATION),
        oh.ServerProfile.from_device(oh.EDGE_GPU, dist_scale=1.4)))
    return MECEnv(make_env_params(fleet, n_channels=2, t0=T0, pool=pool))


def _mode_decisions(env, agent):
    """Deterministic per-UE (split, route) of the trained ENTITY policy at
    the eval-mode reset state — the same forward evaluate_policy uses
    (set-network over env.observe_entities, not per-UE actor stacks)."""
    space = env.action_space
    s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
    masks = space.broadcast_masks(env.action_masks(s), env.params.n_ue)
    dist = nets.entity_actor_forward(agent["entity_actor"], space,
                                     env.observe_entities(s), masks)
    a = jax.vmap(space.mode)(dist, masks)
    b = np.asarray(a["split"])
    route = np.asarray(a["route"]) if "route" in a \
        else np.zeros_like(b)
    local = env.n_actions_b - 1
    labels = ue_labels()
    rows = [{"ue": labels[i], "split": int(b[i]), "route": int(route[i]),
             "local": bool(b[i] == local)}
            for i in range(len(labels))]
    # the context-length-dependent shift: the longest rung stays local (or
    # splits strictly later) while at least one shorter rung offloads
    llm = rows[N_CNN:]
    shorter_offl = [r for r in llm[:-1] if not r["local"]]
    long_r = llm[-1]
    ctx_shift = bool(shorter_offl) and (
        long_r["local"]
        or all(long_r["split"] > r["split"] for r in shorter_offl))
    return {"rows": rows, "ctx_shift": ctx_shift}


def flops_crosscheck(ctx_rungs=LLM_CTX_RUNGS, gen_tokens=GEN_TOKENS):
    """core.overhead per-layer tables vs the MODEL_FLOPS serving
    convention (costmodel.llm_serve_flops) — expected to agree to O(1)
    (the convention excludes attention terms), not exactly."""
    try:
        from benchmarks import costmodel
    except ImportError:        # run directly as a script
        import costmodel
    cfg = get_config(ARCH)
    rows = []
    for ctx in ctx_rungs:
        prefill = sum(l["flops"] for l in oh.layer_costs(cfg, ctx)) \
            + oh.embed_costs(cfg, ctx)["flops"]
        decode = sum(l["flops"] for l in oh.decode_layer_costs(cfg, ctx)) \
            + oh.embed_costs(cfg, 1)["flops"]
        table = float(prefill + gen_tokens * decode)
        conv = float(costmodel.llm_serve_flops(cfg, ctx, gen_tokens))
        rows.append({"ctx": ctx, "table_flops": table,
                     "convention_flops": conv, "ratio": table / conv})
    return rows


def run(quick=True, smoke=False):
    iters = 3 if smoke else (30 if quick else 100)
    env = make_llm_pool_env()
    beta = float(env.params.beta)

    t0 = time.time()
    cfg = MAHPPOConfig(iterations=iters, horizon=512, n_envs=4, reuse=4,
                       entity_policy=True)
    agent, _ = train_mahppo(env, cfg, seed=0)
    train_s = time.time() - t0

    ev = evaluate_policy(env, agent, frames=64)
    entity_ovh = ev["t_task"] + beta * ev["e_task"]
    near = nearest_server_eval(env)
    load = load_aware_eval(env)
    gr = greedy_eval(env)
    lo = local_policy_eval(env, frames=64)
    rows = [
        {"policy": "entity", "t_task": ev["t_task"], "e_task": ev["e_task"],
         "overhead": entity_ovh, "reward": ev["reward"]},
        {"policy": "nearest_server", "t_task": near["t_task"],
         "e_task": near["e_task"], "overhead": near["overhead"],
         "route": near["route"]},
        {"policy": "load_aware", "t_task": load["t_task"],
         "e_task": load["e_task"], "overhead": load["overhead"],
         "route": load["route"]},
        {"policy": "greedy", "t_task": gr["t_task"], "e_task": gr["e_task"],
         "overhead": gr["overhead"], "route": gr["route"]},
        {"policy": "local", "t_task": lo["t_task"], "e_task": lo["e_task"],
         "overhead": lo["t_task"] + beta * lo["e_task"],
         "reward": lo["reward"]},
    ]

    modes = _mode_decisions(env, agent)
    ratio = entity_ovh / max(near["overhead"], 1e-9)
    limit = BEATS_NEAREST_LIMIT_SMOKE if smoke else BEATS_NEAREST_LIMIT
    return {"rows": rows, "train_s": train_s,
            "beats_nearest": bool(entity_ovh <= near["overhead"]),
            "modes": modes, "ctx_shift": modes["ctx_shift"],
            "flops_rows": flops_crosscheck(),
            "parity": [{"name": "llm_entity_vs_nearest",
                        "ratio": ratio, "limit": limit}]}


def run_closed_form(smoke=False):
    """Single-UE realized throughput of the LONG-context rung vs the
    Eq. 7/8 closed form of its split table, at full-local (the multi-frame
    compute carry-over path) and at the latest split (the KV-payload
    transmit path: ~1.5 Gbit spans ~67 frames on a clean channel).
    Training-free, so the ledger gate holds in smoke as well."""
    from repro.env.channel import channel_gain, uplink_rates

    plan = llm_decode_split_table(get_config(ARCH), LLM_CTX_RUNGS[-1],
                                  gen_tokens=GEN_TOKENS, kv_bits=KV_BITS)
    env = MECEnv(make_env_params(plan, n_ue=1, n_channels=2, t0=T0))
    prm = env.params
    target = 6 if smoke else 12
    rows, parity = [], []
    rungs = [("local", env.n_actions_b - 1, 0.05),
             ("late_split", env.n_actions_b - 2, 0.3)]
    for tag, b, p_tx in rungs:
        s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
        g = channel_gain(s.d, prm.pathloss)
        r = float(jnp.maximum(uplink_rates(
            jnp.asarray([p_tx]), jnp.asarray([0]), g, jnp.asarray([True]),
            omega=prm.omega, sigma=prm.sigma), 1.0)[0])
        t_task = float(prm.l_new[0, b]) + float(prm.n_new[0, b]) / r
        frames = int(np.ceil(target * t_task / T0))
        acts = {"split": jnp.asarray([b], jnp.int32),
                "channel": jnp.zeros((1,), jnp.int32),
                "power": jnp.asarray([p_tx], jnp.float32)}

        def body(carry, _):
            s2, _, _, info = env.step(carry, acts)
            return s2, info["completed"]

        _, comp = jax.jit(
            lambda s0: jax.lax.scan(body, s0, None, length=frames))(s)
        realized = float(np.asarray(comp).sum()) / frames
        expected = T0 / t_task
        ratio = expected / max(realized, 1e-9)
        rows.append({"rung": tag, "b": b, "ctx": LLM_CTX_RUNGS[-1],
                     "t_task_s": t_task, "frames": frames,
                     "frames_per_task": t_task / T0,
                     "expected_per_frame": expected,
                     "realized_per_frame": realized, "ratio": ratio})
        parity.append({"name": f"llm_long_ctx_{tag}_throughput",
                       "ratio": ratio, "limit": CLOSED_FORM_LIMIT})
    return {"rows": rows, "parity": parity}


if __name__ == "__main__":
    out = run()
    for r in out["rows"]:
        extra = f" route={r['route']}" if "route" in r else ""
        print(f"{r['policy']:>14s}: overhead {r['overhead']:.4f} "
              f"(t {r['t_task']:.3f} s, e {1e3*r['e_task']:.1f} mJ){extra}")
    print(f"entity {'BEATS' if out['beats_nearest'] else 'LOSES TO'} "
          f"nearest-server greedy; ctx_shift={out['ctx_shift']}")
    for m in out["modes"]["rows"]:
        print(f"  {m['ue']:>18s}: split {m['split']}"
              f"{' (local)' if m['local'] else ''} -> server {m['route']}")
    cf = run_closed_form()
    for r in cf["rows"]:
        print(f"closed form [{r['rung']}]: t_task {r['t_task_s']:.1f} s "
              f"({r['frames_per_task']:.1f} frames), expected "
              f"{r['expected_per_frame']:.4f} vs realized "
              f"{r['realized_per_frame']:.4f} (ratio {r['ratio']:.3f})")
