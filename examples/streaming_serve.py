"""Streaming serve demo: the trained entity policy as a live dispatcher.

Trains the pool-generalist entity policy on the frame-synchronous MEC env
(randomized 2-server geometries, exactly like the generalization bench),
streaming-fine-tunes it by DAgger distillation of the occupancy-aware
dispatch oracle (``rl.streaming`` — the frame-trained weights transfer
honestly but poorly: the mean-overhead equilibrium picks conservative
power/splits that miss deadlines under load), then deploys it as the
dispatcher of the event-driven asyncio serve daemon
(``repro.stream.dispatcher``): mock UE coroutines generate Poisson task
arrivals with per-class deadlines, the daemon renders the live
queue/occupancy state as an ``EnvState`` and asks the policy where to
split, which server to use and at what power (sampled — the
load-spreading deployment mode — with the channel picked least-loaded at
dispatch time, the same live peek every baseline gets), and mock server
coroutines execute each task for its Eq. 7/8 closed-form service time.
Ends with the QoS report (throughput, deadline-miss rate, p50/p95/p99
sojourn) for the tuned policy, its zero-shot (untuned) form, and the
nearest-server / full-local baselines, all on the SAME arrival
realization.

Everything is deterministic in ``--seed``: the daemon runs on a virtual
clock ((time, seq)-ordered events, per-UE RNG streams), so two runs with
the same seed print byte-identical reports regardless of machine or
scheduler jitter.

  PYTHONPATH=src python examples/streaming_serve.py --seed 0
  # quick look (~1 min, undertrained dispatcher):
  PYTHONPATH=src python examples/streaming_serve.py --iters 10 --tune-iters 4
"""
import argparse

from repro.core.fleets import (make_edge_pool, make_mixed_fleet,
                               random_pool_ranges)
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl.mahppo import MAHPPOConfig, train_mahppo
from repro.rl.streaming import StreamTuneConfig, finetune_streaming
from repro.stream.adapter import (EntityDispatcher, LocalDispatcher,
                                  NearestServerDispatcher)
from repro.stream.dispatcher import run_daemon
from repro.stream.events import StreamParams


def build_env(n_ue, n_servers, randomized=False):
    pool = make_edge_pool(n_servers)
    ranges = random_pool_ranges(n_servers) if randomized else None
    return MECEnv(make_env_params(make_mixed_fleet(n_ue=n_ue),
                                  n_channels=2, pool=pool,
                                  pool_ranges=ranges))


def print_report(name, rep):
    print(f"  {name:16s} throughput={rep['throughput']:6.1f}/s  "
          f"miss={rep['miss_rate']:6.1%}  drop={rep['drop_rate']:6.1%}  "
          f"sojourn p50={rep['sojourn_p50']:.3f}s "
          f"p95={rep['sojourn_p95']:.3f}s p99={rep['sojourn_p99']:.3f}s")


def main():
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--seed", type=int, default=0,
                    help="seeds training AND the stream (deterministic)")
    ap.add_argument("--ues", type=int, default=8)
    ap.add_argument("--servers", type=int, default=2)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="per-UE mean arrivals / second")
    ap.add_argument("--horizon", type=float, default=10.0,
                    help="seconds of arrivals (the daemon then drains)")
    ap.add_argument("--iters", type=int, default=30,
                    help="MAHPPO training iterations (frame env)")
    ap.add_argument("--tune-iters", type=int, default=14,
                    help="streaming DAgger fine-tune iterations "
                         "(0 = deploy zero-shot)")
    args = ap.parse_args()

    print(f"training the entity policy: {args.iters} MAHPPO iterations on "
          f"the frame env (N={args.ues}, randomized "
          f"{args.servers}-server geometries) ...")
    env_rnd = build_env(args.ues, args.servers, randomized=True)
    cfg = MAHPPOConfig(iterations=args.iters, horizon=512, n_envs=4,
                       reuse=4, entity_policy=True, randomize_pool=True)
    agent, hist = train_mahppo(env_rnd, cfg, seed=args.seed)
    print(f"  final frame reward: {hist[-1]['reward_mean']:.4f}")

    env = build_env(args.ues, args.servers)
    tuned = agent
    if args.tune_iters:
        print(f"\nstreaming fine-tune: {args.tune_iters} DAgger iterations "
              "distilling the occupancy-aware dispatch oracle (mid-load + "
              "saturated scenarios) ...")
        tuned, th = finetune_streaming(
            env, agent,
            [StreamParams(rate=6.0, horizon=8.0),
             StreamParams(rate=14.0, horizon=8.0)],
            StreamTuneConfig(iterations=args.tune_iters),
            seed=args.seed + 100,
            log_cb=lambda h: print(
                f"  iter {h['iteration']:2d}: reward="
                f"{h['reward_mean']:8.3f}  miss={h['miss_rate']:6.1%}  "
                f"p99={h['p99']:.3f}s"))

    sp = StreamParams(rate=args.rate, horizon=args.horizon)
    print(f"\nstreaming {args.horizon:.0f}s of Poisson arrivals at "
          f"{args.rate:g} tasks/s/UE through the asyncio daemon "
          f"(seed {args.seed}):")

    log = []
    rep, core = run_daemon(
        env,
        EntityDispatcher(env, tuned, deterministic=False, live_channel=True,
                         seed=args.seed),
        sp, seed=args.seed, server_log=log)
    per_server = [sum(1 for (_, e, _) in log if e == s)
                  for s in range(env.n_servers)]
    print_report("entity (tuned)", rep)
    print(f"    server task counts: {per_server}  "
          f"(tasks={rep['tasks']}, arrivals={rep['arrivals']})")

    for name, disp in [("entity zero-shot", EntityDispatcher(env, agent)),
                       ("nearest-server", NearestServerDispatcher(env)),
                       ("full-local", LocalDispatcher(env))]:
        bre, _ = run_daemon(env, disp, sp, seed=args.seed)
        print_report(name, bre)


if __name__ == "__main__":
    main()
