"""End-to-end training driver: train a ~100M-param dense LM (qwen3 family,
reduced) for a few hundred steps on the synthetic Markov corpus, with
checkpointing and CSV metrics.

  PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import os
import time

import jax
import numpy as np

from repro.ckpt import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.synthetic import TokenPipelineConfig, token_batch_stream
from repro.launch.steps import make_train_step
from repro.models import init_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--out", default="artifacts/train_lm")
    args = ap.parse_args()

    cfg = get_config("qwen3-1.7b").replace(
        n_layers=args.layers, d_model=args.d_model, n_heads=12, n_kv_heads=4,
        d_head=64, d_ff=4 * args.d_model, vocab_size=args.vocab,
        param_dtype="float32", compute_dtype="float32", remat=False,
        attn_chunk=128)
    params = init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"model: {args.layers}L d={args.d_model} -> {n_params/1e6:.1f}M params")

    train_step, opt_init = make_train_step(cfg, base_lr=args.lr, warmup=20,
                                           total=args.steps)
    opt = opt_init(params)
    step_fn = jax.jit(train_step)
    stream = token_batch_stream(TokenPipelineConfig(
        vocab_size=args.vocab, seq_len=args.seq, batch=args.batch))

    os.makedirs(args.out, exist_ok=True)
    csv = open(os.path.join(args.out, "metrics.csv"), "w")
    csv.write("step,loss,ce,grad_norm,lr,ms_per_step\n")
    t_last = time.time()
    for step in range(1, args.steps + 1):
        batch = next(stream)
        params, opt, m = step_fn(params, opt, batch)
        if step % 10 == 0 or step == 1:
            dt = (time.time() - t_last) / (10 if step > 1 else 1) * 1e3
            t_last = time.time()
            print(f"step {step:4d} loss={float(m['loss']):.4f} "
                  f"ce={float(m['ce']):.4f} gnorm={float(m['grad_norm']):.2f} "
                  f"{dt:.0f}ms/step")
            csv.write(f"{step},{float(m['loss']):.5f},{float(m['ce']):.5f},"
                      f"{float(m['grad_norm']):.4f},{float(m['lr']):.2e},"
                      f"{dt:.1f}\n")
            csv.flush()
    save_checkpoint(os.path.join(args.out, "final"), params,
                    step=args.steps, extra={"config": cfg.name})
    print(f"saved checkpoint to {args.out}/final.npz")


if __name__ == "__main__":
    main()
