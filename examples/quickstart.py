"""Quickstart: the paper's pipeline end-to-end on a reduced setup.

1. Build a split plan for an assigned architecture (layer-indivisible tasks,
   AE-compressed boundary features — paper §2-3).
2. Train a MAHPPO scheduler for 5 UEs sharing 2 channels (paper §5).
3. Compare against full-local inference (paper §6).

  PYTHONPATH=src python examples/quickstart.py [--arch qwen3-1.7b]
"""
import argparse

import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core.split import transformer_split_table
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl.baselines import local_policy_eval
from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ARCH_IDS)
    ap.add_argument("--iterations", type=int, default=30)
    ap.add_argument("--n-ue", type=int, default=5)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    plan = transformer_split_table(cfg)
    print(f"split plan for {args.arch}:")
    for b in range(plan.n_actions):
        print(f"  b={b}: t_local={1e3*plan.t_local[b]:8.1f}ms "
              f"payload={plan.f_bits[b]/1e3:9.1f}kbit "
              f"feasible={bool(plan.feasible[b])}")

    t_full = float(plan.t_local[-1])
    e_full = float(plan.e_local[-1])
    env = MECEnv(make_env_params(
        plan, n_ue=args.n_ue, n_channels=2,
        t0=max(0.5, round(10 * t_full, 1)),
        beta=t_full / max(e_full, 1e-9)))

    print(f"\ntraining MAHPPO ({args.iterations} iterations)...")
    ppo = MAHPPOConfig(iterations=args.iterations, horizon=1024, n_envs=8)
    agent, hist = train_mahppo(env, ppo, seed=0,
                               log_cb=lambda r: print(
                                   f"  iter {r['iteration']:3d} "
                                   f"reward={r['reward_mean']:.4f}")
                               if r["iteration"] % 5 == 0 else None)

    ev = evaluate_policy(env, agent, frames=64)
    lo = local_policy_eval(env, frames=64)
    beta = float(env.params.beta)
    ovh = ev["t_task"] + beta * ev["e_task"]
    lovh = lo["t_task"] + beta * lo["e_task"]
    print(f"\nMAHPPO : latency {1e3*ev['t_task']:.1f} ms  "
          f"energy {1e3*ev['e_task']:.1f} mJ  overhead {ovh:.4f}")
    print(f"Local  : latency {1e3*lo['t_task']:.1f} ms  "
          f"energy {1e3*lo['e_task']:.1f} mJ  overhead {lovh:.4f}")
    print(f"overhead reduction: {100*(1-ovh/lovh):.0f}%")


if __name__ == "__main__":
    main()
