"""End-to-end collaborative inference driver: ACTUALLY runs the split model.

A reduced assigned architecture is decoupled at the MAHPPO-chosen split
point: the "UE" runs the front layers and the AE+quantization compressor
(the Pallas kernel path), bits cross a simulated wireless channel, the
"edge" dequantizes, decodes and finishes the forward pass. Verifies that
end-to-end top-1 predictions survive compression, and reports simulated
latency per request batch.

  PYTHONPATH=src python examples/collaborative_serve.py --arch qwen3-1.7b

With ``--fleet`` it instead schedules a HETEROGENEOUS 4-UE fleet (two
ResNet18 CNN UEs on Jetson-class devices — one degraded to an IoT-class
SoC — plus two reduced-transformer UEs on phone NPUs) with MAHPPO over the
per-UE split tables, and prints each UE's learned split decision:

  PYTHONPATH=src python examples/collaborative_serve.py --fleet

With ``--servers E`` the edge side becomes a POOL of E servers (TPU-v5e
near the cell center, weaker/farther tiers behind it): the action space
grows a `route` head, and the demo prints each UE's learned (split,
server) decision plus the fleet's load distribution vs the
nearest-server baseline:

  PYTHONPATH=src python examples/collaborative_serve.py --servers 2

With ``--shared-policy`` the N per-UE actors are replaced by ONE
weight-shared actor applied to every UE's featurized observation row
(``env.observe_per_ue``) — O(1) parameters in the fleet size, and the
trained agent evaluates zero-shot on other fleet sizes and pool layouts
(see ``benchmarks/bench_generalization.py``). Composes with --churn and
--servers:

  PYTHONPATH=src python examples/collaborative_serve.py --shared-policy \\
      --servers 2

With ``--entity-policy`` the policy consumes the structured ENTITY-SET
observation (``env.observe_entities``: per-UE rows, per-server rows, and
UE x server edge features) and scores every (UE, server) pair with one
shared route scorer. Training resamples the pool geometry every episode
(the route head actually learns to read the pool), and the SAME
parameters then run zero-shot on a pool of a different SIZE — the demo
finishes by dropping the trained agent onto an E+1-server pool:

  PYTHONPATH=src python examples/collaborative_serve.py --entity-policy \\
      --servers 2

With ``--llm`` the fleet is the MIXED CNN + LLM-decode scenario of
``benchmarks/bench_llm_offload.py``: two ResNet18 UEs plus one
qwen3-1.7b decode UE per context rung (256 / 1024 / 4096), whose
boundary payload (compressed hidden states + UE-side KV cache) GROWS
with context, against a thin multi-tenant v5e slice + edge-GPU pool.
The demo prints each rung's learned split and whether the
context-length-dependent shift (short rungs offload, the long rung
stays local) has emerged:

  PYTHONPATH=src python examples/collaborative_serve.py --llm

With ``--distill`` the demo closes the train-big/serve-small loop: the
trained entity teacher is distilled into a small flat-trunk student on
the STATIC deployment pool (``rl.distill`` — one fused MLP pass over
``observe_per_ue`` rows emits every action head), the student is int8
weight-quantized for the fused dequant-matmul kernel, and the demo
finishes with a batch-1 dispatch-latency readout (teacher vs distilled
f32 vs int8):

  PYTHONPATH=src python examples/collaborative_serve.py --distill
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.core.compressor import init_autoencoder
from repro.core.split import transformer_split_table
from repro.env.channel import channel_gain, uplink_rates
from repro.kernels import ops as kops
from repro.models import apply_model, init_params
from repro.models.layers import apply_norm
from repro.models.model import _logits, _run_stack, layer_plan


def run_split_forward(params, cfg, tokens, split_layer, ae, bits=8):
    """UE part -> compress -> (channel) -> decompress -> edge part."""
    pattern, n_groups, tail_types = layer_plan(cfg)
    assert len(pattern) == 1, "example uses uniform-pattern archs"
    bt = pattern[0]
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = jnp.take(params["embed"], tokens, axis=0)

    stack = params["decoder"]
    blocks = stack["blocks"][0]

    def run_layers(x, lo, hi):
        from repro.models.blocks import apply_block
        for i in range(lo, hi):
            p_i = jax.tree_util.tree_map(lambda a: a[i], blocks)
            x, _, _ = apply_block(p_i, x, cfg, bt, positions=positions,
                                  mode="train")
        return x

    # ---- UE side
    x = run_layers(x, 0, split_layer)
    mn, mx = float(x.min()), float(x.max())
    codes = kops.bottleneck_encode(x.astype(jnp.float32),
                                   ae["enc"].astype(jnp.float32), mn, mx,
                                   bits=bits)
    payload_bits = codes.size * bits

    # ---- edge side
    z = kops.dequantize(codes, mn, mx, bits=bits)
    x_hat = (z @ ae["dec"]).astype(x.dtype)
    x = run_layers(x_hat, split_layer, cfg.n_layers)
    x = apply_norm(stack["ln_f"], x, cfg)
    return _logits(params, cfg, x), payload_bits


def run_fleet_demo(arch: str, iterations: int, churn_rate=0.0,
                   leave_rate=0.0, n_servers=1, shared_policy=False,
                   entity_policy=False, n_ue=4, fused_scorer=False,
                   n_shards=1, llm=False, distill=False):
    """Mixed-fleet scheduling: per-UE split tables + device tiers end-to-end
    through MAHPPO, vs the non-coordinating greedy heuristic. With nonzero
    churn/leave rates the fleet is DYNAMIC: UEs join from a standby pool and
    drop mid-episode, and the policy schedules whoever is present. With
    n_servers > 1 the edge side is an EdgePool and routing is part of the
    learned action. With shared_policy, ONE weight-shared actor over per-UE
    feature rows (`env.observe_per_ue`) replaces the N per-UE actors —
    O(1) parameters in the fleet size, and the trained agent transfers
    zero-shot to other fleet sizes (see benchmarks/bench_generalization.py)."""
    from repro.core.fleets import (EdgePool, LLM_CTX_RUNGS, make_edge_pool,
                                   make_llm_mixed_fleet, make_mixed_fleet,
                                   random_pool_ranges)
    from repro.env.mecenv import MECEnv, make_env_params
    from repro.rl import nets
    from repro.rl.heuristics import greedy_eval
    from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo

    t0 = 0.5
    if llm:
        # the bench_llm_offload scenario: CNN UEs + one LLM-decode UE per
        # context rung, against a thin multi-tenant v5e slice and an
        # interference-free edge-GPU tier; long frames (t0 = 2 s) so the
        # ctx-4096 rung's full-local run spans multiple frames
        from repro.core import overhead as oh_
        fleet = make_llm_mixed_fleet(arch)
        t0 = 2.0
        print(f"LLM context rungs: {LLM_CTX_RUNGS} (f_bits grows with "
              f"context — KV cache rides the boundary payload)")
    else:
        fleet = make_mixed_fleet(arch, n_ue=n_ue)
    print("fleet:")
    for i, (name, prof) in enumerate(zip(fleet.names, fleet.profiles)):
        feas = int(fleet.feasible[i].sum())
        print(f"  ue{i}: {name:14s} on {prof.name:12s} "
              f"(P_compute={prof.p_compute:.1f} W, "
              f"{feas}/{fleet.n_actions} feasible actions)")
    if llm:
        pool = EdgePool((
            oh_.ServerProfile.from_device(oh_.TPU_V5E, utilization=0.025),
            oh_.ServerProfile.from_device(oh_.EDGE_GPU, dist_scale=1.4)))
    else:
        pool = make_edge_pool(n_servers) if n_servers > 1 else None
    if pool is not None:
        print("edge pool:")
        for e, srv in enumerate(pool.servers):
            print(f"  srv{e}: {srv.name:10s} dist x{srv.dist_scale:.1f}  "
                  f"bw x{srv.bw_scale:.1f}  "
                  f"edge_speed={srv.edge_speed/1e12:.1f} TFLOP/s")

    randomize = entity_policy and pool is not None and not llm
    env = MECEnv(make_env_params(
        fleet, n_channels=2, t0=t0, churn_rate=churn_rate,
        leave_rate=leave_rate, pool=pool,
        pool_ranges=random_pool_ranges(pool.n_servers) if randomize
        else None))
    print(f"action space: {', '.join(env.action_space.names)}")
    demo_active = None         # representative membership for the baselines
    if env.dynamic:
        print(f"dynamic fleet: join intensity {churn_rate}, "
              f"leave prob {leave_rate}/frame")
        # short random rollout to show membership actually churns
        s = env.reset(jax.random.PRNGKey(7))
        trace = []
        demo_active = np.asarray(s.active)
        for t in range(24):
            n = env.params.n_ue
            acts = {"split": jnp.full((n,), env.n_actions_b - 1, jnp.int32),
                    "channel": jnp.zeros((n,), jnp.int32),
                    "power": jnp.full((n,), 0.05)}
            if env.multi_server:
                acts["route"] = jnp.zeros((n,), jnp.int32)
            s, _, done, info = env.step(s, acts)
            if bool(done):
                break               # post-done state is the auto-reset fleet
            trace.append("".join("#" if a else "." for a in
                                 np.asarray(s.active)))
            if np.asarray(s.active).any():
                demo_active = np.asarray(s.active)  # last non-empty snapshot
        print("  membership (one column per UE, # active / . standby):")
        for t, row in enumerate(trace):
            if t % 4 == 0:
                print(f"    frame {t:2d}: {row}")
    mode = "entity-set actor, per-server route scorer" if entity_policy \
        else "weight-shared actor" if shared_policy else "per-UE actors"
    extra = " over randomized pool geometries" if randomize else ""
    print(f"\ntraining MAHPPO ({mode}) on the mixed fleet{extra} "
          f"({iterations} iterations)...")
    if fused_scorer:
        print("  fused pair-scorer kernel path (observe_entities_raw)")
    if n_shards > 1:
        print(f"  rollouts sharded over {n_shards} devices "
              f"({len(jax.devices())} visible)")
    cfg = MAHPPOConfig(iterations=iterations, horizon=512, n_envs=4,
                       reuse=4, shared_policy=shared_policy,
                       entity_policy=entity_policy,
                       randomize_pool=randomize,
                       fused_scorer=fused_scorer, n_shards=n_shards)
    agent, hist = train_mahppo(env, cfg, seed=0,
                               log_cb=lambda r: print(
                                   f"  iter {r['iteration']:3d} "
                                   f"reward={r['reward_mean']:.4f}")
                               if r["iteration"] % 5 == 0 else None)
    ev = evaluate_policy(env, agent, frames=64)
    # score greedy on a comparable fleet: the traced membership snapshot,
    # so both columns describe a churned fleet, not all-N vs active-only
    gr = greedy_eval(env, active=demo_active)
    beta = float(env.params.beta)
    if env.dynamic:
        print(f"\nmean fleet size over eval: {ev['n_active']:.2f} "
              f"of {env.params.n_ue} UEs"
              + ("" if demo_active is None else
                 f"; greedy scored on {int(demo_active.sum())} active UEs"))
    print(f"\nMAHPPO : latency {1e3*ev['t_task']:.1f} ms  "
          f"energy {1e3*ev['e_task']:.1f} mJ  "
          f"overhead {ev['t_task'] + beta*ev['e_task']:.4f}")
    print(f"greedy : latency {1e3*gr['t_task']:.1f} ms  "
          f"energy {1e3*gr['e_task']:.1f} mJ  "
          f"overhead {gr['overhead']:.4f}  (per-UE b={gr['b']}"
          + (f", route={gr['route']}" if "route" in gr else "") + ")")
    if env.multi_server:
        from repro.rl.baselines import load_aware_eval, nearest_server_eval
        near = nearest_server_eval(env, active=demo_active)
        load = load_aware_eval(env, active=demo_active)
        print(f"nearest: overhead {near['overhead']:.4f}  "
              f"(route={near['route']})")
        print(f"loadbal: overhead {load['overhead']:.4f}  "
              f"(route={load['route']})")

    if (shared_policy or entity_policy) and n_ue <= 16:
        # (skipped at giant N: instantiating N per-UE actors just for the
        # comparison means N obs_dim-sized orthogonal inits)
        from repro.rl.mahppo import init_agent
        n_pol = nets.param_count(agent.get("actor")
                                 or agent["entity_actor"])
        n_per_ue = nets.param_count(
            init_agent(jax.random.PRNGKey(0), env)["actors"])
        kind = "entity" if entity_policy else "shared"
        print(f"\nactor parameters: {n_pol} {kind} (O(1) in fleet size"
              + (" AND pool size" if entity_policy else "")
              + f") vs {n_per_ue} for per-UE actors at N="
              f"{env.params.n_ue}")

    # learned per-UE decisions at the eval state
    from repro.rl.mahppo import _policy_all
    space = env.action_space
    s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
    masks = env.action_masks()
    if entity_policy:
        dist = nets.entity_actor_forward(
            agent["entity_actor"], space, env.observe_entities(s),
            space.broadcast_masks(masks, env.params.n_ue))
    elif shared_policy:
        dist = nets.shared_actor_forward(
            agent["actor"], space, env.observe_per_ue(s),
            space.broadcast_masks(masks, env.params.n_ue))
    else:
        dist = _policy_all(agent["actors"], space, env.observe(s), masks)
    a_star = jax.vmap(space.mode)(dist, masks)
    for i, b in enumerate(np.asarray(a_star["split"])):
        kind = ("raw offload" if b == 0 else
                "full local" if b == env.n_actions_b - 1 else f"split b={b}")
        where = f" -> srv{int(a_star['route'][i])}" \
            if env.multi_server and b != env.n_actions_b - 1 else ""
        print(f"  ue{i} ({fleet.names[i]}): {kind}{where}")
    if env.multi_server:
        counts = np.bincount(np.asarray(a_star["route"]),
                             minlength=env.n_servers)
        print(f"  learned route distribution: "
              + ", ".join(f"srv{e}={int(c)}" for e, c in enumerate(counts)))
    if llm:
        b_llm = np.asarray(a_star["split"])[-len(LLM_CTX_RUNGS):]
        local = env.n_actions_b - 1
        offl = b_llm[:-1][b_llm[:-1] != local]
        shift = offl.size > 0 and (b_llm[-1] == local
                                   or b_llm[-1] > offl.min())
        print(f"  context-length shift (short rungs offload, "
              f"ctx{LLM_CTX_RUNGS[-1]} stays local/later): "
              f"{'YES' if shift else 'not yet at this budget'}")

    # entity policies transfer across pool SIZE: drop the identical
    # parameters onto an E+1-server pool, zero-shot
    if entity_policy and env.multi_server and n_servers < 3 and not llm:
        from repro.rl.baselines import nearest_server_eval
        env_big = MECEnv(make_env_params(
            fleet, n_channels=2, pool=make_edge_pool(n_servers + 1)))
        ev_big = evaluate_policy(env_big, agent, frames=64)
        near_big = nearest_server_eval(env_big)
        ovh_big = ev_big["t_task"] + beta * ev_big["e_task"]
        print(f"\nzero-shot on an UNSEEN {n_servers + 1}-server pool "
              f"(route head is E-free): entity overhead {ovh_big:.4f} vs "
              f"nearest-server {near_big['overhead']:.4f} "
              f"[{'BEATS' if ovh_big <= near_big['overhead'] else 'LOSES'}]")

    if distill:
        # train big, serve small: the entity teacher generalizes across
        # fleets/pools; the deployment serves ONE pool, where a distilled
        # flat trunk prices a dispatch in microseconds
        import time

        from repro.rl.distill import (DistillConfig, distill_entity_policy,
                                      quantize_flat_trunk)
        env_d = env if not randomize else MECEnv(make_env_params(
            fleet, n_channels=2, t0=t0, pool=pool))   # the STATIC pool
        print("\ndistilling into the serve-small flat trunk "
              "(rl.distill; fixed fleet, fixed pool)...")
        student, _ = distill_entity_policy(
            env_d, agent, DistillConfig(iterations=2, frames=48, epochs=120),
            seed=1, log_cb=lambda r: print(
                f"  round {r['iteration']}: dataset {r['states']} states  "
                f"loss {r['loss']:.4f}  mode agreement {r['agreement']:.2f}"))
        qstudent = quantize_flat_trunk(student)
        n_t, n_s = (nets.param_count(agent["entity_actor"]),
                    nets.param_count(student))
        print(f"  teacher {n_t} params "
              f"({nets.param_bytes(agent['entity_actor']) / 1e3:.1f} kB) -> "
              f"student {n_s} ({100 * n_s / n_t:.1f}%); int8 serving "
              f"weights {nets.param_bytes(qstudent) / 1e3:.1f} kB vs "
              f"f32 {nets.param_bytes(student) / 1e3:.1f} kB")
        ev_t = evaluate_policy(env_d, agent, frames=64)
        ev_q = evaluate_policy(env_d, {"flat_trunk": qstudent}, frames=64)
        ovh_t = ev_t["t_task"] + beta * ev_t["e_task"]
        ovh_q = ev_q["t_task"] + beta * ev_q["e_task"]
        print(f"  int8 student overhead {ovh_q:.4f} vs teacher {ovh_t:.4f} "
              f"(ratio {ovh_q / ovh_t:.2f})")

        # the closing readout: one batch-1 policy forward — the per-task
        # cost the dispatcher pays on the streaming hot path (the full
        # batch sweep lives in benchmarks/bench_policy_latency.py)
        space_d = env_d.action_space
        s0 = env_d.reset(jax.random.PRNGKey(0), eval_mode=True)
        masks_d = space_d.broadcast_masks(env_d.action_masks(),
                                          env_d.params.n_ue)
        rows = env_d.observe_per_ue(s0)
        ents = env_d.observe_entities(s0)
        cells = (
            ("entity teacher", jax.jit(lambda: nets.entity_actor_forward(
                agent["entity_actor"], space_d, ents, masks_d))),
            ("distilled f32", jax.jit(lambda: nets.flat_trunk_forward(
                student, space_d, rows, masks_d))),
            ("distilled int8", jax.jit(lambda: nets.flat_trunk_forward(
                qstudent, space_d, rows, masks_d))),
        )

        def best_us(fn, k=20):
            jax.block_until_ready(fn())             # compile + warm
            best = float("inf")
            for _ in range(k):
                t1 = time.perf_counter()
                jax.block_until_ready(fn())
                best = min(best, time.perf_counter() - t1)
            return best * 1e6

        print("  batch-1 dispatch forward (best of 20):")
        for name, fn in cells:
            print(f"    {name:14s}: {best_us(fn):8.1f} us")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b",
                    choices=[a for a in ARCH_IDS])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--ratio", type=int, default=4)
    ap.add_argument("--fleet", action="store_true",
                    help="schedule a heterogeneous 4-UE fleet instead of "
                         "running the single-UE split forward")
    ap.add_argument("--churn", action="store_true",
                    help="make the --fleet scenario dynamic: UEs join/leave "
                         "mid-episode (implies --fleet; also implied by "
                         "passing --churn-rate/--leave-rate)")
    ap.add_argument("--churn-rate", type=float, default=None,
                    help="Poisson join intensity per standby slot per frame "
                         "(default 0.2 when churning; implies --churn)")
    ap.add_argument("--leave-rate", type=float, default=None,
                    help="per-frame departure probability of an active UE "
                         "(default 0.1 when churning; implies --churn)")
    ap.add_argument("--servers", type=int, default=1, metavar="E",
                    help="size of the edge pool (E > 1 adds a learned "
                         "`route` action head; implies --fleet)")
    ap.add_argument("--shared-policy", action="store_true",
                    help="train ONE weight-shared actor over per-UE "
                         "feature rows instead of per-UE actors — O(1) "
                         "parameters in the fleet size, transfers "
                         "zero-shot across fleets (implies --fleet)")
    ap.add_argument("--entity-policy", action="store_true",
                    help="train the entity-set policy: structured "
                         "{ue, server, edge} observations through a "
                         "shared per-server route scorer, with the pool "
                         "geometry resampled every episode — transfers "
                         "zero-shot across pool layouts AND sizes "
                         "(implies --fleet; defaults --servers to 2)")
    ap.add_argument("--n-ue", type=int, default=4, metavar="N",
                    help="fleet size: cycles the 4-UE device mix to N "
                         "UEs (the entity policy stays O(1) params in N "
                         "— try 256; implies --fleet)")
    ap.add_argument("--fused-scorer", action="store_true",
                    help="route the entity pair scorer through the fused "
                         "kernel path (kernels.ops.pair_scorer; implies "
                         "--entity-policy) — same logits, no (N, E, .) "
                         "intermediates, the giant-fleet hot path")
    ap.add_argument("--llm", action="store_true",
                    help="schedule the mixed CNN + LLM-decode fleet (one "
                         "UE per context rung; KV cache rides the "
                         "boundary payload) on the bench_llm_offload "
                         "pool — implies --entity-policy")
    ap.add_argument("--distill", action="store_true",
                    help="after training, distill the entity teacher into "
                         "the serve-small flat trunk (rl.distill), int8-"
                         "quantize it for the fused dequant-matmul kernel, "
                         "and close with a batch-1 dispatch-latency "
                         "readout (implies --entity-policy; needs a "
                         "static fleet, so excludes --churn)")
    ap.add_argument("--n-shards", type=int, default=1, metavar="K",
                    help="shard rollout collection over K devices (on "
                         "CPU set XLA_FLAGS=--xla_force_host_platform_"
                         "device_count=K before launch; implies --fleet)")
    ap.add_argument("--iterations", type=int, default=15)
    args = ap.parse_args()

    if args.entity_policy and args.shared_policy:
        ap.error("pick one of --entity-policy / --shared-policy")
    if args.fused_scorer and args.shared_policy:
        ap.error("--fused-scorer fuses the entity route scorer; it "
                 "cannot combine with --shared-policy")
    if args.fused_scorer:
        args.entity_policy = True
    if args.llm:
        args.entity_policy = True   # the scenario is about routing
    if args.distill:
        args.entity_policy = True   # distillation needs an entity teacher
    if args.entity_policy and args.servers < 2:
        args.servers = 2       # the route scorer needs a pool to score
    churn = (args.churn or args.churn_rate is not None
             or args.leave_rate is not None)
    if args.distill and churn:
        ap.error("--distill targets a fixed deployment fleet; it cannot "
                 "combine with --churn")
    if args.fleet or churn or args.servers > 1 or args.shared_policy \
            or args.entity_policy or args.n_ue != 4 or args.n_shards > 1 \
            or args.llm:
        run_fleet_demo(
            args.arch, args.iterations,
            churn_rate=(0.2 if args.churn_rate is None
                        else args.churn_rate) if churn else 0.0,
            leave_rate=(0.1 if args.leave_rate is None
                        else args.leave_rate) if churn else 0.0,
            n_servers=args.servers, shared_policy=args.shared_policy,
            entity_policy=args.entity_policy, n_ue=args.n_ue,
            fused_scorer=args.fused_scorer, n_shards=args.n_shards,
            llm=args.llm, distill=args.distill)
        return

    cfg = reduced(get_config(args.arch), n_layers=4)
    if len(cfg.block_pattern) != 1:
        cfg = cfg.replace(block_pattern=("dense",))
    params = init_params(cfg, jax.random.PRNGKey(0))

    # The paper assumes a PRE-TRAINED backbone (feature anisotropy is what
    # the AE exploits) — pre-train briefly on the synthetic corpus.
    from repro.data.synthetic import TokenPipelineConfig, token_batch_stream
    from repro.launch.steps import make_train_step
    print("pre-training backbone (150 steps)...")
    train_step, opt_init = make_train_step(cfg, base_lr=3e-3, warmup=20,
                                           total=150)
    opt = opt_init(params)
    stream = token_batch_stream(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch=16))
    sfn = jax.jit(train_step)
    for i in range(150):
        params, opt, m = sfn(params, opt, next(stream))
    print(f"  final train loss {float(m['loss']):.3f}")
    tokens = next(stream)["tokens"][: args.batch]

    ref_logits, _, _ = apply_model(params, cfg, tokens, mode="train")
    ref_top1 = jnp.argmax(ref_logits, -1)

    d = cfg.d_model
    split = cfg.n_layers // 2

    # Fit the optimal LINEAR autoencoder in closed form (PCA of the boundary
    # features on a calibration batch) — the train-free analogue of the
    # paper's stage-1 L2 objective for a 1x1-conv AE.
    calib = jax.random.randint(jax.random.PRNGKey(9), (8, args.seq), 0,
                               cfg.vocab_size)
    positions = jnp.broadcast_to(jnp.arange(args.seq, dtype=jnp.int32),
                                 (8, args.seq))
    from repro.models.blocks import apply_block
    xc = jnp.take(params["embed"], calib, axis=0)
    blocks = params["decoder"]["blocks"][0]
    for i in range(split):
        p_i = jax.tree_util.tree_map(lambda a: a[i], blocks)
        xc, _, _ = apply_block(p_i, xc, cfg, cfg.block_pattern[0],
                               positions=positions, mode="train")
    feats = xc.reshape(-1, d).astype(jnp.float32)
    mu = feats.mean(0)
    _, _, vt = jnp.linalg.svd(feats - mu, full_matrices=False)
    pcs = vt[: d // args.ratio].T                       # (d, d')
    ae = {"enc": pcs, "dec": pcs.T}
    logits, payload_bits = run_split_forward(params, cfg, tokens, split, ae)
    agree = float(jnp.mean((jnp.argmax(logits, -1) == ref_top1)))

    # simulated channel: single UE, 50 m, 0.3 W
    g = channel_gain(jnp.array([50.0]))
    r = uplink_rates(jnp.array([0.3]), jnp.array([0]), g, jnp.array([True]),
                     omega=jnp.array([1e6]), sigma=jnp.array([1e-9]))
    t_tx = payload_bits / float(r[0])
    raw_bits = tokens.size * 32

    print(f"arch={args.arch} (reduced {cfg.n_layers}L d={cfg.d_model}), "
          f"split after layer {split}")
    print(f"boundary payload: {payload_bits/1e3:.1f} kbit "
          f"(hidden f32 would be {tokens.size*d*32/1e3:.0f} kbit, "
          f"rate R={tokens.size*d*32/payload_bits:.0f}x)")
    print(f"uplink {float(r[0])/1e6:.1f} Mb/s -> tx {1e3*t_tx:.1f} ms")
    print(f"top-1 agreement with uncompressed forward: {100*agree:.1f}% "
          f"(PCA linear AE, ratio {args.ratio}x + int8)")
    print(f"raw-input offload would be {raw_bits/1e3:.1f} kbit")


if __name__ == "__main__":
    main()
