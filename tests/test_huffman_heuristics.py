"""Huffman codec round-trip + size-estimator validation; heuristic
scheduler baselines (greedy, static oracle)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# only the property test needs hypothesis; the codec-size and heuristic
# tests below must still run where it isn't installed
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.core.compressor import quantize
from repro.core.huffman import build_code, coded_size_bits, decode, encode
from repro.core.jalad import byte_entropy_bits

if given is not None:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(2, 12))
    def test_huffman_roundtrip(seed, sharpness):
        x = np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                         (32, 32))) ** sharpness
        codes, _, _ = quantize(jnp.asarray(x), 8)
        sym = np.asarray(codes).reshape(-1)
        stream, table, n = encode(sym)
        back = decode(stream, table, n)
        assert (back == sym).all()


def test_huffman_size_close_to_entropy_estimate():
    """JALAD's information-theoretic size estimate is within 2% of the real
    Huffman coded size (validates core/jalad.py)."""
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(0), (64, 64))) ** 3
    codes, _, _ = quantize(jnp.asarray(x), 8)
    sym = np.asarray(codes).reshape(-1)
    actual = coded_size_bits(sym)
    est = float(byte_entropy_bits(jnp.asarray(sym), 8)) * sym.size
    assert abs(actual - est) / est < 0.02


def test_huffman_empty_input():
    """n = 0 round-trips through every codec entry point: empty code
    table, empty stream, empty decode, zero coded size — and decoding a
    nonempty count against an empty table is an error, not a hang."""
    empty = np.empty(0, np.int64)
    assert build_code(empty) == {}
    stream, table, n = encode(empty)
    assert (stream, table, n) == (b"", {}, 0)
    back = decode(stream, table, n)
    assert back.size == 0
    assert coded_size_bits(empty) == 0
    with pytest.raises(ValueError):
        decode(b"", {}, 3)


def test_huffman_beats_raw_on_peaky_data():
    x = np.zeros((64, 64))
    x[0, 0] = 1.0  # extremely peaky -> Huffman hits its 1-bit/symbol floor
    codes, _, _ = quantize(jnp.asarray(x), 8)
    sym = np.asarray(codes).reshape(-1)
    coded = coded_size_bits(sym)
    assert coded <= sym.size + len(np.unique(sym))  # ~1 bit/symbol
    assert coded < sym.size * 8 * 0.15


@pytest.fixture(scope="module")
def env3():
    from repro.core.cnn import make_resnet18
    from repro.core.split import cnn_split_table
    from repro.env.mecenv import MECEnv, make_env_params
    plan = cnn_split_table(make_resnet18(101), 224)
    return MECEnv(make_env_params(plan, n_ue=3, n_channels=2))


def test_oracle_beats_greedy_and_local(env3):
    from repro.rl.heuristics import greedy_eval, oracle_static_eval
    g = greedy_eval(env3)
    o = oracle_static_eval(env3)
    beta = float(env3.params.beta)
    local = (float(env3.params.l_new[0, -1])
             + beta * float(env3.params.l_new[0, -1])
             * float(env3.params.p_compute[0]))
    assert o["overhead"] <= g["overhead"] + 1e-9
    assert o["overhead"] < local
    # oracle staggers: not all UEs make the same offload decision
    assert len(set(o["b"])) > 1 or len(set(o["c"])) > 1


def test_heuristic_expected_overhead_realized_for_long_tasks():
    """The heuristics' Eq. 7/8 expected-overhead math must agree with the
    simulator in the LONG-task regime (t_task >> t0): driving the env
    with greedy's own static actions realizes greedy's predicted per-task
    latency as completion throughput. Pre-PR-7 the simulator discarded
    unfinished carry-over work at every frame boundary, so any plan with
    t_task > 2*t0 completed nothing and this agreement was impossible."""
    from repro.core.cnn import make_resnet18
    from repro.core.split import cnn_split_table
    from repro.env.mecenv import MECEnv, make_env_params
    from repro.rl.heuristics import greedy_eval
    plan = cnn_split_table(make_resnet18(101), 224)
    # t0=5ms: every feasible split needs several frames per task
    env = MECEnv(make_env_params(plan, n_ue=2, n_channels=2, t0=0.005))
    g = greedy_eval(env)
    assert g["t_task"] > 2 * float(env.params.t0)
    acts = {"split": jnp.asarray(g["b"], jnp.int32),
            "channel": jnp.asarray([0, 1], jnp.int32),   # greedy's RR
            "power": jnp.full((2,), float(env.params.p_max))}
    s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
    s = s._replace(d=jnp.full((2,), 50.0))               # greedy's d
    frames, completed = 400, 0.0
    step = jax.jit(env.step)
    for _ in range(frames):
        s, _, done, info = step(s, acts)
        completed += float(info["completed"])
        assert not bool(done)           # eval queues outlast the horizon
    realized_t = 2 * frames * float(env.params.t0) / completed
    assert realized_t == pytest.approx(g["t_task"], rel=0.05)


@pytest.mark.slow
def test_mahppo_approaches_static_oracle(env3):
    """The RL agent should reach (or beat — it is state-dependent) the
    neighborhood of the exhaustive static-oracle overhead."""
    from repro.rl.heuristics import oracle_static_eval
    from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo
    o = oracle_static_eval(env3)
    cfg = MAHPPOConfig(iterations=80, horizon=1024, n_envs=8, reuse=8)
    agent, _ = train_mahppo(env3, cfg, seed=0)
    ev = evaluate_policy(env3, agent, frames=64)
    beta = float(env3.params.beta)
    rl_ovh = ev["t_task"] + beta * ev["e_task"]
    assert rl_ovh < 1.35 * o["overhead"], (rl_ovh, o["overhead"])
