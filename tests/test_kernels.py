"""Per-kernel shape/dtype sweeps, allclose vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [(17, 130), (256, 512), (3, 5, 384)])
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_ref(shape, bits, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 3).astype(dtype)
    q1 = ops.quantize(x, -9.0, 9.0, bits=bits)
    q2 = ref.quantize_ref(x, -9.0, 9.0, bits=bits)
    # rounding of values exactly at .5 boundaries may differ by 1 code in
    # low-precision dtypes; require exactness in f32
    if dtype == jnp.float32:
        assert jnp.all(q1 == q2)
    else:
        assert jnp.max(jnp.abs(q1.astype(jnp.int32) - q2.astype(jnp.int32))) <= 1


@pytest.mark.parametrize("bits", [4, 8])
def test_dequantize_matches_ref(bits):
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 257)) * 2
    q = ref.quantize_ref(x, -7.0, 7.0, bits=bits)
    d1 = ops.dequantize(q, -7.0, 7.0, bits=bits)
    d2 = ref.dequantize_ref(q, -7.0, 7.0, bits=bits)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-5, atol=1e-5)


def test_quant_roundtrip_error_bound():
    """Round-off error is bounded by half a quantization step (Eq. 1-2)."""
    x = jax.random.uniform(jax.random.PRNGKey(2), (128, 256),
                           minval=-5.0, maxval=5.0)
    for bits in (4, 8):
        q = ops.quantize(x, -5.0, 5.0, bits=bits)
        d = ops.dequantize(q, -5.0, 5.0, bits=bits)
        step = 10.0 / ((1 << bits) - 1)
        assert float(jnp.max(jnp.abs(d - x))) <= step / 2 + 1e-5


@pytest.mark.parametrize("t,d,dp", [(64, 128, 32), (513, 384, 96),
                                    (100, 260, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bottleneck_encode(t, d, dp, dtype):
    x = jax.random.normal(jax.random.PRNGKey(3), (t, d), dtype)
    w = (jax.random.normal(jax.random.PRNGKey(4), (d, dp)) * 0.05).astype(dtype)
    b1 = ops.bottleneck_encode(x, w, -4.0, 4.0)
    b2 = ref.bottleneck_encode_ref(x, w, -4.0, 4.0)
    diff = jnp.abs(b1.astype(jnp.int32) - b2.astype(jnp.int32))
    assert int(diff.max()) <= 1  # .5-boundary rounding tolerance


@pytest.mark.parametrize("s", [64, 257, 1024])
@pytest.mark.parametrize("hkv,g", [(2, 4), (1, 8), (4, 1)])
def test_decode_attention(s, hkv, g):
    key = jax.random.PRNGKey(5)
    b, d = 2, 64
    q = jax.random.normal(key, (b, hkv * g, d))
    k = jax.random.normal(jax.random.PRNGKey(6), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(7), (b, s, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    pos = jnp.where(pos % 5 == 2, -1, pos)
    idx = s - 10
    o1 = ops.decode_attention(q, k, v, pos, idx)
    o2 = ref.decode_attention_ref(q, k, v, pos, idx)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_model_flash():
    """Kernel oracle agrees with the model's chunked flash attention."""
    from repro.models.attention import flash_attention
    b, s, hkv, g, d = 2, 128, 2, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(8), (b, 1, hkv * g, d))
    k = jax.random.normal(jax.random.PRNGKey(9), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(10), (b, s, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    idx = s - 1
    o_flash = flash_attention(
        q, k, v, q_positions=jnp.full((b, 1), idx),
        k_positions=pos, causal=True, chunk=64)
    o_ref = ref.decode_attention_ref(q[:, 0], k, v, pos, idx)
    np.testing.assert_allclose(np.asarray(o_flash[:, 0]), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)
