"""Per-kernel shape/dtype sweeps, allclose vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("shape", [(17, 130), (256, 512), (3, 5, 384)])
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantize_matches_ref(shape, bits, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), shape) * 3).astype(dtype)
    q1 = ops.quantize(x, -9.0, 9.0, bits=bits)
    q2 = ref.quantize_ref(x, -9.0, 9.0, bits=bits)
    # rounding of values exactly at .5 boundaries may differ by 1 code in
    # low-precision dtypes; require exactness in f32
    if dtype == jnp.float32:
        assert jnp.all(q1 == q2)
    else:
        assert jnp.max(jnp.abs(q1.astype(jnp.int32) - q2.astype(jnp.int32))) <= 1


@pytest.mark.parametrize("bits", [4, 8])
def test_dequantize_matches_ref(bits):
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 257)) * 2
    q = ref.quantize_ref(x, -7.0, 7.0, bits=bits)
    d1 = ops.dequantize(q, -7.0, 7.0, bits=bits)
    d2 = ref.dequantize_ref(q, -7.0, 7.0, bits=bits)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2),
                               rtol=1e-5, atol=1e-5)


def test_quant_roundtrip_error_bound():
    """Round-off error is bounded by half a quantization step (Eq. 1-2)."""
    x = jax.random.uniform(jax.random.PRNGKey(2), (128, 256),
                           minval=-5.0, maxval=5.0)
    for bits in (4, 8):
        q = ops.quantize(x, -5.0, 5.0, bits=bits)
        d = ops.dequantize(q, -5.0, 5.0, bits=bits)
        step = 10.0 / ((1 << bits) - 1)
        assert float(jnp.max(jnp.abs(d - x))) <= step / 2 + 1e-5


@pytest.mark.parametrize("t,d,dp", [(64, 128, 32), (513, 384, 96),
                                    (100, 260, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bottleneck_encode(t, d, dp, dtype):
    x = jax.random.normal(jax.random.PRNGKey(3), (t, d), dtype)
    w = (jax.random.normal(jax.random.PRNGKey(4), (d, dp)) * 0.05).astype(dtype)
    b1 = ops.bottleneck_encode(x, w, -4.0, 4.0)
    b2 = ref.bottleneck_encode_ref(x, w, -4.0, 4.0)
    diff = jnp.abs(b1.astype(jnp.int32) - b2.astype(jnp.int32))
    assert int(diff.max()) <= 1  # .5-boundary rounding tolerance


@pytest.mark.parametrize("s", [64, 257, 1024])
@pytest.mark.parametrize("hkv,g", [(2, 4), (1, 8), (4, 1)])
def test_decode_attention(s, hkv, g):
    key = jax.random.PRNGKey(5)
    b, d = 2, 64
    q = jax.random.normal(key, (b, hkv * g, d))
    k = jax.random.normal(jax.random.PRNGKey(6), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(7), (b, s, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    pos = jnp.where(pos % 5 == 2, -1, pos)
    idx = s - 10
    o1 = ops.decode_attention(q, k, v, pos, idx)
    o2 = ref.decode_attention_ref(q, k, v, pos, idx)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_model_flash():
    """Kernel oracle agrees with the model's chunked flash attention."""
    from repro.models.attention import flash_attention
    b, s, hkv, g, d = 2, 128, 2, 2, 32
    q = jax.random.normal(jax.random.PRNGKey(8), (b, 1, hkv * g, d))
    k = jax.random.normal(jax.random.PRNGKey(9), (b, s, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(10), (b, s, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    idx = s - 1
    o_flash = flash_attention(
        q, k, v, q_positions=jnp.full((b, 1), idx),
        k_positions=pos, causal=True, chunk=64)
    o_ref = ref.decode_attention_ref(q[:, 0], k, v, pos, idx)
    np.testing.assert_allclose(np.asarray(o_flash[:, 0]), np.asarray(o_ref),
                               rtol=2e-5, atol=2e-5)


# ------------------------------------------------ fused pair scorer (PR 6)
# route-scorer fusion: edge-feature build + occupancy reduction + server
# embed + decomposed pair MLP in one op, raced against the naive oracle
# that mirrors the default entity path op-for-op.

def _pair_scorer_inputs(key, n, e, dtype=jnp.float32):
    """Live-env-magnitude inputs at fleet size n, pool size e."""
    ks = jax.random.split(key, 8)
    ue_emb = jnp.tanh(jax.random.normal(ks[0], (n, 128))).astype(dtype)
    raw = {
        "d": jax.random.uniform(ks[1], (n,), minval=1.0,
                                maxval=100.0).astype(dtype),
        "work": jax.random.uniform(ks[2], (n,), minval=5e7,
                                   maxval=5e8).astype(dtype),
        "active": (jax.random.uniform(ks[3], (n,)) < 0.7).astype(dtype),
        "geom": jax.random.uniform(ks[4], (e, 3), minval=0.5,
                                   maxval=2.0).astype(dtype),
        "consts": jnp.asarray([3.0, 0.5, 1e-9, 0.1, 0.5, e * 2.0,
                               100.0, 1e-12], dtype),
    }
    srv_enc = {"w": jax.random.normal(ks[5], (4, 32)) * 0.5,
               "b": jnp.zeros((32,))}
    scorer = [{"w": jax.random.normal(ks[6], (163, 48)) * 0.1,
               "b": jnp.zeros((48,))},
              {"w": jax.random.normal(ks[7], (48, 1)) * 0.01,
               "b": jnp.zeros((1,))}]
    return ue_emb, raw, srv_enc, scorer


def _pair_ref(ue_emb, raw, srv_enc, scorer):
    return ref.pair_scorer_ref(
        ue_emb, raw["d"], raw["work"], raw["active"], raw["geom"],
        raw["consts"], srv_enc["w"], srv_enc["b"], scorer[0]["w"],
        scorer[0]["b"], scorer[1]["w"], scorer[1]["b"])


@pytest.mark.parametrize("n,e", [(1, 1), (7, 2), (64, 3), (300, 5)])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_pair_scorer_matches_ref(n, e, impl):
    """Fused scorer == naive oracle over an N/E grid. N=300 exercises the
    ragged final Pallas block (grid block 256)."""
    args = _pair_scorer_inputs(jax.random.PRNGKey(n * 7 + e), n, e)
    lf, sf = ops.pair_scorer(*args, impl=impl, interpret=True)
    lr, sr = _pair_ref(*args)
    assert lf.shape == (n, e) and sf.shape == sr.shape
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sr),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_pair_scorer_dtype_grid(dtype, impl):
    """Lower-precision observation blocks go through the same f32 kernel
    accumulation: parity vs the oracle fed the identical rounded inputs."""
    args = _pair_scorer_inputs(jax.random.PRNGKey(11), 33, 3, dtype=dtype)
    lf, _ = ops.pair_scorer(*args, impl=impl, interpret=True)
    lr, _ = _pair_ref(*args)
    assert lf.dtype == jnp.float32
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(lf), np.asarray(lr),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_pair_scorer_masked_inactive_under_churn(impl):
    """Churn semantics: inactive UEs still get scored rows (the env pins
    them to full-local via feasibility masks, not by dropping rows), and
    the active mask enters ONLY through the per-(server, channel)
    occupancy scalar — so a departure changes every logit through that
    one reduction and nothing else."""
    n, e = 24, 3
    ue_emb, raw, srv_enc, scorer = _pair_scorer_inputs(
        jax.random.PRNGKey(3), n, e)
    for frac in (0.0, 0.5, 1.0):     # empty / half / full fleet
        r = dict(raw, active=(jnp.arange(n) < frac * n).astype(jnp.float32))
        lf, sf = ops.pair_scorer(ue_emb, r, srv_enc, scorer,
                                 impl=impl, interpret=True)
        lr, sr = _pair_ref(ue_emb, r, srv_enc, scorer)
        np.testing.assert_allclose(np.asarray(lf), np.asarray(lr),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(sf), np.asarray(sr),
                                   rtol=1e-5, atol=1e-5)
    # two churn states differing ONLY in the mask: occupancy is the sole
    # coupling, so equal occupancy => bitwise-equal logits
    a1 = jnp.zeros((n,)).at[0].set(1.0)
    a2 = jnp.zeros((n,)).at[n - 1].set(1.0)
    l1, _ = ops.pair_scorer(ue_emb, dict(raw, active=a1), srv_enc, scorer,
                            impl=impl, interpret=True)
    l2, _ = ops.pair_scorer(ue_emb, dict(raw, active=a2), srv_enc, scorer,
                            impl=impl, interpret=True)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_pair_scorer_unknown_impl_raises():
    args = _pair_scorer_inputs(jax.random.PRNGKey(0), 4, 2)
    with pytest.raises(ValueError, match="impl"):
        ops.pair_scorer(*args, impl="cuda")


# --------------------------------------------- quant impl routing (PR 10)
# quantize/dequantize grew the same dual-impl REPRO_*_IMPL convention as
# pair_scorer: decomposed XLA off-TPU, the Pallas kernel on TPU, env-var
# override. The two impls share the exact elementwise math, so codes must
# be BITWISE equal, not merely close.

@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_impls_bitwise_equal(bits):
    x = jax.random.normal(jax.random.PRNGKey(12), (37, 130)) * 4
    qx = ops.quantize(x, -9.0, 9.0, bits=bits, impl="xla")
    qp = ops.quantize(x, -9.0, 9.0, bits=bits, impl="pallas",
                      interpret=True)
    np.testing.assert_array_equal(np.asarray(qx), np.asarray(qp))
    dx = ops.dequantize(qx, -9.0, 9.0, bits=bits, impl="xla")
    dp = ops.dequantize(qx, -9.0, 9.0, bits=bits, impl="pallas",
                        interpret=True)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dp),
                               rtol=1e-6, atol=1e-6)


def test_quant_impl_env_var(monkeypatch):
    """REPRO_QUANT_IMPL selects the path; an unknown value is an error,
    not a silent fallback. An explicit ``interpret=`` implies Pallas (the
    pre-routing call signature keeps its meaning)."""
    x = jax.random.normal(jax.random.PRNGKey(13), (8, 64))
    monkeypatch.setenv("REPRO_QUANT_IMPL", "xla")
    q_env = ops.quantize(x, -4.0, 4.0)
    np.testing.assert_array_equal(
        np.asarray(q_env), np.asarray(ops.quantize(x, -4.0, 4.0, impl="xla")))
    monkeypatch.setenv("REPRO_QUANT_IMPL", "metal")
    with pytest.raises(ValueError, match="impl"):
        ops.quantize(x, -4.0, 4.0)
    with pytest.raises(ValueError, match="impl"):
        ops.dequantize(q_env, -4.0, 4.0)
    # explicit interpret routes to Pallas regardless of the env var
    q_int = ops.quantize(x, -4.0, 4.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(q_env), np.asarray(q_int))


# ------------------------------------------ fused int8 flat trunk (PR 10)
# serve-small dispatch kernel: dequantize every layer's int8 weight codes
# in-register and run the whole tanh MLP in one fused pass, raced against
# the dequantize-then-matmul oracle.

def _trunk_layers(key, dims=(19, 64, 64, 13), bits=8):
    """Random quantized trunk: per-layer min-max int8 codes + f32 biases
    (the ``rl.distill.quantize_flat_trunk`` layout) and the dequantized
    f32 weights the oracle path sees."""
    qlayers = []
    for i, (d_in, d_out) in enumerate(zip(dims, dims[1:])):
        kw, kb, key = jax.random.split(key, 3)
        w = jax.random.normal(kw, (d_in, d_out)) * 0.4
        mn, mx = float(w.min()), float(w.max())
        qlayers.append({"codes": ref.quantize_ref(w, mn, mx, bits=bits),
                        "mn": jnp.float32(mn), "mx": jnp.float32(mx),
                        "b": jax.random.normal(kb, (d_out,)) * 0.1})
    return qlayers


def _trunk_ref(x, qlayers, bits=8):
    return ref.flat_trunk_ref(
        x, tuple(l["codes"] for l in qlayers),
        tuple(l["mn"] for l in qlayers), tuple(l["mx"] for l in qlayers),
        tuple(l["b"] for l in qlayers), bits=bits)


@pytest.mark.parametrize("shape", [(1, 19), (7, 19), (4, 8, 19), (600, 19)])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_flat_trunk_matches_ref(shape, impl):
    """Fused trunk == naive oracle over batch shapes: batch 1 (the
    dispatch hot path), leading-dim flattening, and 600 rows exercising
    the ragged final Pallas block (block_n 512)."""
    qlayers = _trunk_layers(jax.random.PRNGKey(sum(shape)))
    x = jax.random.normal(jax.random.PRNGKey(1), shape)
    out = ops.flat_trunk(x, qlayers, impl=impl, interpret=True)
    exp = _trunk_ref(x.reshape(-1, shape[-1]), qlayers)
    assert out.shape == shape[:-1] + (13,)
    np.testing.assert_allclose(np.asarray(out).reshape(-1, 13),
                               np.asarray(exp), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_flat_trunk_dtype_grid(dtype, impl):
    """bf16 feature rows accumulate in f32 inside both impls: parity vs
    the oracle fed the identical rounded inputs, f32 head columns out."""
    qlayers = _trunk_layers(jax.random.PRNGKey(2), bits=8)
    x = (jax.random.normal(jax.random.PRNGKey(3), (33, 19)) * 2).astype(dtype)
    out = ops.flat_trunk(x, qlayers, impl=impl, interpret=True)
    exp = _trunk_ref(x, qlayers)
    assert out.dtype == jnp.float32
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=tol, atol=tol)


def test_flat_trunk_impl_env_var(monkeypatch):
    qlayers = _trunk_layers(jax.random.PRNGKey(4))
    x = jax.random.normal(jax.random.PRNGKey(5), (6, 19))
    monkeypatch.setenv("REPRO_FLAT_TRUNK_IMPL", "xla")
    np.testing.assert_allclose(
        np.asarray(ops.flat_trunk(x, qlayers)),
        np.asarray(ops.flat_trunk(x, qlayers, impl="xla")),
        rtol=0, atol=0)
    monkeypatch.setenv("REPRO_FLAT_TRUNK_IMPL", "cuda")
    with pytest.raises(ValueError, match="impl"):
        ops.flat_trunk(x, qlayers)
