"""Multi-server edge pool: per-server channels/interference, routed
action space, edge service times (processor sharing), and the routing
heuristics/baselines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import golden_cases as gc
from repro.core import overhead as oh
from repro.core.cnn import make_resnet18
from repro.core.fleets import EdgePool, make_edge_pool, single_server
from repro.core.split import cnn_split_table
from repro.env.channel import channel_gain, uplink_rates
from repro.env.mecenv import MECEnv, make_env_params


def _pool_env(n_ue=4, pool=None, **kw):
    plan = cnn_split_table(make_resnet18(101), 224)
    return MECEnv(make_env_params(plan, n_ue=n_ue, n_channels=2,
                                  pool=pool or make_edge_pool(2), **kw))


def test_pool_construction():
    assert single_server().is_single_paper_server
    assert make_edge_pool(2).n_servers == 2
    assert not make_edge_pool(2).is_single_paper_server
    with pytest.raises(ValueError):
        EdgePool(())
    with pytest.raises(ValueError, match="duplicate"):
        EdgePool((oh.ServerProfile("a"), oh.ServerProfile("a")))


def test_env_exposes_route_head():
    env = _pool_env()
    assert env.multi_server and env.n_servers == 2
    assert env.action_space.names == ("split", "channel", "route", "power")
    assert env.action_space.head("route").n == 2
    assert env.params.omega.shape == (2, 2)
    assert env.params.t_edge.shape == (4, env.n_actions_b, 2)
    # paper-default single server keeps the legacy 3-head space
    env1 = _pool_env(pool=single_server())
    assert not env1.multi_server
    assert env1.action_space.names == ("split", "channel", "power")


def test_routed_trajectory_matches_golden():
    """40 random-action frames — route draws included — on the 4-UE
    2-server pool env reproduce the goldens.json capture (PR-7
    exact-carry recapture) byte-for-byte: reward stream, final state,
    PRNG key, and membership mask. Pins the routed interference, edge
    processor-sharing, and carry threading through the pool path."""
    got = gc.trajectory_golden("pool2_homo4")
    assert got == gc.load_goldens()["trajectories"]["pool2_homo4"]


def test_interference_isolated_per_server():
    """Same channel id on different servers must not interfere: routing a
    rival to the other server restores the lone-UE rate."""
    g = channel_gain(jnp.array([50.0, 50.0]))
    omega = jnp.full((2, 2), 1e6)
    sigma = jnp.full((2, 2), 1e-9)
    p = jnp.array([0.3, 0.3])
    c = jnp.array([0, 0])
    tx = jnp.array([True, True])
    r_shared = uplink_rates(p, c, g, tx, omega=omega, sigma=sigma,
                            route=jnp.array([0, 0]))
    r_split = uplink_rates(p, c, g, tx, omega=omega, sigma=sigma,
                           route=jnp.array([0, 1]))
    assert float(r_split[0]) > float(r_shared[0])
    # 1-D omega/sigma with no route is numerically the (E=1) 2-D case
    r_flat = uplink_rates(p, c, g, tx, omega=omega[0], sigma=sigma[0])
    r_e1 = uplink_rates(p, c, g, tx, omega=omega[:1], sigma=sigma[:1],
                        route=jnp.array([0, 0]))
    np.testing.assert_array_equal(np.asarray(r_flat), np.asarray(r_e1))


def test_step_rewards_spreading_load():
    """With deep queues, splitting the fleet across servers completes more
    tasks per frame than piling everyone onto the near server."""
    env = _pool_env()
    s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
    n = env.params.n_ue
    base = {"split": jnp.full((n,), 1, jnp.int32),
            "channel": jnp.asarray([0, 1, 0, 1], jnp.int32),
            "power": jnp.full((n,), 0.3)}
    _, r_pile, _, i_pile = env.step(
        s, dict(base, route=jnp.zeros((n,), jnp.int32)))
    _, r_bal, _, i_bal = env.step(
        s, dict(base, route=jnp.asarray([0, 0, 1, 1], jnp.int32)))
    assert float(i_bal["completed"]) > float(i_pile["completed"])
    assert float(r_bal) > float(r_pile)
    np.testing.assert_allclose(np.asarray(i_bal["server_load"]), [2.0, 2.0])


def test_edge_service_processor_sharing():
    """A busier server serves each task slower: same routing but more
    co-offloaders inflates t_task via the shared edge_speed."""
    pool = EdgePool((oh.ServerProfile("slow", oh.EDGE_NUC, 1.0, 1.0,
                                      edge_speed=2.0e11),
                     oh.ServerProfile.from_device(oh.EDGE_GPU,
                                                  dist_scale=1.2)))
    env = _pool_env(pool=pool)
    s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
    n = env.params.n_ue
    b = jnp.full((n,), 0, jnp.int32)        # raw offload: all edge work
    a_lone = {"split": b, "channel": jnp.asarray([0, 1, 0, 1], jnp.int32),
              "power": jnp.full((n,), 0.3),
              "route": jnp.asarray([0, 1, 1, 1], jnp.int32)}
    a_crowd = dict(a_lone, route=jnp.zeros((n,), jnp.int32))
    t_lone, _ = env.task_overhead(s, a_lone)
    t_crowd, _ = env.task_overhead(s, a_crowd)
    # UE0 offloads to "slow" in both cases, but shares it with 3 others in
    # the crowded assignment: its per-task edge seconds scale ~4x
    assert float(t_crowd[0]) > float(t_lone[0])
    te = np.asarray(env.params.t_edge)
    assert np.all(te >= 0.0)
    # full-local and infeasible (padded) slots never pay edge time
    assert np.all(te[:, -1, :] == 0.0)
    feas = np.asarray(env.params.feasible)
    assert np.all(te[~feas] == 0.0)


def test_padded_slot_inert_with_edge_pool():
    """t_edge must not resurrect padded actions: a forced padded action
    still completes nothing (t_task would be pure edge time otherwise)."""
    from repro.configs import get_config
    from repro.core.split import build_fleet, transformer_split_table
    cnn = cnn_split_table(make_resnet18(101), 224)
    tf_small = transformer_split_table(get_config("qwen3-1.7b"),
                                       ue_dev=oh.PHONE_NPU, n_points=2)
    fleet = build_fleet([cnn, tf_small], [oh.JETSON_NANO, oh.PHONE_NPU])
    pool = EdgePool((oh.ServerProfile.from_device(oh.TPU_V5E),
                     oh.ServerProfile.from_device(oh.EDGE_GPU,
                                                  dist_scale=1.3)))
    env = MECEnv(make_env_params(fleet, n_channels=2, pool=pool))
    s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
    b = jnp.asarray([1, 4], jnp.int32)      # ue1 forced onto a padded slot
    assert not bool(env.params.feasible[1, 4])
    k_before = float(s.k[1])
    s2, _, _, info = env.step(s, {"split": b,
                                  "channel": jnp.zeros((2,), jnp.int32),
                                  "route": jnp.zeros((2,), jnp.int32),
                                  "power": jnp.full((2,), 0.3)})
    assert float(s2.k[1]) == k_before       # no phantom completions


def test_routing_heuristics_ordering():
    """nearest-server == pile-up greedy here (the demo pool's near server
    dominates every clean-channel comparison), and load-aware routing
    beats both once interference is priced in; the routed oracle is best."""
    from repro.rl.baselines import load_aware_eval, nearest_server_eval
    from repro.rl.heuristics import greedy_eval, oracle_static_eval
    env = _pool_env(n_ue=3)
    gr = greedy_eval(env)
    near = nearest_server_eval(env)
    load = load_aware_eval(env)
    assert gr["route"] == near["route"] == [0, 0, 0]
    assert load["overhead"] < near["overhead"]
    orc = oracle_static_eval(env, max_joint=500_000)
    assert len(set(orc["route"])) > 1       # the oracle spreads the fleet
    assert orc["overhead"] <= load["overhead"] + 1e-9
    assert orc["overhead"] <= gr["overhead"] + 1e-9


def test_mahppo_iteration_on_pool_env():
    """One jitted MAHPPO iteration trains through the 4-head action space
    (and composes with churn) without any per-head plumbing."""
    from repro.optim import adamw_init
    from repro.rl.mahppo import MAHPPOConfig, init_agent, make_train_fns
    for kw in ({}, {"churn_rate": 0.3, "leave_rate": 0.2}):
        env = _pool_env(**kw)
        cfg = MAHPPOConfig(iterations=1, horizon=64, n_envs=2, reuse=1,
                           batch=32)
        key = jax.random.PRNGKey(0)
        agent = init_agent(key, env)
        assert "route" in agent["actors"]["heads"]
        opt = adamw_init(agent)
        states = jax.vmap(env.reset)(jax.random.split(key, cfg.n_envs))
        iteration = make_train_fns(env, cfg)
        agent, opt, key, states, metrics = iteration(agent, opt, key, states)
        assert np.isfinite(float(metrics["reward_mean"]))
