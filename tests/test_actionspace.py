"""HybridActionSpace unit tests: mask-respecting sampling, agreement of
the generic sample/log_prob/entropy/init with the pre-redesign hard-coded
(b, c, p) implementation (reproduced inline below), and bound handling on
continuous heads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.rl import nets
from repro.rl.actionspace import (ContinuousHead, DiscreteHead,
                                  HybridActionSpace)


def _space(n_b=7, n_c=2, p_max=0.5):
    return HybridActionSpace(
        (DiscreteHead("split", n_b), DiscreteHead("channel", n_c)),
        (ContinuousHead("power", 1e-4, p_max),))


# ---- the PRE-redesign hybrid implementation, verbatim (2 discrete heads
# + 1 Gaussian), as the reference the generic path must reproduce
def _legacy_sample(key, lb, lc, mu, log_std, mask=None):
    if mask is not None:
        lb = jnp.where(mask, lb, -1e9)
    kb, kc, kp = jax.random.split(key, 3)
    b = jax.random.categorical(kb, lb)
    c = jax.random.categorical(kc, lc)
    u = mu + jnp.exp(log_std) * jax.random.normal(kp, mu.shape)
    return b, c, u


def _legacy_log_prob(lb, lc, mu, log_std, b, c, u):
    var = jnp.exp(2 * log_std)
    lp = -0.5 * ((u - mu) ** 2 / var + 2 * log_std + jnp.log(2 * jnp.pi))
    return jax.nn.log_softmax(lb)[..., b] + jax.nn.log_softmax(lc)[..., c] \
        + lp


def _legacy_entropy(lb, lc, log_std):
    pb, pc = jax.nn.softmax(lb), jax.nn.softmax(lc)
    hb = -jnp.sum(pb * jnp.log(pb + 1e-12), axis=-1)
    hc = -jnp.sum(pc * jnp.log(pc + 1e-12), axis=-1)
    return hb + hc + 0.5 * jnp.log(2 * jnp.pi * jnp.e) + log_std


def _rand_dist(key, space):
    ks = jax.random.split(key, 4)
    return {"split": jax.random.normal(ks[0], (space.head("split").n,)),
            "channel": jax.random.normal(ks[1], (space.head("channel").n,)),
            "power": {"mu": jax.random.normal(ks[2], ()),
                      "log_std": jnp.clip(jax.random.normal(ks[3], ()),
                                          -3.0, 1.0)}}


def test_sample_matches_legacy_bitwise():
    """Same keys, same draws: the generic sampler consumes the PRNG in
    head-declaration order, exactly like the old kb/kc/kp split."""
    space = _space()
    mask = jnp.array([True, True, False, True, True, False, True])
    for seed in range(50):
        dist = _rand_dist(jax.random.PRNGKey(1000 + seed), space)
        key = jax.random.PRNGKey(seed)
        b0, c0, u0 = _legacy_sample(key, dist["split"], dist["channel"],
                                    dist["power"]["mu"],
                                    dist["power"]["log_std"], mask)
        a = space.sample(key, dist, {"split": mask})
        assert int(a["split"]) == int(b0)
        assert int(a["channel"]) == int(c0)
        assert np.asarray(a["power"]).tobytes() == np.asarray(u0).tobytes()
        assert bool(mask[int(a["split"])])          # never an invalid draw


def test_log_prob_entropy_match_legacy():
    space = _space()
    for seed in range(20):
        dist = _rand_dist(jax.random.PRNGKey(seed), space)
        a = space.sample(jax.random.PRNGKey(seed + 99), dist)
        lp = space.log_prob(dist, a)
        lp_ref = _legacy_log_prob(dist["split"], dist["channel"],
                                  dist["power"]["mu"],
                                  dist["power"]["log_std"],
                                  a["split"], a["channel"], a["power"])
        np.testing.assert_allclose(np.asarray(lp), np.asarray(lp_ref),
                                   rtol=1e-6)
        en = space.entropy(dist)
        en_ref = _legacy_entropy(dist["split"], dist["channel"],
                                 dist["power"]["log_std"])
        np.testing.assert_allclose(np.asarray(en), np.asarray(en_ref),
                                   rtol=1e-6)


def test_active_weight_zeroes_contribution():
    space = _space()
    dist = _rand_dist(jax.random.PRNGKey(0), space)
    a = space.sample(jax.random.PRNGKey(1), dist)
    assert float(space.log_prob(dist, a, active=0.0)) == 0.0
    assert float(space.entropy(dist, active=0.0)) == 0.0
    np.testing.assert_allclose(
        float(space.log_prob(dist, a, active=1.0)),
        float(space.log_prob(dist, a)))


def test_extra_head_changes_nothing_for_others():
    """Adding a head (the multi-server `route`) only appends its own
    factor: per-head log-prob terms of the shared heads are unchanged."""
    space2 = _space()
    space3 = HybridActionSpace(
        space2.discrete + (DiscreteHead("route", 3),), space2.continuous)
    dist = _rand_dist(jax.random.PRNGKey(0), space2)
    dist3 = dict(dist, route=jnp.array([0.3, -0.2, 0.1]))
    a = space2.sample(jax.random.PRNGKey(5), dist)
    a3 = dict(a, route=jnp.asarray(1))
    delta = float(space3.log_prob(dist3, a3)) - float(space2.log_prob(dist, a))
    np.testing.assert_allclose(
        delta, float(jax.nn.log_softmax(dist3["route"])[1]), rtol=1e-6)
    dh = float(space3.entropy(dist3)) - float(space2.entropy(dist))
    p = jax.nn.softmax(dist3["route"])
    np.testing.assert_allclose(dh, float(-(p * jnp.log(p + 1e-12)).sum()),
                               rtol=1e-5)


def test_mode_respects_mask():
    space = _space()
    dist = _rand_dist(jax.random.PRNGKey(3), space)
    # make the globally-best split infeasible: mode must avoid it
    best = int(jnp.argmax(dist["split"]))
    mask = jnp.ones((space.head("split").n,), bool).at[best].set(False)
    a = space.mode(dist, {"split": mask})
    assert int(a["split"]) != best and bool(mask[int(a["split"])])
    assert float(a["power"]) == float(dist["power"]["mu"])


def test_init_heads_shapes_and_forward():
    space = _space(n_b=6, n_c=3)
    actor = nets.init_actor(jax.random.PRNGKey(0), 10, space)
    assert set(actor["heads"]) == {"split", "channel", "power"}
    assert actor["heads"]["split"][-1]["b"].shape == (6,)
    assert actor["heads"]["channel"][-1]["b"].shape == (3,)
    assert actor["heads"]["power"][-1]["b"].shape == (2,)
    obs = jax.random.normal(jax.random.PRNGKey(1), (10,))
    dist = nets.actor_forward(actor, space, obs)
    assert dist["split"].shape == (6,)
    assert dist["power"]["mu"].shape == ()
    assert -3.0 <= float(dist["power"]["log_std"]) <= 1.0


def test_space_validation():
    with pytest.raises(ValueError, match="duplicate"):
        HybridActionSpace((DiscreteHead("a", 2), DiscreteHead("a", 3)), ())
    with pytest.raises(ValueError, match="non-discrete"):
        HybridActionSpace((DiscreteHead("a", 2),),
                          (ContinuousHead("p", 0.0, 1.0),),
                          masks={"p": jnp.ones((1, 2), bool)})
    sp = _space()
    with pytest.raises(KeyError):
        sp.head("nope")
