"""Sharded rollouts (PR 6): config validation always, live shard_map
paths whenever the host exposes >= 2 devices.

The multi-device tests skip on a 1-device host; CI runs this file a
second time under XLA_FLAGS=--xla_force_host_platform_device_count=2
(set BEFORE importing jax) to exercise them on CPU. The single-device
`n_shards=1` path is covered by the rest of the suite — it traces the
exact pre-sharding graph, which is what the PR-3/4/5 goldens pin.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import overhead as oh
from repro.core.cnn import make_resnet18
from repro.core.fleets import make_edge_pool
from repro.core.split import cnn_split_table
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl.mahppo import (MAHPPOConfig, _env_mesh, evaluate_policy,
                             init_agent, train_mahppo)

multi_device = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs >= 2 devices (set XLA_FLAGS="
           "--xla_force_host_platform_device_count=2 before jax import)")


@pytest.fixture(scope="module")
def pool_env():
    plan = cnn_split_table(make_resnet18(101), 224)
    return MECEnv(make_env_params(plan, n_ue=3, n_channels=2,
                                  pool=make_edge_pool(2)))


def test_n_shards_config_validation():
    with pytest.raises(ValueError, match="n_shards"):
        MAHPPOConfig(n_shards=0)
    with pytest.raises(ValueError, match="divisible"):
        MAHPPOConfig(horizon=64, n_envs=4, n_shards=3)
    assert MAHPPOConfig(horizon=64, n_envs=4, n_shards=2).n_shards == 2


def test_env_mesh_raises_with_actionable_hint():
    n = len(jax.devices()) + 1
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        _env_mesh(n)


def test_eval_shard_count_must_divide_envs(pool_env):
    agent = init_agent(jax.random.PRNGKey(0), pool_env,
                       entity_policy=True)
    with pytest.raises(ValueError, match="divisible"):
        evaluate_policy(pool_env, agent, frames=2, n_envs=3, n_shards=2)


@multi_device
def test_sharded_eval_matches_unsharded(pool_env):
    """Each eval episode depends only on its own key, so shard_mapping
    the vmapped batch over 2 devices must reproduce the unsharded
    batched numbers exactly."""
    agent = init_agent(jax.random.PRNGKey(0), pool_env,
                       entity_policy=True)
    r1 = evaluate_policy(pool_env, agent, frames=8, n_envs=4, n_shards=1)
    r2 = evaluate_policy(pool_env, agent, frames=8, n_envs=4, n_shards=2)
    for k in ("reward", "t_task", "e_task", "completed"):
        assert r1[k] == r2[k], (k, r1[k], r2[k])


@multi_device
@pytest.mark.parametrize("fused", [False, True])
def test_sharded_training_iteration_runs(pool_env, fused):
    """One jitted sharded iteration end-to-end (entity policy, with and
    without the fused scorer): finite metrics, and the fused/unfused
    sharded runs see the SAME env trajectories (the scorer fusion is a
    pure reparametrization of the same math)."""
    cfg = MAHPPOConfig(iterations=2, horizon=32, n_envs=4, n_shards=2,
                       reuse=1, batch=16, entity_policy=True,
                       fused_scorer=fused)
    agent, hist = train_mahppo(pool_env, cfg, seed=0)
    assert len(hist) == 2
    for h in hist:
        assert np.isfinite(float(h["reward_mean"]))
        assert np.isfinite(float(h["actor_loss"]))


@multi_device
def test_sharded_training_decorrelates_env_streams(pool_env):
    """Shards fold their mesh index into the rollout key: a 2-shard run
    must not collapse to two copies of the same env stream. Train one
    iteration and check the collected reward is finite and the agent
    moved (params differ from init)."""
    cfg = MAHPPOConfig(iterations=1, horizon=32, n_envs=4, n_shards=2,
                       reuse=1, batch=16, entity_policy=True)
    key = jax.random.PRNGKey(0)
    init = init_agent(key, pool_env, entity_policy=True)
    agent, _ = train_mahppo(pool_env, cfg, seed=0)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), init, agent)
    assert max(jax.tree_util.tree_leaves(diffs)) > 0.0
