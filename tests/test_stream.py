"""Event-driven streaming runtime properties (repro.stream).

Mirrors tests/test_churn_properties.py's two layers:
 * seeded tests that always run, and
 * hypothesis-driven variants over arbitrary (seed, rate, deadline)
   scenarios when hypothesis is installed.

The core invariants:
 1. event-ledger conservation AFTER EVERY EVENT:
        arrivals == completed + dropped + queued + in_flight
    and at drain: queued == in_flight == 0.
 2. closed-form agreement: a single uncontended task's stream service
    time/energy equals ``env.task_overhead``'s Eq. 7/8 closed form to
    1e-6 relative — the frame env, the heuristics, and the stream sim
    all flow through ``core.overhead.task_latency_energy``.
 3. determinism: reports and per-task records are pure functions of the
    seed (heap sim AND virtual-clock asyncio daemon), and the daemon
    reproduces the heap simulator exactly.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.core.fleets import make_edge_pool, make_mixed_fleet
from repro.env.mecenv import EnvState, MECEnv, make_env_params
from repro.rl.heuristics import _joint_overhead
from repro.rl.mahppo import init_agent
from repro.stream.adapter import (EntityDispatcher, GreedyDispatcher,
                                  LocalDispatcher, NearestServerDispatcher,
                                  stream_env_state)
from repro.stream.dispatcher import run_daemon
from repro.stream.events import StreamCore, StreamParams, StreamSim
from repro.stream.qos import (StreamRewardConfig, TaskRecord, stream_reward,
                              tail_stats)


def _pool_env(n_ue=6, n_servers=2):
    return MECEnv(make_env_params(make_mixed_fleet(n_ue=n_ue),
                                  n_channels=2,
                                  pool=make_edge_pool(n_servers)))


def _single_env(n_ue=4):
    return MECEnv(make_env_params(make_mixed_fleet(n_ue=n_ue),
                                  n_channels=2))


# ------------------------------------------------------------- tail stats
def test_tail_stats_values():
    s = tail_stats(np.arange(1, 101, dtype=float))
    assert s["p50"] == pytest.approx(50.5)
    assert s["p95"] == pytest.approx(95.05)
    assert s["p99"] == pytest.approx(99.01)
    empty = tail_stats([])
    assert all(np.isnan(v) for v in empty.values())


def test_tail_stats_shared_with_benchmarks():
    """benchmarks/_timing re-exports THE stream.qos definition."""
    import sys
    sys.path.insert(0, ".")
    try:
        from benchmarks import _timing
    finally:
        sys.path.pop(0)
    assert _timing.tail_stats is tail_stats


# ------------------------------------------- closed-form agreement (Eq. 7/8)
def _lone_task_agreement(env, b, c, e, p, ue=0):
    """Start ONE task with no contention in the stream; its frozen service
    time/energy must equal env.task_overhead's closed form when only that
    UE offloads."""
    core = StreamCore(env, StreamParams(), seed=0)
    task = TaskRecord(tid=0, ue=ue, cls=0, t_arrive=0.0, deadline=1e9)
    core.arrivals += 1
    core.queues[ue].append(task)
    t_svc = core.start(core.next_task(ue),
                       {"split": b, "channel": c, "route": e, "power": p})
    n = env.params.n_ue
    b_local = env.n_actions_b - 1
    split = np.full((n,), b_local, np.int32)
    split[ue] = b
    acts = {"split": jnp.asarray(split),
            "channel": jnp.full((n,), c, jnp.int32),
            "power": jnp.full((n,), p, jnp.float32)}
    if env.multi_server:
        acts["route"] = jnp.full((n,), e, jnp.int32)
    s = EnvState(k=jnp.ones((n,)), l=jnp.zeros((n,)), n=jnp.zeros((n,)),
                 d=jnp.asarray(core.d, jnp.float32),
                 t=jnp.zeros((), jnp.int32), key=jax.random.PRNGKey(0),
                 active=jnp.ones((n,), bool))
    t_env, e_env = env.task_overhead(s, acts)
    assert t_svc == pytest.approx(float(t_env[ue]), rel=1e-6)
    assert task.energy == pytest.approx(float(e_env[ue]), rel=1e-6)
    return s, acts


def test_closed_form_agreement_multi_server():
    env = _pool_env()
    for b, c, e in [(0, 0, 0), (2, 1, 1), (1, 0, 1)]:
        _lone_task_agreement(env, b, c, e, float(env.params.p_max))


def test_closed_form_agreement_single_server():
    env = _single_env()
    _lone_task_agreement(env, 1, 1, 0, float(env.params.p_max))


def test_three_callers_cannot_drift():
    """env.task_overhead and heuristics._joint_overhead share the helper:
    identical inputs -> identical Eq. 7/8 outputs (the stream sim is tied
    to the same helper by the lone-task tests above)."""
    env = _pool_env()
    n = env.params.n_ue
    rng = np.random.RandomState(3)
    b = rng.randint(0, env.n_actions_b, n)
    c = rng.randint(0, env.n_channels, n)
    e = rng.randint(0, env.n_servers, n)
    p = np.full((n,), float(env.params.p_max))
    d = np.full((n,), 50.0)
    s = EnvState(k=jnp.ones((n,)), l=jnp.zeros((n,)), n=jnp.zeros((n,)),
                 d=jnp.asarray(d, jnp.float32), t=jnp.zeros((), jnp.int32),
                 key=jax.random.PRNGKey(0), active=jnp.ones((n,), bool))
    acts = {"split": jnp.asarray(b, jnp.int32),
            "channel": jnp.asarray(c, jnp.int32),
            "route": jnp.asarray(e, jnp.int32),
            "power": jnp.asarray(p, jnp.float32)}
    t_env, e_env = env.task_overhead(s, acts)
    t_h, e_h = _joint_overhead(env, b, c, p, d, route=e)
    np.testing.assert_allclose(np.asarray(t_env), t_h, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(e_env), e_h, rtol=1e-6)


# ---------------------------------------------------- ledger conservation
def _ledger_run(env, dispatch, sp, seed, check_every=True):
    sim = StreamSim(env, dispatch, sp, seed=seed)
    while True:
        led = sim.ledger()
        assert led["arrivals"] == led["completed"] + led["dropped"] \
            + led["queued"] + led["in_flight"], led
        if not sim.step():
            break
    led = sim.ledger()
    assert led["queued"] == 0 and led["in_flight"] == 0
    assert led["arrivals"] == led["completed"] + led["dropped"]
    rep = sim.report()
    assert rep["tasks"] == led["arrivals"]
    assert 0.0 <= rep["miss_rate"] <= 1.0
    return sim


def test_stream_ledger_seeded():
    env = _pool_env()
    for seed in (0, 7, 123):
        _ledger_run(env, GreedyDispatcher(env),
                    StreamParams(rate=6.0, horizon=3.0), seed)


def test_stream_ledger_single_server():
    env = _single_env()
    _ledger_run(env, GreedyDispatcher(env),
                StreamParams(rate=5.0, horizon=3.0), seed=1)


def test_saturation_drops_and_misses():
    """Tight deadlines at heavy load: tasks ARE dropped, drops have
    well-formed records, and every record is terminal exactly once."""
    env = _pool_env()
    sp = StreamParams(rate=20.0, horizon=3.0,
                      classes=((1.0, 0.05),))
    sim = _ledger_run(env, LocalDispatcher(env), sp, seed=0)
    rep = sim.report()
    assert rep["dropped"] > 0
    assert rep["miss_rate"] > 0.5
    tids = [r.tid for r in sim.monitor.records]
    assert len(tids) == len(set(tids)) == sim.arrivals
    for r in sim.monitor.records:
        assert r.dropped == (r.b == -1)      # dropped tasks never served
        assert r.t_done >= r.t_arrive


# ------------------------------------------------------------ determinism
def test_stream_determinism():
    env = _pool_env()
    sp = StreamParams(rate=6.0, horizon=3.0)

    def records(seed):
        sim = StreamSim(env, GreedyDispatcher(env), sp, seed=seed)
        sim.run()
        return sorted((r.tid, r.ue, r.t_arrive, r.t_done, r.dropped)
                      for r in sim.monitor.records)

    assert records(3) == records(3)
    assert records(3) != records(4)


def test_deterministic_arrivals_mode():
    env = _pool_env(n_ue=4)
    sp = StreamParams(rate=5.0, horizon=2.0, deterministic=True)
    sim = _ledger_run(env, GreedyDispatcher(env), sp, seed=0)
    gaps = sorted(r.t_arrive for r in sim.monitor.records if r.ue == 0)
    diffs = np.diff(gaps)
    assert np.allclose(diffs, 1.0 / sp.rate)


# --------------------------------------------------------- state adapter
def test_snapshot_counts_queue_and_in_flight():
    env = _pool_env()
    sp = StreamParams(rate=10.0, horizon=2.0)
    sim = StreamSim(env, GreedyDispatcher(env), sp, seed=2)
    checked = 0
    while sim.step():
        s = stream_env_state(sim)
        k = np.asarray(s.k)
        for u in range(env.params.n_ue):
            expect = len(sim.queues[u]) + (sim.serving[u] is not None)
            assert k[u] == expect
        assert np.all(np.asarray(s.l) >= 0)
        assert np.all(np.asarray(s.n) >= 0)
        # a UE with no in-service task has no in-flight remainder
        idle = np.asarray([sim.serving[u] is None
                           for u in range(env.params.n_ue)])
        assert np.all(np.asarray(s.l)[idle] == 0)
        assert np.all(np.asarray(s.n)[idle] == 0)
        checked += 1
        if checked >= 40:
            break


def test_entity_dispatcher_zero_shot():
    """An (untrained) entity agent dispatches a stream end to end: masked
    feasible splits only, ledger balanced, report well-formed."""
    env = _pool_env(n_ue=4)
    agent = init_agent(jax.random.PRNGKey(0), env, entity_policy=True)
    sim = _ledger_run(env, EntityDispatcher(env, agent),
                      StreamParams(rate=4.0, horizon=2.0), seed=0)
    feas = np.asarray(env.params.feasible)
    for r in sim.monitor.records:
        if not r.dropped:
            assert feas[r.ue, r.b], (r.ue, r.b)
            assert 0 <= r.server < env.n_servers
            lo = env.action_space.head("power").low
            hi = env.action_space.head("power").high
            assert lo <= r.power <= hi


def test_entity_dispatcher_live_channel():
    """The deployment mode (sampled + least-loaded channel override)
    still emits in-range channels and keeps the ledger balanced."""
    env = _pool_env(n_ue=4)
    agent = init_agent(jax.random.PRNGKey(0), env, entity_policy=True)
    sim = _ledger_run(env, EntityDispatcher(env, agent, deterministic=False,
                                            live_channel=True, seed=3),
                      StreamParams(rate=4.0, horizon=2.0), seed=0)
    served = [r for r in sim.monitor.records if not r.dropped]
    assert served
    for r in served:
        assert 0 <= r.channel < env.n_channels


def test_entity_dispatcher_requires_entity_agent():
    env = _pool_env(n_ue=4)
    shared = init_agent(jax.random.PRNGKey(0), env, shared_policy=True)
    with pytest.raises(ValueError):
        EntityDispatcher(env, shared)


def test_oracle_dispatcher():
    """The occupancy-aware oracle serves a balanced ledger, emits only
    feasible actions, and its candidate sweep leaves the core's live
    occupancy state exactly as it found it (it commits candidates
    in-place to price them under ``core.start`` semantics)."""
    from repro.stream.adapter import StreamOracleDispatcher
    env = _pool_env(n_ue=4)
    oracle = StreamOracleDispatcher(env)
    inner = StreamOracleDispatcher(env)
    snaps = []

    def spy(core, ue):
        before = (core.tx.copy(), core.chan.copy(), core.route.copy(),
                  core.power.copy())
        act = inner(core, ue)
        after = (core.tx, core.chan, core.route, core.power)
        snaps.append(all(np.array_equal(b, np.asarray(a))
                         for b, a in zip(before, after)))
        return act

    sim = _ledger_run(env, spy, StreamParams(rate=6.0, horizon=2.0), seed=1)
    assert snaps and all(snaps)
    feas = np.asarray(env.params.feasible)
    lo = env.action_space.head("power").low
    for r in sim.monitor.records:
        if not r.dropped:
            assert feas[r.ue, r.b]
            assert 0 <= r.server < env.n_servers
            assert lo <= r.power <= float(env.params.p_max)
    assert oracle.p_grid[-1] <= float(env.params.p_max)


# --------------------------------------------------------- asyncio daemon
def test_daemon_matches_heap_sim():
    """The virtual-clock asyncio daemon drives the same StreamCore as the
    event heap: identical per-task records for both a state-independent
    (local) and an interference-coupled (greedy) dispatcher."""
    env = _pool_env()
    sp = StreamParams(rate=4.0, horizon=2.5)
    for mk in (LocalDispatcher, GreedyDispatcher):
        sim = StreamSim(env, mk(env), sp, seed=3)
        rep_sim = sim.run()
        rep_d, core = run_daemon(env, mk(env), sp, seed=3)
        key = lambda recs: sorted((r.tid, r.ue, r.t_arrive, r.t_start,
                                   r.t_done, r.dropped, r.b, r.server)
                                  for r in recs)
        assert key(sim.monitor.records) == key(core.monitor.records)
        assert rep_sim == rep_d


def test_daemon_deterministic():
    env = _pool_env(n_ue=4)
    sp = StreamParams(rate=6.0, horizon=2.0)
    r1, c1 = run_daemon(env, NearestServerDispatcher(env), sp, seed=5)
    r2, c2 = run_daemon(env, NearestServerDispatcher(env), sp, seed=5)
    assert r1 == r2
    assert [(t.tid, t.t_done) for t in c1.monitor.records] \
        == [(t.tid, t.t_done) for t in c2.monitor.records]
    r3, _ = run_daemon(env, NearestServerDispatcher(env), sp, seed=6)
    assert r1 != r3


# ------------------------------------------------------- streaming reward
def test_stream_reward_orders_outcomes():
    good = {"miss_rate": 0.0, "sojourn_p99": 0.1, "energy_task": 0.05}
    bad = {"miss_rate": 0.5, "sojourn_p99": 2.0, "energy_task": 0.05}
    cfg = StreamRewardConfig()
    assert stream_reward(good, cfg) > stream_reward(bad, cfg)
    # fully dropped stream (NaN tails) still scores finitely
    allnan = {"miss_rate": 1.0, "sojourn_p99": float("nan"),
              "energy_task": float("nan")}
    assert np.isfinite(stream_reward(allnan, cfg))


@pytest.mark.slow
def test_finetune_streaming_smoke():
    from repro.rl.streaming import StreamTuneConfig, finetune_streaming
    env = _pool_env(n_ue=4)
    agent = init_agent(jax.random.PRNGKey(0), env, entity_policy=True)
    sp = StreamParams(rate=3.0, horizon=1.5)
    tuned, hist = finetune_streaming(
        env, agent, sp, StreamTuneConfig(iterations=2, episodes_per_iter=2),
        seed=0)
    assert len(hist) == 2
    assert all(np.isfinite(h["reward_mean"]) for h in hist)
    # every iteration's distillation update must move the in-loop actor
    # (the RETURNED actor is the best-scoring candidate and may
    # legitimately be the zero-shot weights at smoke scale)
    assert all(h["actor_delta"] > 0 for h in hist)
    l2 = jax.tree.leaves(tuned["entity_actor"])
    assert not any(np.isnan(np.asarray(x)).any() for x in l2)
    # critic rides along untouched
    same = jax.tree.map(lambda a, b: bool((np.asarray(a)
                                           == np.asarray(b)).all()),
                        agent["critic"], tuned["critic"])
    assert all(jax.tree.leaves(same))


# ------------------------------------------------- hypothesis properties
if given is not None:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.floats(0.5, 15.0),
           st.floats(0.05, 1.5), st.booleans())
    def test_stream_ledger_property(seed, rate, deadline, deterministic):
        """Ledger conservation for ARBITRARY load, deadline tightness and
        arrival process (every arrival ends exactly one of completed /
        dropped / queued / in-flight, drained to zero)."""
        env = _ledger_property_env()
        sp = StreamParams(rate=rate, horizon=2.0,
                          classes=((0.5, deadline), (0.5, 2 * deadline)),
                          deterministic=deterministic)
        _ledger_run(env, GreedyDispatcher(env), sp, seed)

    _LEDGER_ENV = []

    def _ledger_property_env():
        if not _LEDGER_ENV:
            _LEDGER_ENV.append(_pool_env(n_ue=4))
        return _LEDGER_ENV[0]
