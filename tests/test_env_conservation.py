"""Work and task conservation across frame boundaries.

Two ledger families:

* task conservation — across an episode, completed tasks exactly exhaust
  the initial queues (no task lost or double-counted), for arbitrary
  policies;
* work conservation (the PR-7 exact-carry fix) — an in-flight task's
  remaining work `(l, n)` is monotone non-increasing across frames and
  never resets while `k` is unchanged, under churn and per-frame
  split/channel/power/route changes, and a task spanning ≥3 frames
  completes at exactly its Eq. 7/8 closed-form latency and energy. The
  only non-conserved quantity is the explicit TX_EPS_BITS transmit floor,
  reported per-frame in ``info["eps_bits"]`` and bounded here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# the hypothesis-driven ledgers skip cleanly where it isn't installed;
# the closed-form and fixed-seed carry tests below run everywhere
try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    def _skip_deco(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    given = settings = _skip_deco

    class st:                             # placeholder so strategies parse
        integers = staticmethod(lambda *a, **k: None)
        booleans = staticmethod(lambda *a, **k: None)

from repro.core.cnn import make_resnet18
from repro.core.fleets import make_edge_pool
from repro.core.split import build_fleet, cnn_split_table
from repro.env.channel import channel_gain, uplink_rates
from repro.env.mecenv import TX_EPS_BITS, MECEnv, make_env_params


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_completed_tasks_conserved(seed):
    plan = cnn_split_table(make_resnet18(101), 224)
    env = MECEnv(make_env_params(plan, n_ue=3, n_channels=2, lam_tasks=20.0))
    key = jax.random.PRNGKey(seed)
    s = env.reset(key)
    initial = float(s.k.sum())
    done = False
    completed = 0.0
    rng = np.random.RandomState(seed % 2**31)
    for _ in range(400):
        b = jnp.asarray(rng.randint(0, env.n_actions_b, 3), jnp.int32)
        c = jnp.asarray(rng.randint(0, env.n_channels, 3), jnp.int32)
        p = jnp.asarray(rng.uniform(0.05, 0.5, 3), jnp.float32)
        s, r, done, info = env.step(s, {"split": b, "channel": c,
                                        "power": p})
        completed += float(info["completed"])
        if bool(done):
            break
    assert bool(done), "episode should terminate under any policy"
    assert completed == pytest_approx(initial), (completed, initial)


def pytest_approx(x):
    import pytest
    return pytest.approx(x, abs=1.0)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_completed_tasks_conserved_hetero_fleet(seed):
    """Conservation holds per-UE with MIXED plans (different backbones,
    devices, and action-space widths, so padded actions exist)."""
    from repro.configs import get_config
    from repro.core import overhead as oh
    from repro.core.split import transformer_split_table
    cnn = cnn_split_table(make_resnet18(101), 224)
    cnn_iot = cnn_split_table(make_resnet18(101), 224, dev=oh.IOT_SOC)
    tf_small = transformer_split_table(get_config("qwen3-1.7b"),
                                       ue_dev=oh.PHONE_NPU, n_points=2)
    fleet = build_fleet([cnn, tf_small, cnn_iot],
                        [oh.JETSON_NANO, oh.PHONE_NPU, oh.IOT_SOC])
    env = MECEnv(make_env_params(fleet, n_channels=2, lam_tasks=20.0))
    feas = np.asarray(env.action_masks()["split"])
    valid = [np.where(feas[ue])[0] for ue in range(3)]
    key = jax.random.PRNGKey(seed)
    s = env.reset(key)
    per_ue_initial = np.asarray(s.k).copy()
    per_ue_completed = np.zeros(3)
    done = False
    rng = np.random.RandomState(seed % 2**31)
    for _ in range(600):
        k_before = np.asarray(s.k).copy()
        b = jnp.asarray([rng.choice(v) for v in valid], jnp.int32)
        c = jnp.asarray(rng.randint(0, env.n_channels, 3), jnp.int32)
        p = jnp.asarray(rng.uniform(0.05, 0.5, 3), jnp.float32)
        s, r, done, info = env.step(s, {"split": b, "channel": c,
                                        "power": p})
        if bool(done):
            per_ue_completed += k_before  # auto-reset wiped s.k
            break
        per_ue_completed += k_before - np.asarray(s.k)
    assert bool(done), "episode should terminate under any feasible policy"
    # completed + remaining == spawned, per UE
    np.testing.assert_allclose(per_ue_completed, per_ue_initial, atol=1.0)


# --------------------------------------------------------------------------
# Multi-frame exact carry (PR 7): tasks spanning >2 frames hit the closed
# form. Pre-fix, the phase-1 remainder was discarded at every frame
# boundary, so NONE of these scenarios ever terminated.
# --------------------------------------------------------------------------

@pytest.mark.parametrize("t0,split,p_tx", [
    (0.02, "local", 0.05),      # full-local: t_task ~ 3.16 frames
    (0.005, 1, 0.3),            # split 1:   t_task ~ 5.66 frames, ~3 tx
], ids=["local_3frames", "offload_6frames"])
def test_multi_frame_task_matches_closed_form(t0, split, p_tx):
    """A lone UE with 3 queued tasks, each needing >3 frames of work,
    finishes in EXACTLY ceil(3 * t_task / t0) frames with total energy
    equal to 3 * (Eq. 8 per-task energy) — work is conserved bit-for-bit
    across every frame boundary it straddles."""
    plan = cnn_split_table(make_resnet18(101), 224)
    env = MECEnv(make_env_params(plan, n_ue=1, n_channels=2, t0=t0))
    s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
    s = s._replace(k=jnp.asarray([3.0]))
    b = env.n_actions_b - 1 if split == "local" else split
    prm = env.params
    l_b = float(prm.l_new[0, b])
    n_b = float(prm.n_new[0, b])
    g = channel_gain(s.d, prm.pathloss)
    r = float(jnp.maximum(uplink_rates(
        jnp.asarray([p_tx]), jnp.asarray([0]), g, jnp.asarray([True]),
        omega=prm.omega, sigma=prm.sigma), 1.0)[0])
    t_task = l_b + n_b / r
    e_task = l_b * float(prm.p_compute[0]) + (n_b / r) * p_tx
    assert t_task > 3 * t0      # the regime the pre-fix env never finished

    acts = {"split": jnp.asarray([b], jnp.int32),
            "channel": jnp.zeros((1,), jnp.int32),
            "power": jnp.asarray([p_tx], jnp.float32)}
    frames, energy, eps, completed, done = 0, 0.0, 0.0, 0.0, False
    while not done and frames < 200:
        s, _, done, info = env.step(s, acts)
        frames += 1
        energy += float(info["energy"])
        eps += float(info["eps_bits"])
        completed += float(info["completed"])
    assert bool(done), "multi-frame tasks must complete post-fix"
    assert completed == 3.0
    assert frames == int(np.ceil(3 * t_task / t0 - 1e-6))
    # energy is exact up to the eps-floored bits (bounded below)
    assert energy == pytest.approx(3 * e_task, rel=1e-4)
    assert 0.0 <= eps <= 3 * TX_EPS_BITS


def _carry_env(kind):
    plan = cnn_split_table(make_resnet18(101), 224)
    if kind == "churn":
        # t0=0.01 makes even mid-table tasks span many frames; churn
        # exercises the leave/join carry-drop path
        return MECEnv(make_env_params(plan, n_ue=3, n_channels=2, t0=0.01,
                                      churn_rate=0.3, leave_rate=0.2,
                                      lam_tasks=20.0))
    return MECEnv(make_env_params(plan, n_ue=3, n_channels=2, t0=0.01,
                                  pool=make_edge_pool(2), lam_tasks=20.0))


def _check_carry_invariants(kind, seed):
    """For every UE that stays active with an unchanged queue count, the
    in-flight remainder (l, n) is monotone non-increasing frame over
    frame and never resets to a fresh task's work — even while the
    policy changes split/channel/power (and route) mid-task. The eps
    ledger stays within its per-frame bound."""
    env = _carry_env(kind)
    n = env.params.n_ue
    s = env.reset(jax.random.PRNGKey(seed))
    rng = np.random.RandomState(seed % 2**31)
    for _ in range(120):
        prev = s
        acts = {"split": jnp.asarray(rng.randint(0, env.n_actions_b, n),
                                     jnp.int32),
                "channel": jnp.asarray(rng.randint(0, env.n_channels, n),
                                       jnp.int32),
                "power": jnp.asarray(rng.uniform(0.05, 0.5, n),
                                     jnp.float32)}
        if env.multi_server:
            acts["route"] = jnp.asarray(rng.randint(0, env.n_servers, n),
                                        jnp.int32)
        s, _, done, info = env.step(prev, acts)
        eps = float(info["eps_bits"])
        assert 0.0 <= eps <= 2 * n * TX_EPS_BITS
        if bool(done):
            continue                      # auto-reset: fresh queues/state
        pl, pn = np.asarray(prev.l), np.asarray(prev.n)
        pk, pa = np.asarray(prev.k), np.asarray(prev.active)
        cl, cn = np.asarray(s.l), np.asarray(s.n)
        ck, ca = np.asarray(s.k), np.asarray(s.active)
        for ue in range(n):
            # the invariant applies to UEs holding an in-flight task that
            # stay active (active both frames => untouched by churn, since
            # leaves deactivate and joins activate from standby) with k
            # unchanged: the carry-over did not complete (any completion
            # strictly decrements k), so its remainder must have shrunk IN
            # PLACE — monotone non-increasing, never reset to fresh work.
            if not (pa[ue] and ca[ue] and ck[ue] == pk[ue]
                    and pk[ue] > 0 and pl[ue] + pn[ue] > 0):
                continue
            assert cl[ue] <= pl[ue] + 1e-6, (kind, ue)
            assert cn[ue] <= pn[ue] + 1e-3, (kind, ue)
            # never resets: the in-flight task is still in flight
            assert cl[ue] + cn[ue] > 0.0, (kind, ue)


@pytest.mark.parametrize("kind", ["churn", "pool"])
@pytest.mark.parametrize("seed", [0, 7, 12345])
def test_inflight_work_monotone_and_never_resets(kind, seed):
    _check_carry_invariants(kind, seed)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1), st.booleans())
def test_inflight_work_monotone_hypothesis(seed, pool):
    _check_carry_invariants("pool" if pool else "churn", seed)
