"""Task conservation: across an episode, completed tasks exactly exhaust the
initial queues (no task lost or double-counted), for arbitrary policies."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.cnn import make_resnet18
from repro.core.split import cnn_split_table
from repro.env.mecenv import MECEnv, make_env_params


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_completed_tasks_conserved(seed):
    plan = cnn_split_table(make_resnet18(101), 224)
    env = MECEnv(make_env_params(plan, n_ue=3, n_channels=2, lam_tasks=20.0))
    key = jax.random.PRNGKey(seed)
    s = env.reset(key)
    initial = float(s.k.sum())
    done = False
    completed = 0.0
    rng = np.random.RandomState(seed % 2**31)
    for _ in range(400):
        b = jnp.asarray(rng.randint(0, env.n_actions_b, 3), jnp.int32)
        c = jnp.asarray(rng.randint(0, env.n_channels, 3), jnp.int32)
        p = jnp.asarray(rng.uniform(0.05, 0.5, 3), jnp.float32)
        s, r, done, info = env.step(s, b, c, p)
        completed += float(info["completed"])
        if bool(done):
            break
    assert bool(done), "episode should terminate under any policy"
    assert completed == pytest_approx(initial), (completed, initial)


def pytest_approx(x):
    import pytest
    return pytest.approx(x, abs=1.0)
