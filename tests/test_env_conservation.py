"""Task conservation: across an episode, completed tasks exactly exhaust the
initial queues (no task lost or double-counted), for arbitrary policies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.cnn import make_resnet18
from repro.core.split import build_fleet, cnn_split_table
from repro.env.mecenv import MECEnv, make_env_params


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_completed_tasks_conserved(seed):
    plan = cnn_split_table(make_resnet18(101), 224)
    env = MECEnv(make_env_params(plan, n_ue=3, n_channels=2, lam_tasks=20.0))
    key = jax.random.PRNGKey(seed)
    s = env.reset(key)
    initial = float(s.k.sum())
    done = False
    completed = 0.0
    rng = np.random.RandomState(seed % 2**31)
    for _ in range(400):
        b = jnp.asarray(rng.randint(0, env.n_actions_b, 3), jnp.int32)
        c = jnp.asarray(rng.randint(0, env.n_channels, 3), jnp.int32)
        p = jnp.asarray(rng.uniform(0.05, 0.5, 3), jnp.float32)
        s, r, done, info = env.step(s, {"split": b, "channel": c,
                                        "power": p})
        completed += float(info["completed"])
        if bool(done):
            break
    assert bool(done), "episode should terminate under any policy"
    assert completed == pytest_approx(initial), (completed, initial)


def pytest_approx(x):
    import pytest
    return pytest.approx(x, abs=1.0)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_completed_tasks_conserved_hetero_fleet(seed):
    """Conservation holds per-UE with MIXED plans (different backbones,
    devices, and action-space widths, so padded actions exist)."""
    from repro.configs import get_config
    from repro.core import overhead as oh
    from repro.core.split import transformer_split_table
    cnn = cnn_split_table(make_resnet18(101), 224)
    cnn_iot = cnn_split_table(make_resnet18(101), 224, dev=oh.IOT_SOC)
    tf_small = transformer_split_table(get_config("qwen3-1.7b"),
                                       ue_dev=oh.PHONE_NPU, n_points=2)
    fleet = build_fleet([cnn, tf_small, cnn_iot],
                        [oh.JETSON_NANO, oh.PHONE_NPU, oh.IOT_SOC])
    env = MECEnv(make_env_params(fleet, n_channels=2, lam_tasks=20.0))
    feas = np.asarray(env.action_masks()["split"])
    valid = [np.where(feas[ue])[0] for ue in range(3)]
    key = jax.random.PRNGKey(seed)
    s = env.reset(key)
    per_ue_initial = np.asarray(s.k).copy()
    per_ue_completed = np.zeros(3)
    done = False
    rng = np.random.RandomState(seed % 2**31)
    for _ in range(600):
        k_before = np.asarray(s.k).copy()
        b = jnp.asarray([rng.choice(v) for v in valid], jnp.int32)
        c = jnp.asarray(rng.randint(0, env.n_channels, 3), jnp.int32)
        p = jnp.asarray(rng.uniform(0.05, 0.5, 3), jnp.float32)
        s, r, done, info = env.step(s, {"split": b, "channel": c,
                                        "power": p})
        if bool(done):
            per_ue_completed += k_before  # auto-reset wiped s.k
            break
        per_ue_completed += k_before - np.asarray(s.k)
    assert bool(done), "episode should terminate under any feasible policy"
    # completed + remaining == spawned, per UE
    np.testing.assert_allclose(per_ue_completed, per_ue_initial, atol=1.0)
