"""CNN backbones + autoencoder compressor training (paper §2, §6.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cnn as cnn_lib
from repro.core.compressor import (accuracy_with_ae, init_autoencoder,
                                   pca_init_autoencoder, roundtrip,
                                   train_autoencoder)
from repro.data.synthetic import synthetic_image_batch


@pytest.mark.parametrize("name", ["resnet18", "vgg11", "mobilenetv2"])
def test_cnn_forward_shapes(name):
    model = cnn_lib.CNN_FACTORY[name](num_classes=11, width=0.25)
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.ones((2, 3, 32, 32))
    y = cnn_lib.forward(model, params, x)
    assert y.shape == (2, 11)
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("name", ["resnet18", "vgg11", "mobilenetv2"])
def test_cnn_split_equals_full(name):
    """forward == forward_from(forward(..., upto)) at every split point."""
    model = cnn_lib.CNN_FACTORY[name](num_classes=7, width=0.25)
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 32, 32))
    y_full = cnn_lib.forward(model, params, x)
    for k in model.split_after:
        feat = cnn_lib.forward(model, params, x, upto=k + 1)
        y_split = cnn_lib.forward_from(model, params, feat, k + 1)
        np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_split),
                                   rtol=1e-4, atol=1e-4)


def test_feature_shape_walker_matches_runtime():
    model = cnn_lib.make_resnet18(num_classes=7)
    params = model.init(jax.random.PRNGKey(0))
    x = jnp.ones((1, 3, 64, 64))
    shapes = model.feature_shapes(64)
    for k in model.split_after:
        feat = cnn_lib.forward(model, params, x, upto=k + 1)
        assert tuple(feat.shape[1:]) == tuple(shapes[k]), (k, feat.shape)


def test_ae_training_reduces_loss():
    model = cnn_lib.make_resnet18(num_classes=5, width=0.25)
    params = model.init(jax.random.PRNGKey(0))

    def data_iter():
        k = 0
        while True:
            x, y = synthetic_image_batch(jax.random.PRNGKey(k), 8, 32,
                                         n_classes=5)
            yield x, y
            k += 1

    split = model.split_after[0]
    ch = model.feature_shapes(32)[split][0]
    ae, _, logs = train_autoencoder(
        jax.random.PRNGKey(1), model, params, split, data_iter(),
        ch=ch, ch_prime=max(1, ch // 4), steps=25, lr=1e-3)
    first = np.mean([l["l2"] for l in logs[:5]])
    last = np.mean([l["l2"] for l in logs[-5:]])
    assert last < first


def test_pca_init_3d_matches_4d():
    """pca_init_autoencoder treats (B, C, H, W) CNN features and their
    channel-last (B, H*W, C) flattening as the SAME sample set — both
    layouts must produce identical principal components."""
    feats4 = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 4, 4))
    b, c, h, w = feats4.shape
    feats3 = jnp.moveaxis(feats4, 1, -1).reshape(b, h * w, c)
    ae4 = pca_init_autoencoder(feats4, 3)
    ae3 = pca_init_autoencoder(feats3, 3)
    np.testing.assert_allclose(np.asarray(ae4["enc"]),
                               np.asarray(ae3["enc"]), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(ae4["dec"]),
                               np.asarray(ae3["dec"]), rtol=1e-5, atol=1e-6)
    # the components actually compress: PCA reconstruction beats a random
    # linear AE of the same width on the features it was fit to
    rand = init_autoencoder(jax.random.PRNGKey(1), c, 3)
    err_pca = float(jnp.mean((roundtrip(ae4, feats4) - feats4) ** 2))
    err_rand = float(jnp.mean((roundtrip(rand, feats4) - feats4) ** 2))
    assert err_pca < err_rand


def test_ae_quantized_roundtrip_close():
    ae = init_autoencoder(jax.random.PRNGKey(0), 16, 16)
    # near-orthogonal init at same width won't be identity, but roundtrip
    # must at least be finite and the quantized path close to unquantized
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 4, 4))
    r_f = roundtrip(ae, x, bits=None)
    r_q = roundtrip(ae, x, bits=8)
    assert float(jnp.max(jnp.abs(r_f - r_q))) < 0.1 * float(
        jnp.max(jnp.abs(r_f)) + 1)
