"""Entity-set policy (PR 5): the shared per-server route scorer and the
geometry-resampling machinery behind it.

Layers of guarantees:

1. the entity agent trains end-to-end (one jitted iteration) on static,
   churn, pool, and RANDOMIZED-pool envs, and its parameter set carries
   no fixed-width route branch — the same parameters run on pools of any
   size E (train at E=2, evaluate zero-shot at E=1/3).
2. geometry resampling: `reset(randomize=True)` draws within the declared
   ranges, the default reset carries NO geometry (bitwise-identical
   pytree structure to PR 4), episode-end auto-resets redraw, and the
   drawn geometry actually changes the physics (rates, edge service).
3. the route scorer's logits respond to the entity features (a server
   made infinitely slow and far loses its routes), and the per-head
   feasibility masks still bind under the provider path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import golden_cases as gc
from repro.configs import get_config
from repro.core import overhead as oh
from repro.core.cnn import make_resnet18
from repro.core.fleets import (EdgePool, make_edge_pool,
                               random_pool_ranges)
from repro.core.split import build_fleet, cnn_split_table, \
    transformer_split_table
from repro.env.mecenv import MECEnv, make_env_params
from repro.optim import adamw_init
from repro.rl import nets
from repro.rl.mahppo import (MAHPPOConfig, evaluate_policy, init_agent,
                             init_states, make_train_fns)


@pytest.fixture(scope="module")
def mixed_fleet():
    cnn = cnn_split_table(make_resnet18(101), 224)
    cnn_iot = cnn_split_table(make_resnet18(101), 224, dev=oh.IOT_SOC)
    tf_small = transformer_split_table(get_config("qwen3-1.7b"),
                                       ue_dev=oh.PHONE_NPU, n_points=2)
    return build_fleet([cnn, tf_small, cnn_iot],
                       [oh.JETSON_NANO, oh.PHONE_NPU, oh.IOT_SOC])


def _env_for(name, fleet):
    if name == "pool":
        return MECEnv(make_env_params(fleet, n_channels=2,
                                      pool=make_edge_pool(2)))
    if name == "churn":
        return MECEnv(make_env_params(fleet, n_channels=2,
                                      churn_rate=0.3, leave_rate=0.2))
    if name == "randomized":
        return MECEnv(make_env_params(fleet, n_channels=2,
                                      pool=make_edge_pool(2),
                                      pool_ranges=random_pool_ranges(2)))
    return MECEnv(make_env_params(fleet, n_channels=2))


@pytest.mark.parametrize("name", ["mixed", "pool", "churn", "randomized"])
def test_entity_policy_trains_on_every_env_kind(mixed_fleet, name):
    """One jitted entity-policy iteration end-to-end; the agent is a
    single entity actor + value head and metrics are finite."""
    env = _env_for(name, mixed_fleet)
    cfg = MAHPPOConfig(iterations=1, horizon=64, n_envs=2, reuse=1,
                       batch=32, entity_policy=True,
                       randomize_pool=(name == "randomized"))
    key = jax.random.PRNGKey(0)
    agent = init_agent(key, env, entity_policy=True)
    assert "entity_actor" in agent and "actors" not in agent
    # no fixed-width route branch: route logits come from the scorer
    assert "route" not in agent["entity_actor"]["heads"]
    opt = adamw_init(agent)
    states = init_states(env, cfg, key)
    iteration = make_train_fns(env, cfg)
    agent, opt, key, states, metrics = iteration(agent, opt, key, states)
    assert np.isfinite(float(metrics["reward_mean"]))
    res = evaluate_policy(env, agent, frames=8)
    assert np.isfinite(res["t_task"]) and np.isfinite(res["reward"])


@pytest.mark.parametrize("case", ["entity.pool", "entity.churn"])
def test_entity_policy_path_matches_golden(case):
    """The entity-set path is pinned against tests/goldens/goldens.json
    (PR-7 recapture): init key stream via the tolerance fingerprint,
    the full jitted iteration via exact post sha / metrics / key."""
    got, _ = gc.train_capture(case, with_init_tree=True)
    g = gc.load_goldens()["training"][case]
    assert gc.fingerprint_close(got["init_fp"], g["init_fp"]), \
        f"{case}: init key stream / param layout drifted"
    assert got["post_sha"] == g["post_sha"], case
    assert got["metrics"] == g["metrics"], case
    assert got["key"] == g["key"], case


def test_entity_agent_transfers_across_pool_size(mixed_fleet):
    """The SAME parameter set evaluates on E=1, E=2, and E=3 pools (and a
    bigger fleet): route logits are scored per server, so neither N nor E
    appears in any parameter shape."""
    env2 = _env_for("pool", mixed_fleet)
    agent = init_agent(jax.random.PRNGKey(0), env2, entity_policy=True)
    n_params = nets.param_count(agent)
    for env in (
            MECEnv(make_env_params(mixed_fleet, n_channels=2)),
            MECEnv(make_env_params(mixed_fleet, n_channels=2,
                                   pool=make_edge_pool(3)))):
        res = evaluate_policy(env, agent, frames=4)
        assert np.isfinite(res["t_task"]) and np.isfinite(res["e_task"])
        # and an agent built FOR that env has the identical param count
        a2 = init_agent(jax.random.PRNGKey(1), env, entity_policy=True)
        assert nets.param_count(a2) == n_params


def test_randomized_reset_draws_within_ranges(mixed_fleet):
    env = _env_for("randomized", mixed_fleet)
    lo = np.asarray(env.params.pool_low)
    hi = np.asarray(env.params.pool_high)
    geoms = []
    for seed in range(8):
        s = env.reset(jax.random.PRNGKey(seed), randomize=True)
        g = np.asarray(s.geom)
        assert g.shape == (2, 3)
        assert np.all(g >= lo) and np.all(g <= hi)
        geoms.append(g)
    # the draws actually vary (the whole point of randomization)
    assert np.std(np.stack(geoms), axis=0).min() > 0.0
    # default reset carries NO geometry — the PR-4 state pytree exactly
    s0 = env.reset(jax.random.PRNGKey(0))
    assert s0.geom is None
    # randomize on an env without ranges is an explicit error
    with pytest.raises(ValueError, match="pool_ranges"):
        _env_for("pool", mixed_fleet).reset(jax.random.PRNGKey(0),
                                            randomize=True)


def test_pool_ranges_require_multi_server(mixed_fleet):
    with pytest.raises(ValueError, match="multi-server"):
        make_env_params(mixed_fleet, n_channels=2,
                        pool_ranges=random_pool_ranges(1))


def test_randomize_pool_requires_entity_policy():
    """Flat observations describe the construction-time pool only —
    training them on resampled geometry would silently learn from state
    that contradicts the physics, so the config combination is an
    explicit error for both flat modes."""
    with pytest.raises(ValueError, match="entity_policy"):
        MAHPPOConfig(randomize_pool=True)
    with pytest.raises(ValueError, match="entity_policy"):
        MAHPPOConfig(randomize_pool=True, shared_policy=True)
    with pytest.raises(ValueError, match="one of"):
        MAHPPOConfig(shared_policy=True, entity_policy=True)
    MAHPPOConfig(randomize_pool=True, entity_policy=True)   # the one way


def test_geometry_changes_the_physics(mixed_fleet):
    """The same actions under two planted geometries: a far/slow draw
    must yield strictly worse per-task latency than a near/fast draw —
    geometry is live data, not a dead observation field."""
    env = _env_for("randomized", mixed_fleet)
    n = env.params.n_ue
    s = env.reset(jax.random.PRNGKey(0), eval_mode=True, randomize=True)
    near = jnp.asarray([[1.0, 1.0, 0.0]] * 2, jnp.float32)
    far = jnp.asarray([[2.0, 0.5, 4e-12]] * 2, jnp.float32)
    acts = {"split": jnp.zeros((n,), jnp.int32),     # raw offload
            "channel": jnp.asarray([0, 1, 0], jnp.int32),
            "route": jnp.asarray([0, 1, 0], jnp.int32),
            "power": jnp.full((n,), 0.3)}
    t_near, _ = env.task_overhead(s._replace(geom=near), acts)
    t_far, _ = env.task_overhead(s._replace(geom=far), acts)
    assert np.all(np.asarray(t_far) > np.asarray(t_near))
    # and the instant-edge near draw reproduces the no-service-time case
    te_near = env._pool_phys(s._replace(geom=near))[2]
    np.testing.assert_array_equal(np.asarray(te_near), 0.0)


def test_auto_reset_redraws_geometry():
    """Driving an episode to completion redraws the pool geometry (every
    episode trains on a fresh layout); non-terminal steps keep it. A
    homogeneous CNN fleet (sub-frame full-local tasks) drains its lam=1
    queues in a handful of frames."""
    env = MECEnv(make_env_params(
        cnn_split_table(make_resnet18(101), 224), n_ue=3, n_channels=2,
        lam_tasks=1.0,
        pool=make_edge_pool(2), pool_ranges=random_pool_ranges(2)))
    n = env.params.n_ue
    s = env.reset(jax.random.PRNGKey(1), randomize=True)
    g0 = np.asarray(s.geom)
    acts = {"split": jnp.full((n,), env.n_actions_b - 1, jnp.int32),
            "channel": jnp.zeros((n,), jnp.int32),
            "route": jnp.zeros((n,), jnp.int32),
            "power": jnp.full((n,), 0.3)}
    done = False
    for _ in range(64):
        s, _, d, _ = env.step(s, acts)
        if not done and not bool(d):
            # until the first termination the draw is stable
            np.testing.assert_array_equal(np.asarray(s.geom), g0)
        if bool(d):
            done = True
            break
    assert done, "full-local on lam=1 queues must terminate quickly"
    s, _, _, _ = env.step(s, acts)   # post-done state has the redraw
    assert not np.array_equal(np.asarray(s.geom), g0)


def test_route_scorer_responds_to_server_features(mixed_fleet):
    """Make server 1 infinitely unattractive IN THE OBSERVATION and check
    a trained-from-init scorer shifts probability mass off it relative to
    an attractive version — the route head conditions on pool features
    (exactly what the mean-field shared policy could not do)."""
    env = _env_for("randomized", mixed_fleet)
    space = env.action_space
    agent = init_agent(jax.random.PRNGKey(0), env, entity_policy=True)
    s = env.reset(jax.random.PRNGKey(0), eval_mode=True, randomize=True)
    good = jnp.asarray([[1.0, 1.0, 0.0], [1.0, 1.0, 0.0]], jnp.float32)
    bad = jnp.asarray([[1.0, 1.0, 0.0], [25.0, 0.01, 4e-10]], jnp.float32)
    masks = space.broadcast_masks(env.action_masks(), env.params.n_ue)
    d_good = nets.entity_actor_forward(
        agent["entity_actor"], space, env.observe_entities(
            s._replace(geom=good)), masks)
    d_bad = nets.entity_actor_forward(
        agent["entity_actor"], space, env.observe_entities(
            s._replace(geom=bad)), masks)
    p_good = np.asarray(jax.nn.softmax(d_good["route"], -1))[:, 1]
    p_bad = np.asarray(jax.nn.softmax(d_bad["route"], -1))[:, 1]
    # an untrained scorer has no learned preference, but its logits MUST
    # move when the server entity moves: identical logits would mean the
    # features never reach the head
    assert not np.allclose(p_good, p_bad)


def test_entity_masks_still_bind(mixed_fleet):
    """Sampling through the provider path never draws an infeasible
    split: the provided route logits ride the same masking/sampling
    machinery as branch heads."""
    env = _env_for("pool", mixed_fleet)
    space = env.action_space
    agent = init_agent(jax.random.PRNGKey(0), env, entity_policy=True)
    s = env.reset(jax.random.PRNGKey(1))
    masks = space.broadcast_masks(env.action_masks(), env.params.n_ue)
    dist = nets.entity_actor_forward(agent["entity_actor"], space,
                                     env.observe_entities(s), masks)
    assert dist["route"].shape == (env.params.n_ue, env.n_servers)
    mask = np.asarray(env.action_masks()["split"])
    for seed in range(100):
        keys = jax.random.split(jax.random.PRNGKey(seed), env.params.n_ue)
        a = jax.vmap(space.sample)(keys, dist, masks)
        for ue, b in enumerate(np.asarray(a["split"])):
            assert mask[ue, int(b)], (ue, int(b))
        assert np.all(np.asarray(a["route"]) < env.n_servers)


@pytest.mark.parametrize("name", ["pool", "churn", "randomized"])
def test_fused_scorer_matches_default_route_logits(mixed_fleet, name):
    """Kernel on/off equivalence (PR 6): the fused pair-scorer obs path
    (``observe_entities_raw`` -> ``kernels.ops.pair_scorer``) produces
    the same route logits, distributions, and values as the default
    materialized entity path, on live env states — including churn
    states with inactive UEs."""
    env = _env_for(name, mixed_fleet)
    space = env.action_space
    agent = init_agent(jax.random.PRNGKey(0), env, entity_policy=True)
    s = env.reset(jax.random.PRNGKey(2), randomize=(name == "randomized"))
    # advance a few frames so churn envs carry genuinely inactive UEs
    for i in range(3):
        masks = space.broadcast_masks(env.action_masks(s),
                                      env.params.n_ue)
        dist = nets.entity_actor_forward(agent["entity_actor"], space,
                                         env.observe_entities(s), masks)
        a = jax.vmap(space.sample)(
            jax.random.split(jax.random.PRNGKey(i), env.params.n_ue),
            dist, masks)
        s = env.step(s, a)[0]
    masks = space.broadcast_masks(env.action_masks(s), env.params.n_ue)
    d_def = nets.entity_actor_forward(agent["entity_actor"], space,
                                      env.observe_entities(s), masks)
    d_fused = nets.entity_actor_forward(agent["entity_actor"], space,
                                        env.observe_entities_raw(s), masks)
    if env.multi_server:            # churn env is single-server: no route
        np.testing.assert_allclose(np.asarray(d_fused["route"]),
                                   np.asarray(d_def["route"]),
                                   rtol=1e-5, atol=1e-6)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6),
        d_fused, d_def)
    v_def = nets.entity_value_forward(agent["entity_actor"],
                                      agent["critic"],
                                      env.observe_entities(s))
    v_fused = nets.entity_value_forward(agent["entity_actor"],
                                        agent["critic"],
                                        env.observe_entities_raw(s))
    np.testing.assert_allclose(np.asarray(v_fused), np.asarray(v_def),
                               rtol=1e-5, atol=1e-6)


def test_fused_scorer_training_iteration_runs(mixed_fleet):
    """cfg.fused_scorer=True trains one jitted iteration end-to-end and
    the config refuses fused_scorer without entity_policy."""
    env = _env_for("pool", mixed_fleet)
    cfg = MAHPPOConfig(iterations=1, horizon=32, n_envs=2, reuse=1,
                       batch=16, entity_policy=True, fused_scorer=True)
    key = jax.random.PRNGKey(0)
    agent = init_agent(key, env, entity_policy=True)
    opt = adamw_init(agent)
    states = init_states(env, cfg, key)
    iteration = make_train_fns(env, cfg)
    agent, opt, key, states, metrics = iteration(agent, opt, key, states)
    assert np.isfinite(float(metrics["reward_mean"]))
    assert np.isfinite(float(metrics["actor_loss"]))
    with pytest.raises(ValueError, match="entity_policy"):
        MAHPPOConfig(fused_scorer=True)
