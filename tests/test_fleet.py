"""Heterogeneous-fleet refactor invariants: a homogeneous fleet reproduces
the seed single-plan env bit-for-bit, padded/infeasible actions are never
sampled, and the fleet env stays fully jit/vmap-friendly. The golden
trajectories at the bottom additionally pin the action-space/edge-pool
redesign: a single-server EdgePool must be indistinguishable from no pool
at all, PRNG stream included."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import golden_cases as gc
from repro.configs import get_config
from repro.core import overhead as oh
from repro.core.cnn import make_resnet18
from repro.core.fleets import single_server
from repro.core.split import (build_fleet, cnn_split_table,
                              homogeneous_fleet, transformer_split_table)
from repro.env.mecenv import MECEnv, make_env_params, per_ue
from repro.rl import nets


def _acts(b, c, p):
    return {"split": b, "channel": c, "power": p}


@pytest.fixture(scope="module")
def mixed_fleet():
    cnn = cnn_split_table(make_resnet18(101), 224)
    cnn_iot = cnn_split_table(make_resnet18(101), 224, dev=oh.IOT_SOC)
    # n_points=2 -> 4 actions vs the CNN's 6: exercises padding
    tf_small = transformer_split_table(get_config("qwen3-1.7b"),
                                       ue_dev=oh.PHONE_NPU, n_points=2)
    return build_fleet([cnn, tf_small, cnn_iot],
                       [oh.JETSON_NANO, oh.PHONE_NPU, oh.IOT_SOC])


def test_homogeneous_fleet_matches_seed_env_bit_for_bit():
    """N identical plans through the fleet path == the seed homogeneous env
    (single plan broadcast), reward-for-reward and state-for-state."""
    plan = cnn_split_table(make_resnet18(101), 224)
    env_a = MECEnv(make_env_params(plan, n_ue=3, n_channels=2))
    env_b = MECEnv(make_env_params(homogeneous_fleet(plan, 3), n_channels=2))
    np.testing.assert_array_equal(np.asarray(env_a.params.l_new),
                                  np.asarray(env_b.params.l_new))
    sa = env_a.reset(jax.random.PRNGKey(3))
    sb = env_b.reset(jax.random.PRNGKey(3))
    rng = np.random.RandomState(0)
    for _ in range(50):
        b = jnp.asarray(rng.randint(0, env_a.n_actions_b, 3), jnp.int32)
        c = jnp.asarray(rng.randint(0, env_a.n_channels, 3), jnp.int32)
        p = jnp.asarray(rng.uniform(0.05, 0.5, 3), jnp.float32)
        sa, ra, da, _ = env_a.step(sa, _acts(b, c, p))
        sb, rb, db, _ = env_b.step(sb, _acts(b, c, p))
        assert np.asarray(ra).tobytes() == np.asarray(rb).tobytes()
        np.testing.assert_array_equal(np.asarray(sa.k), np.asarray(sb.k))
        np.testing.assert_array_equal(np.asarray(sa.n), np.asarray(sb.n))


def test_fleet_padding_layout(mixed_fleet):
    f = mixed_fleet
    assert f.n_ue == 3 and f.n_actions == 6
    # full-local is the LAST action for every UE, raw offload the first
    assert np.all(f.f_bits[:, -1] == 0.0)
    assert np.all(f.t_local[:, 0] == 0.0)
    # the 4-action transformer row has exactly 2 padded (infeasible) slots
    assert int((~f.feasible[1]).sum()) >= 2
    assert not f.feasible[1, 3] and not f.feasible[1, 4]
    # padded slots cost nothing (a step taking them completes no tasks)
    assert np.all(f.t_local[1, 3:5] == 0.0) and np.all(f.f_bits[1, 3:5] == 0.0)
    # per-UE device power
    np.testing.assert_allclose(
        f.p_compute, [oh.JETSON_NANO.active_power, oh.PHONE_NPU.active_power,
                      oh.IOT_SOC.active_power])


def test_mask_per_ue_and_sampling_respects_it(mixed_fleet):
    env = MECEnv(make_env_params(mixed_fleet, n_channels=2))
    space = env.action_space
    mask = env.action_masks()["split"]
    assert mask.shape == (3, env.n_actions_b)
    actor = nets.init_actor(jax.random.PRNGKey(0), env.obs_dim, space)
    obs = env.observe(env.reset(jax.random.PRNGKey(1)))
    for ue in range(3):
        m = {"split": mask[ue]}
        dist = nets.actor_forward(actor, space, obs, m)
        for seed in range(200):
            a = space.sample(jax.random.PRNGKey(seed), dist, m)
            assert bool(mask[ue, int(a["split"])]), (ue, int(a["split"]))
        # even from RAW (unmasked) logits, space.sample's mask protects
        raw = dict(dist, split=jnp.zeros_like(dist["split"]))
        for seed in range(200):
            a = space.sample(jax.random.PRNGKey(seed), raw, m)
            assert bool(mask[ue, int(a["split"])]), (ue, int(a["split"]))


def test_padded_action_is_inert(mixed_fleet):
    """Forcing a padded action completes nothing and burns no energy for
    that UE (defense in depth under the mask)."""
    env = MECEnv(make_env_params(mixed_fleet, n_channels=2))
    s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
    b = jnp.asarray([5, 3, 5], jnp.int32)     # ue1 takes a padded slot
    _, _, _, info = env.step(s, _acts(b, jnp.zeros((3,), jnp.int32),
                                      jnp.full((3,), 0.3)))
    l_b = per_ue(env.params.l_new, b)
    n_b = per_ue(env.params.n_new, b)
    assert float(l_b[1]) == 0.0 and float(n_b[1]) == 0.0


def test_fleet_env_jit_vmap(mixed_fleet):
    env = MECEnv(make_env_params(mixed_fleet, n_channels=2))
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    states = jax.vmap(env.reset)(keys)
    b = jnp.zeros((4, 3), jnp.int32)
    c = jnp.zeros((4, 3), jnp.int32)
    p = jnp.full((4, 3), 0.3)
    step = jax.jit(jax.vmap(env.step))
    _, r, _, _ = step(states, _acts(b, c, p))
    assert r.shape == (4,)
    assert bool(jnp.all(jnp.isfinite(r)))


def test_mahppo_short_training_on_mixed_fleet(mixed_fleet):
    """One jitted iteration runs end-to-end on a mixed fleet and only
    feasible actions appear in the collected trajectories."""
    from repro.rl.mahppo import MAHPPOConfig, make_train_fns, init_agent
    from repro.optim import adamw_init
    env = MECEnv(make_env_params(mixed_fleet, n_channels=2))
    cfg = MAHPPOConfig(iterations=1, horizon=64, n_envs=2, reuse=1,
                       batch=32)
    key = jax.random.PRNGKey(0)
    agent = init_agent(key, env)
    opt = adamw_init(agent)
    states = jax.vmap(env.reset)(jax.random.split(key, cfg.n_envs))
    iteration = make_train_fns(env, cfg)
    agent, opt, key, states, metrics = iteration(agent, opt, key, states)
    assert np.isfinite(float(metrics["reward_mean"]))


# Golden trajectories — 40 frames of rewards + the final EnvState under
# the fixed seed/action stream of `golden_cases.golden_rollout` — live in
# tests/goldens/goldens.json, captured by scripts/capture_goldens.py at
# the PR-7 exact-carry fix (the one planned recapture). They guard that
# (a) the static env itself, (b) the dynamic env with
# churn_rate=leave_rate=0.0, and (c) BOTH through a single-server
# EdgePool are BIT-FOR-BIT identical — PRNG key stream included.
_GOLD = gc.load_goldens()["trajectories"]


def _golden_check(env, g, name):
    rewards, s = gc.golden_rollout(env)
    assert rewards.tobytes().hex() == g["rewards"], name
    for field in ("k", "l", "n", "d"):
        got = np.asarray(getattr(s, field), np.float32).tobytes().hex()
        assert got == g[field], (name, field)
    assert np.asarray(s.key, np.uint32).tobytes().hex() == g["key"], name
    got_act = np.asarray(s.active, np.uint8).tobytes().hex()
    assert got_act == g["active"], name


@pytest.mark.parametrize("pool_kwargs", [
    {},                                         # no pool argument at all
    {"pool": None},
    {"pool": "single"},                         # 1-server EdgePool
], ids=["default", "none", "edgepool1"])
@pytest.mark.parametrize("churn_kwargs", [
    {},                                         # the static entry point
    {"churn_rate": 0.0, "leave_rate": 0.0},     # zero-churn dynamic request
], ids=["static", "zero_churn"])
def test_env_matches_prechurn_golden(mixed_fleet, churn_kwargs, pool_kwargs):
    kw = dict(churn_kwargs)
    if pool_kwargs:
        kw["pool"] = single_server() if pool_kwargs["pool"] == "single" \
            else None
    plan = cnn_split_table(make_resnet18(101), 224)
    for name, env in [
            ("homo", MECEnv(make_env_params(plan, n_ue=3, n_channels=2,
                                            **kw))),
            ("mixed", MECEnv(make_env_params(mixed_fleet, n_channels=2,
                                             **kw)))]:
        assert not env.dynamic          # both rates 0.0 => static machinery
        assert not env.multi_server     # one paper server => no routing
        assert env.action_space.names == ("split", "channel", "power")
        assert env.obs_dim == 4 * env.params.n_ue
        _golden_check(env, _GOLD[name], name)


@pytest.mark.parametrize("pool", [None, "single"], ids=["none", "edgepool1"])
def test_churn_env_matches_preactionspace_golden(pool):
    """The dynamic env through the actions-dict API (and through a
    1-server EdgePool) reproduces the PR-2 churn trajectories bit-for-bit,
    PRNG stream and final membership mask included."""
    plan = cnn_split_table(make_resnet18(101), 224)
    env = MECEnv(make_env_params(
        plan, n_ue=3, n_channels=2, churn_rate=0.4, leave_rate=0.2,
        lam_tasks=30.0, pool=single_server() if pool else None))
    assert env.dynamic and not env.multi_server
    _golden_check(env, _GOLD["churn"], "churn")


# Golden per-UE feature rows (hex float32 (N, OBS_UE_DIM) matrices),
# introduced with `observe_per_ue` in PR 4 and since maintained by
# scripts/capture_goldens.py: the homogeneous and mixed static fleets, a
# churned fleet with a planted standby UE (zeroed own features, live
# aggregates), and the mixed fleet through the 2-server demo pool. Any
# change to the feature layout, normalization, or the static fleets.py
# descriptors shows up here. (These are reset-state observations, so the
# PR-7 carry-fix recapture left them byte-identical to the PR-4 values.)
_GOLD_FEATS = gc.load_goldens()["observe_per_ue"]


def _feat_hex(env, s):
    return np.asarray(env.observe_per_ue(s), np.float32).tobytes().hex()


def test_observe_per_ue_matches_golden(mixed_fleet):
    from repro.core.fleets import make_edge_pool
    from repro.env.mecenv import OBS_UE_DIM
    plan = cnn_split_table(make_resnet18(101), 224)
    cases = {
        "homo": MECEnv(make_env_params(plan, n_ue=3, n_channels=2)),
        "mixed": MECEnv(make_env_params(mixed_fleet, n_channels=2)),
        "pool2": MECEnv(make_env_params(mixed_fleet, n_channels=2,
                                        pool=make_edge_pool(2))),
    }
    for name, env in cases.items():
        assert env.ue_feat_dim == OBS_UE_DIM
        s = env.reset(jax.random.PRNGKey(3))
        assert env.observe_per_ue(s).shape == (3, OBS_UE_DIM)
        assert _feat_hex(env, s) == _GOLD_FEATS[name], name


def test_observe_per_ue_churn_matches_golden():
    """A planted standby UE: zeroed own features + zero activity flag,
    static descriptors intact, aggregates over the two live UEs."""
    plan = cnn_split_table(make_resnet18(101), 224)
    env = MECEnv(make_env_params(plan, n_ue=3, n_channels=2,
                                 churn_rate=0.4, leave_rate=0.2,
                                 lam_tasks=30.0))
    s = env.reset(jax.random.PRNGKey(3))
    s = s._replace(active=jnp.asarray([True, False, True]))
    assert _feat_hex(env, s) == _GOLD_FEATS["churn_standby"]


# Golden entity-set observations (hex float32 blocks), introduced with
# `observe_entities` in PR 5 and since maintained by
# scripts/capture_goldens.py: the homogeneous single-server fleet
# (degenerate [[1,1,0]] geometry, zero edge-service column), and the mixed
# fleet through the 2- and 3-server demo pools. Any change to the entity
# feature layout, the geometry encoding (slowness, not speed), or the
# normalization constants shows up here.
_GOLD_ENTITIES = gc.load_goldens()["observe_entities"]


def test_observe_entities_matches_golden(mixed_fleet):
    from repro.core.fleets import make_edge_pool
    from repro.env.mecenv import OBS_ENT_EDGE, OBS_ENT_SRV, OBS_ENT_UE
    plan = cnn_split_table(make_resnet18(101), 224)
    cases = {
        "homo": (MECEnv(make_env_params(plan, n_ue=3, n_channels=2)), 1),
        "pool2": (MECEnv(make_env_params(mixed_fleet, n_channels=2,
                                         pool=make_edge_pool(2))), 2),
        "pool3": (MECEnv(make_env_params(mixed_fleet, n_channels=2,
                                         pool=make_edge_pool(3))), 3),
    }
    for name, (env, n_srv) in cases.items():
        s = env.reset(jax.random.PRNGKey(3))
        obs = env.observe_entities(s)
        assert obs["ue"].shape == (3, OBS_ENT_UE)
        assert obs["server"].shape == (n_srv, OBS_ENT_SRV)
        assert obs["edge"].shape == (3, n_srv, OBS_ENT_EDGE)
        for block in ("ue", "server", "edge"):
            got = np.asarray(obs[block], np.float32).tobytes().hex()
            assert got == _GOLD_ENTITIES[name][block], (name, block)
    # the single paper server is the degenerate [[1, 1, 0]] geometry and
    # its edge-service column is identically zero (instant edge)
    homo_obs = cases["homo"][0].observe_entities(
        cases["homo"][0].reset(jax.random.PRNGKey(3)))
    np.testing.assert_array_equal(np.asarray(homo_obs["server"])[0, :3],
                                  [1.0, 1.0, 0.0])
    np.testing.assert_array_equal(np.asarray(homo_obs["edge"])[:, :, 2],
                                  0.0)


def test_split_plan_invariants_enforced():
    from repro.core.split import _finalize
    rows = [(0.0, 0.0, 0.0, 0.0, 100.0, True),
            (2.0, 0.1, 0.0, 0.0, 50.0, True),
            (1.0, 0.1, 0.0, 0.0, 25.0, True),   # t_local not monotone
            (3.0, 0.2, 0.0, 0.0, 0.0, True)]
    with pytest.raises(ValueError):
        _finalize("bad", [1, 2], rows)
    rows_bad_bits = [(0.0, 0.0, 0.0, 0.0, 100.0, True),
                     (1.0, 0.1, 0.0, 0.0, 50.0, True),
                     (2.0, 0.2, 0.0, 0.0, 7.0, True)]  # f_bits[-1] != 0
    with pytest.raises(ValueError):
        _finalize("bad2", [1], rows_bad_bits)


def test_build_fleet_validation():
    plan = cnn_split_table(make_resnet18(101), 224)
    with pytest.raises(ValueError):
        build_fleet([])
    with pytest.raises(ValueError):
        build_fleet([plan, plan], [oh.JETSON_NANO])
    # tables built for one device can't be paired with another's profile
    with pytest.raises(ValueError, match="jetson-nano"):
        build_fleet([plan], [oh.IOT_SOC])
