"""Heterogeneous-fleet refactor invariants: a homogeneous fleet reproduces
the seed single-plan env bit-for-bit, padded/infeasible actions are never
sampled, and the fleet env stays fully jit/vmap-friendly. The golden
trajectories at the bottom additionally pin the action-space/edge-pool
redesign: a single-server EdgePool must be indistinguishable from no pool
at all, PRNG stream included."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import overhead as oh
from repro.core.cnn import make_resnet18
from repro.core.fleets import single_server
from repro.core.split import (build_fleet, cnn_split_table,
                              homogeneous_fleet, transformer_split_table)
from repro.env.mecenv import MECEnv, make_env_params, per_ue
from repro.rl import nets


def _acts(b, c, p):
    return {"split": b, "channel": c, "power": p}


@pytest.fixture(scope="module")
def mixed_fleet():
    cnn = cnn_split_table(make_resnet18(101), 224)
    cnn_iot = cnn_split_table(make_resnet18(101), 224, dev=oh.IOT_SOC)
    # n_points=2 -> 4 actions vs the CNN's 6: exercises padding
    tf_small = transformer_split_table(get_config("qwen3-1.7b"),
                                       ue_dev=oh.PHONE_NPU, n_points=2)
    return build_fleet([cnn, tf_small, cnn_iot],
                       [oh.JETSON_NANO, oh.PHONE_NPU, oh.IOT_SOC])


def test_homogeneous_fleet_matches_seed_env_bit_for_bit():
    """N identical plans through the fleet path == the seed homogeneous env
    (single plan broadcast), reward-for-reward and state-for-state."""
    plan = cnn_split_table(make_resnet18(101), 224)
    env_a = MECEnv(make_env_params(plan, n_ue=3, n_channels=2))
    env_b = MECEnv(make_env_params(homogeneous_fleet(plan, 3), n_channels=2))
    np.testing.assert_array_equal(np.asarray(env_a.params.l_new),
                                  np.asarray(env_b.params.l_new))
    sa = env_a.reset(jax.random.PRNGKey(3))
    sb = env_b.reset(jax.random.PRNGKey(3))
    rng = np.random.RandomState(0)
    for _ in range(50):
        b = jnp.asarray(rng.randint(0, env_a.n_actions_b, 3), jnp.int32)
        c = jnp.asarray(rng.randint(0, env_a.n_channels, 3), jnp.int32)
        p = jnp.asarray(rng.uniform(0.05, 0.5, 3), jnp.float32)
        sa, ra, da, _ = env_a.step(sa, _acts(b, c, p))
        sb, rb, db, _ = env_b.step(sb, _acts(b, c, p))
        assert np.asarray(ra).tobytes() == np.asarray(rb).tobytes()
        np.testing.assert_array_equal(np.asarray(sa.k), np.asarray(sb.k))
        np.testing.assert_array_equal(np.asarray(sa.n), np.asarray(sb.n))


def test_fleet_padding_layout(mixed_fleet):
    f = mixed_fleet
    assert f.n_ue == 3 and f.n_actions == 6
    # full-local is the LAST action for every UE, raw offload the first
    assert np.all(f.f_bits[:, -1] == 0.0)
    assert np.all(f.t_local[:, 0] == 0.0)
    # the 4-action transformer row has exactly 2 padded (infeasible) slots
    assert int((~f.feasible[1]).sum()) >= 2
    assert not f.feasible[1, 3] and not f.feasible[1, 4]
    # padded slots cost nothing (a step taking them completes no tasks)
    assert np.all(f.t_local[1, 3:5] == 0.0) and np.all(f.f_bits[1, 3:5] == 0.0)
    # per-UE device power
    np.testing.assert_allclose(
        f.p_compute, [oh.JETSON_NANO.active_power, oh.PHONE_NPU.active_power,
                      oh.IOT_SOC.active_power])


def test_mask_per_ue_and_sampling_respects_it(mixed_fleet):
    env = MECEnv(make_env_params(mixed_fleet, n_channels=2))
    space = env.action_space
    mask = env.action_masks()["split"]
    assert mask.shape == (3, env.n_actions_b)
    actor = nets.init_actor(jax.random.PRNGKey(0), env.obs_dim, space)
    obs = env.observe(env.reset(jax.random.PRNGKey(1)))
    for ue in range(3):
        m = {"split": mask[ue]}
        dist = nets.actor_forward(actor, space, obs, m)
        for seed in range(200):
            a = space.sample(jax.random.PRNGKey(seed), dist, m)
            assert bool(mask[ue, int(a["split"])]), (ue, int(a["split"]))
        # even from RAW (unmasked) logits, space.sample's mask protects
        raw = dict(dist, split=jnp.zeros_like(dist["split"]))
        for seed in range(200):
            a = space.sample(jax.random.PRNGKey(seed), raw, m)
            assert bool(mask[ue, int(a["split"])]), (ue, int(a["split"]))


def test_padded_action_is_inert(mixed_fleet):
    """Forcing a padded action completes nothing and burns no energy for
    that UE (defense in depth under the mask)."""
    env = MECEnv(make_env_params(mixed_fleet, n_channels=2))
    s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
    b = jnp.asarray([5, 3, 5], jnp.int32)     # ue1 takes a padded slot
    _, _, _, info = env.step(s, _acts(b, jnp.zeros((3,), jnp.int32),
                                      jnp.full((3,), 0.3)))
    l_b = per_ue(env.params.l_new, b)
    n_b = per_ue(env.params.n_new, b)
    assert float(l_b[1]) == 0.0 and float(n_b[1]) == 0.0


def test_fleet_env_jit_vmap(mixed_fleet):
    env = MECEnv(make_env_params(mixed_fleet, n_channels=2))
    keys = jax.random.split(jax.random.PRNGKey(0), 4)
    states = jax.vmap(env.reset)(keys)
    b = jnp.zeros((4, 3), jnp.int32)
    c = jnp.zeros((4, 3), jnp.int32)
    p = jnp.full((4, 3), 0.3)
    step = jax.jit(jax.vmap(env.step))
    _, r, _, _ = step(states, _acts(b, c, p))
    assert r.shape == (4,)
    assert bool(jnp.all(jnp.isfinite(r)))


def test_mahppo_short_training_on_mixed_fleet(mixed_fleet):
    """One jitted iteration runs end-to-end on a mixed fleet and only
    feasible actions appear in the collected trajectories."""
    from repro.rl.mahppo import MAHPPOConfig, make_train_fns, init_agent
    from repro.optim import adamw_init
    env = MECEnv(make_env_params(mixed_fleet, n_channels=2))
    cfg = MAHPPOConfig(iterations=1, horizon=64, n_envs=2, reuse=1,
                       batch=32)
    key = jax.random.PRNGKey(0)
    agent = init_agent(key, env)
    opt = adamw_init(agent)
    states = jax.vmap(env.reset)(jax.random.split(key, cfg.n_envs))
    iteration = make_train_fns(env, cfg)
    agent, opt, key, states, metrics = iteration(agent, opt, key, states)
    assert np.isfinite(float(metrics["reward_mean"]))


# Golden trajectories captured from the PRE-churn static env (PR 1 HEAD)
# and, for "churn", from the PRE-actionspace dynamic env (PR 2 HEAD):
# 40 frames of rewards + the final EnvState under a fixed seed/action
# stream. Guards that (a) the static env itself, (b) the dynamic env
# with churn_rate=leave_rate=0.0, and (c) BOTH through a single-server
# EdgePool are BIT-FOR-BIT the seed behavior — including the PRNG key
# stream (key hexes below).
_GOLD = {
    "homo": {
        "rewards": "ed7b13beb7b8a4bd81b3eebd05e6a8bd5b8019bd48cb09be9ec33a"
                   "bdd3e590bd58ebd3bdb580c2bddea8cebdc29f48bd47c183bd5271"
                   "d2bd28dba6bd52c4c9bd5a1286bd1cbdafbd7fa641bd01fea9bdd8"
                   "4a4ebd07bdb3bd6087a5bd68e70cbeec2816be4697b3bd3f0570bd"
                   "a9339cbe525f68bd74a807be7ec88abdd2980dbe28f0c2bd7ce10c"
                   "be7f91fdbdee0fd1bdda1fd9bd284bfdbd2ad8d8bd5a42f7bd",
        "k": "000040400000000000000000", "l": "def94e3d0000000000000000",
        "n": "000044470000000000000000",
        "d": "54d26642cad9e3416aabea41", "key": "04aeb16524c70b97",
        "active": "010101",
    },
    "mixed": {
        "rewards": "ecec87be79c742bfd09e39bf9c0d1ebe4babb4bf800261bff286c7"
                   "bda075d3bd93d91abcf52307bc070817be937336be5c99a9bd4a92"
                   "8ebe2a44c8be93550fbe0e7725bee8a309be4f9c01be643b17be8e"
                   "c648be26d344bd861a84be262245bfa438b5bd503c33be5f51a2bd"
                   "1cfb78bdd43191bec5ceadbebc4beebda4603ebec52030bffb01db"
                   "bd083a2cbf1a2e2fbf10c529bff7e12fbfc52030bfbc942fbf",
        "k": "000000000000000000001643", "l": "0000000000000000d07d853d",
        "n": "00000000000000000000c447",
        "d": "54d26642cad9e3416aabea41", "key": "04aeb16524c70b97",
        "active": "010101",
    },
    # homogeneous plan with churn_rate=0.4, leave_rate=0.2, lam_tasks=30
    "churn": {
        "rewards": "ed7b13beb7b8a4bd96c715bfa64296bd1464a3bd19989fbd9ab80d"
                   "bed09fa5bdce4dcabdd82d9cbdc4cb92bdfb533cbe6c098ebe24a9"
                   "c6bd8b7bc0bd81278fbd70b5a2bd5394a8bdd4d67fbd37004cbee8"
                   "f531bde0e6cebd4459b9bdb5a4ddbd14accfbd1c71dcbd3a5f97bd"
                   "a777a6be61fa12be362459bdb95511bec402c8bda23609beb07042"
                   "bef4be3fbf4293cabda0988bbd4efff5bdf319f1bd663e12be",
        "k": "000000000000000000008041", "l": "000000000000000000000000",
        "n": "000000000000000030af2746",
        "d": "0d0253422049a441fe1e9842", "key": "c1ee0d7e351a63cb",
        "active": "000101",
    },
}


def _golden_rollout(env, n_ue=3, seed=3, steps=40):
    s = env.reset(jax.random.PRNGKey(seed))
    rng = np.random.RandomState(0)
    feas = np.asarray(env.params.feasible)
    valid = [np.where(feas[ue])[0] for ue in range(n_ue)]
    rewards = []
    for _ in range(steps):
        b = jnp.asarray([rng.choice(v) for v in valid], jnp.int32)
        c = jnp.asarray(rng.randint(0, env.n_channels, n_ue), jnp.int32)
        p = jnp.asarray(rng.uniform(0.05, 0.5, n_ue), jnp.float32)
        s, r, d, _ = env.step(s, _acts(b, c, p))
        rewards.append(np.float32(r))
    return np.asarray(rewards, np.float32), s


def _golden_check(env, g, name):
    rewards, s = _golden_rollout(env)
    assert rewards.tobytes().hex() == g["rewards"], name
    for field in ("k", "l", "n", "d"):
        got = np.asarray(getattr(s, field), np.float32).tobytes().hex()
        assert got == g[field], (name, field)
    assert np.asarray(s.key, np.uint32).tobytes().hex() == g["key"], name
    got_act = np.asarray(s.active, np.uint8).tobytes().hex()
    assert got_act == g["active"], name


@pytest.mark.parametrize("pool_kwargs", [
    {},                                         # no pool argument at all
    {"pool": None},
    {"pool": "single"},                         # 1-server EdgePool
], ids=["default", "none", "edgepool1"])
@pytest.mark.parametrize("churn_kwargs", [
    {},                                         # the static entry point
    {"churn_rate": 0.0, "leave_rate": 0.0},     # zero-churn dynamic request
], ids=["static", "zero_churn"])
def test_env_matches_prechurn_golden(mixed_fleet, churn_kwargs, pool_kwargs):
    kw = dict(churn_kwargs)
    if pool_kwargs:
        kw["pool"] = single_server() if pool_kwargs["pool"] == "single" \
            else None
    plan = cnn_split_table(make_resnet18(101), 224)
    for name, env in [
            ("homo", MECEnv(make_env_params(plan, n_ue=3, n_channels=2,
                                            **kw))),
            ("mixed", MECEnv(make_env_params(mixed_fleet, n_channels=2,
                                             **kw)))]:
        assert not env.dynamic          # both rates 0.0 => static machinery
        assert not env.multi_server     # one paper server => no routing
        assert env.action_space.names == ("split", "channel", "power")
        assert env.obs_dim == 4 * env.params.n_ue
        _golden_check(env, _GOLD[name], name)


@pytest.mark.parametrize("pool", [None, "single"], ids=["none", "edgepool1"])
def test_churn_env_matches_preactionspace_golden(pool):
    """The dynamic env through the actions-dict API (and through a
    1-server EdgePool) reproduces the PR-2 churn trajectories bit-for-bit,
    PRNG stream and final membership mask included."""
    plan = cnn_split_table(make_resnet18(101), 224)
    env = MECEnv(make_env_params(
        plan, n_ue=3, n_channels=2, churn_rate=0.4, leave_rate=0.2,
        lam_tasks=30.0, pool=single_server() if pool else None))
    assert env.dynamic and not env.multi_server
    _golden_check(env, _GOLD["churn"], "churn")


# Golden per-UE feature rows (hex float32 (N, OBS_UE_DIM) matrices) pinned
# at the PR-4 introduction of `observe_per_ue`: the homogeneous and mixed
# static fleets, a churned fleet with a planted standby UE (zeroed own
# features, live aggregates), and the mixed fleet through the 2-server
# demo pool. Any change to the feature layout, normalization, or the
# static fleets.py descriptors shows up here.
_GOLD_FEATS = {
    "homo": "295c6f3f0000000000000000cfb9133fcfb9133f0000803f3d0ad73e"
            "2a7b013e0000803f3b069c3d857a7a3e0000803f0000803f0000803f"
            "000000000000803f295c6f3fa627c53e0000c03f1f856b3f00000000"
            "0000000011d3913e11d3913e0000803f3d0ad73e2a7b013e0000803f"
            "3b069c3d857a7a3e0000803f0000803f0000803f000000000000803f"
            "295c6f3fa627c53e0000c03f3333733f00000000000000004430963e"
            "4430963e0000803f3d0ad73e2a7b013e0000803f3b069c3d857a7a3e"
            "0000803f0000803f0000803f000000000000803f295c6f3fa627c53e"
            "0000c03f",
    "mixed": "295c6f3f0000000000000000cfb9133fcfb9133f0000803f3d0ad73e"
             "2a7b013e0000803f3b069c3d857a7a3e0000803f0000803f0000803f"
             "000000000000803f295c6f3fa627c53e0000c03f1f856b3f00000000"
             "0000000011d3913e11d3913e0000803f9a99193f56248e40abaa2a3f"
             "877b0140f5bd863e0000803f0000803f0000803f000000000000803f"
             "295c6f3fa627c53e0000c03f3333733f00000000000000004430963e"
             "4430963e0000803f0ad7233ee510e93f0000803f09678c3f857a7a3e"
             "0000803f0000803f0000803f000000000000803f295c6f3fa627c53e"
             "0000c03f",
    "churn": "5555553f0000000000000000cfb9133fcfb9133f0000803f3d0ad73e"
             "2a7b013e0000803f3b069c3d857a7a3e0000803f0000803f0000803f"
             "00000000abaa2a3f9a99593ff1d1de3e0000803f0000000000000000"
             "000000000000000000000000000000003d0ad73e2a7b013e0000803f"
             "3b069c3d857a7a3e0000803f0000803f0000803f00000000abaa2a3f"
             "9a99593ff1d1de3e0000803fdedd5d3f00000000000000004430963e"
             "4430963e0000803f3d0ad73e2a7b013e0000803f3b069c3d857a7a3e"
             "0000803f0000803f0000803f00000000abaa2a3f9a99593ff1d1de3e"
             "0000803f",
    "pool2": "295c6f3f0000000000000000cfb9133fcfb9133f0000803f3d0ad73e"
             "2a7b013e0000803f3b069c3d857a7a3e0000803f9a99993f0000803f"
             "b1befe3e0000803f295c6f3fa627c53e0000403f1f856b3f00000000"
             "0000000011d3913e11d3913e0000803f9a99193f56248e40abaa2a3f"
             "877b0140f5bd863e0000803f9a99993f0000803fb1befe3e0000803f"
             "295c6f3fa627c53e0000403f3333733f00000000000000004430963e"
             "4430963e0000803f0ad7233ee510e93f0000803f09678c3f857a7a3e"
             "0000803f9a99993f0000803fb1befe3e0000803f295c6f3fa627c53e"
             "0000403f",
}


def _feat_hex(env, s):
    return np.asarray(env.observe_per_ue(s), np.float32).tobytes().hex()


def test_observe_per_ue_matches_golden(mixed_fleet):
    from repro.core.fleets import make_edge_pool
    from repro.env.mecenv import OBS_UE_DIM
    plan = cnn_split_table(make_resnet18(101), 224)
    cases = {
        "homo": MECEnv(make_env_params(plan, n_ue=3, n_channels=2)),
        "mixed": MECEnv(make_env_params(mixed_fleet, n_channels=2)),
        "pool2": MECEnv(make_env_params(mixed_fleet, n_channels=2,
                                        pool=make_edge_pool(2))),
    }
    for name, env in cases.items():
        assert env.ue_feat_dim == OBS_UE_DIM
        s = env.reset(jax.random.PRNGKey(3))
        assert env.observe_per_ue(s).shape == (3, OBS_UE_DIM)
        assert _feat_hex(env, s) == _GOLD_FEATS[name], name


def test_observe_per_ue_churn_matches_golden():
    """A planted standby UE: zeroed own features + zero activity flag,
    static descriptors intact, aggregates over the two live UEs."""
    plan = cnn_split_table(make_resnet18(101), 224)
    env = MECEnv(make_env_params(plan, n_ue=3, n_channels=2,
                                 churn_rate=0.4, leave_rate=0.2,
                                 lam_tasks=30.0))
    s = env.reset(jax.random.PRNGKey(3))
    s = s._replace(active=jnp.asarray([True, False, True]))
    assert _feat_hex(env, s) == _GOLD_FEATS["churn"]


# Golden entity-set observations (hex float32 blocks) pinned at the PR-5
# introduction of `observe_entities`: the homogeneous single-server fleet
# (degenerate [[1,1,0]] geometry, zero edge-service column), and the mixed
# fleet through the 2- and 3-server demo pools. Any change to the entity
# feature layout, the geometry encoding (slowness, not speed), or the
# normalization constants shows up here.
_GOLD_ENTITIES = {
    "homo.ue": "295c6f3f0000000000000000cfb9133fcfb9133f0000803f3d0ad73e"
               "2a7b013e0000803f3b069c3d857a7a3e0000803f295c6f3fa627c53e"
               "0000c03f1f856b3f000000000000000011d3913e11d3913e0000803f"
               "3d0ad73e2a7b013e0000803f3b069c3d857a7a3e0000803f295c6f3f"
               "a627c53e0000c03f3333733f00000000000000004430963e4430963e"
               "0000803f3d0ad73e2a7b013e0000803f3b069c3d857a7a3e0000803f"
               "295c6f3fa627c53e0000c03f",
    "homo.server": "0000803f0000803f000000000000c03f",
    "homo.edge": "cfb9133f963a913f0000000011d3913e1c57b83f000000004430963e"
                 "edb4b63f00000000",
    "pool2.ue": "295c6f3f0000000000000000cfb9133fcfb9133f0000803f3d0ad73e"
                "2a7b013e0000803f3b069c3d857a7a3e0000803f295c6f3fa627c53e"
                "0000403f1f856b3f000000000000000011d3913e11d3913e0000803f"
                "9a99193f56248e40abaa2a3f877b0140f5bd863e0000803f295c6f3f"
                "a627c53e0000403f3333733f00000000000000004430963e4430963e"
                "0000803f0ad7233ee510e93f0000803f09678c3f857a7a3e0000803f"
                "295c6f3fa627c53e0000403f",
    "pool2.server": "0000803f0000803f000000000000403f3333b33f0000803f"
                    "aaaa2a3f0000403f",
    "pool2.edge": "cfb9133f963a913f00000000efd04e3fa0337d3fa0013e3b11d3913e"
                  "1c57b83f000000007d27cc3e8db3a53f74ad89404430963eedb4b63f"
                  "000000009243d23e6611a43fa0013e3b",
    "pool3.server": "0000803f0000803f000000000000003f3333b33f0000803f"
                    "aaaa2a3f0000003f6666e63fcdcc4c3f555585400000003f",
    "pool3.edge": "cfb9133f963a913f00000000efd04e3f9f337d3fa0013e3b07f4843f"
                  "ed51343f4571943c11d3913e1c57b83f000000007d27cc3e8cb3a53f"
                  "74ad8940f53d033fa0d9723f061fd7414430963eedb4b63f00000000"
                  "9243d23e6611a43fa0013e3b702b073fb13c703f4571943c",
}


def test_observe_entities_matches_golden(mixed_fleet):
    from repro.core.fleets import make_edge_pool
    from repro.env.mecenv import OBS_ENT_EDGE, OBS_ENT_SRV, OBS_ENT_UE
    plan = cnn_split_table(make_resnet18(101), 224)
    cases = {
        "homo": (MECEnv(make_env_params(plan, n_ue=3, n_channels=2)), 1),
        "pool2": (MECEnv(make_env_params(mixed_fleet, n_channels=2,
                                         pool=make_edge_pool(2))), 2),
        "pool3": (MECEnv(make_env_params(mixed_fleet, n_channels=2,
                                         pool=make_edge_pool(3))), 3),
    }
    for name, (env, n_srv) in cases.items():
        s = env.reset(jax.random.PRNGKey(3))
        obs = env.observe_entities(s)
        assert obs["ue"].shape == (3, OBS_ENT_UE)
        assert obs["server"].shape == (n_srv, OBS_ENT_SRV)
        assert obs["edge"].shape == (3, n_srv, OBS_ENT_EDGE)
        for block in ("ue", "server", "edge"):
            key = f"{name}.{block}"
            if key not in _GOLD_ENTITIES:
                continue
            got = np.asarray(obs[block], np.float32).tobytes().hex()
            assert got == _GOLD_ENTITIES[key], key
    # the single paper server is the degenerate [[1, 1, 0]] geometry and
    # its edge-service column is identically zero (instant edge)
    homo_obs = cases["homo"][0].observe_entities(
        cases["homo"][0].reset(jax.random.PRNGKey(3)))
    np.testing.assert_array_equal(np.asarray(homo_obs["server"])[0, :3],
                                  [1.0, 1.0, 0.0])
    np.testing.assert_array_equal(np.asarray(homo_obs["edge"])[:, :, 2],
                                  0.0)


def test_split_plan_invariants_enforced():
    from repro.core.split import _finalize
    rows = [(0.0, 0.0, 0.0, 0.0, 100.0, True),
            (2.0, 0.1, 0.0, 0.0, 50.0, True),
            (1.0, 0.1, 0.0, 0.0, 25.0, True),   # t_local not monotone
            (3.0, 0.2, 0.0, 0.0, 0.0, True)]
    with pytest.raises(ValueError):
        _finalize("bad", [1, 2], rows)
    rows_bad_bits = [(0.0, 0.0, 0.0, 0.0, 100.0, True),
                     (1.0, 0.1, 0.0, 0.0, 50.0, True),
                     (2.0, 0.2, 0.0, 0.0, 7.0, True)]  # f_bits[-1] != 0
    with pytest.raises(ValueError):
        _finalize("bad2", [1], rows_bad_bits)


def test_build_fleet_validation():
    plan = cnn_split_table(make_resnet18(101), 224)
    with pytest.raises(ValueError):
        build_fleet([])
    with pytest.raises(ValueError):
        build_fleet([plan, plan], [oh.JETSON_NANO])
    # tables built for one device can't be paired with another's profile
    with pytest.raises(ValueError, match="jetson-nano"):
        build_fleet([plan], [oh.IOT_SOC])
