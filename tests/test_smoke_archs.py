"""Per-architecture smoke tests: REDUCED variant of each assigned arch
(<=2 pattern-rounds of layers, d_model<=512, <=4 experts) runs one forward /
train step and a prefill+decode step on CPU; asserts shapes + finiteness."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.models import (apply_model, decode_step, init_params, loss_fn,
                          prefill)

B, S = 2, 24


def _batch(cfg):
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.n_aux_tokens:
        batch["aux_embeds"] = jnp.full(
            (B, cfg.n_aux_tokens, cfg.d_model), 0.01, jnp.float32)
    return batch


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    cfg = reduced(get_config(request.param))
    params = init_params(cfg, jax.random.PRNGKey(0))
    return request.param, cfg, params


def test_train_step_finite(arch_setup):
    arch, cfg, params = arch_setup
    loss, metrics = loss_fn(params, cfg, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert float(metrics["ce"]) > 0


def test_grads_finite(arch_setup):
    arch, cfg, params = arch_setup
    g = jax.grad(lambda p: loss_fn(p, cfg, _batch(cfg))[0])(params)
    leaves = jax.tree_util.tree_leaves(g)
    assert leaves
    assert all(bool(jnp.all(jnp.isfinite(x))) for x in leaves), arch


def test_prefill_decode_shapes(arch_setup):
    arch, cfg, params = arch_setup
    batch = _batch(cfg)
    logits, cache = prefill(params, cfg, batch["tokens"], attn_len=S + 4,
                            aux_embeds=batch.get("aux_embeds"))
    assert logits.shape == (B, cfg.vocab_size)
    tok = jnp.ones((B, 1), jnp.int32)
    lg, cache2 = decode_step(params, cfg, cache, tok, jnp.int32(S))
    assert lg.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(lg))), arch
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(cache2))


def test_decode_matches_full_forward(arch_setup):
    """Cache-based decode of token s must match position s of a full
    forward — exercises KV caches, ring buffers, SSM/RG-LRU states."""
    arch, cfg, params = arch_setup
    s = 17
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, s + 1), 0,
                              cfg.vocab_size)
    aux = None
    if cfg.n_aux_tokens:
        aux = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.n_aux_tokens, cfg.d_model)) * 0.1
    full_logits, _, _ = apply_model(params, cfg, toks, aux_embeds=aux,
                                    mode="train")
    _, cache = prefill(params, cfg, toks[:, :s], attn_len=s + 1,
                       aux_embeds=aux)
    dec, _ = decode_step(params, cfg, cache, toks[:, s:s + 1], jnp.int32(s))
    ref = full_logits[:, s]
    rel = float(jnp.max(jnp.abs(ref - dec))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 2e-3, f"{arch} decode/full mismatch rel={rel}"


def test_multi_token_decode(arch_setup):
    """Three consecutive decode steps stay consistent with full forward."""
    arch, cfg, params = arch_setup
    s = 12
    toks = jax.random.randint(jax.random.PRNGKey(4), (B, s + 3), 0,
                              cfg.vocab_size)
    aux = None
    if cfg.n_aux_tokens:
        aux = jax.random.normal(
            jax.random.PRNGKey(5), (B, cfg.n_aux_tokens, cfg.d_model)) * 0.1
    full_logits, _, _ = apply_model(params, cfg, toks, aux_embeds=aux,
                                    mode="train")
    _, cache = prefill(params, cfg, toks[:, :s], attn_len=s + 3,
                       aux_embeds=aux)
    for i in range(3):
        dec, cache = decode_step(params, cfg, cache, toks[:, s + i:s + i + 1],
                                 jnp.int32(s + i))
        ref = full_logits[:, s + i]
        rel = float(jnp.max(jnp.abs(ref - dec))) / (
            float(jnp.max(jnp.abs(ref))) + 1e-9)
        assert rel < 5e-3, f"{arch} step {i} rel={rel}"


def test_sliding_window_cache():
    """Ring-buffer window cache: decode with window W only sees last W
    tokens — matches a full forward restricted to the window."""
    cfg = reduced(get_config("recurrentgemma-9b"))
    params = init_params(cfg, jax.random.PRNGKey(0))
    w = cfg.window
    s = w + 9  # prefill longer than the window
    toks = jax.random.randint(jax.random.PRNGKey(6), (B, s + 1), 0,
                              cfg.vocab_size)
    full_logits, _, _ = apply_model(params, cfg, toks, mode="train")
    _, cache = prefill(params, cfg, toks[:, :s], attn_len=s + 1)
    dec, _ = decode_step(params, cfg, cache, toks[:, s:s + 1], jnp.int32(s))
    ref = full_logits[:, s]
    rel = float(jnp.max(jnp.abs(ref - dec))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 2e-3, f"window cache mismatch rel={rel}"
