"""MAHPPO components: GAE vs naive, hybrid log-probs, masking, short
end-to-end training improves reward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cnn import make_resnet18
from repro.core.split import cnn_split_table
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl import nets
from repro.rl.gae import gae


def test_gae_matches_naive():
    T, E = 7, 2
    key = jax.random.PRNGKey(0)
    r = jax.random.normal(key, (T, E))
    v = jax.random.normal(jax.random.PRNGKey(1), (T, E))
    d = (jax.random.uniform(jax.random.PRNGKey(2), (T, E)) < 0.2)
    last_v = jax.random.normal(jax.random.PRNGKey(3), (E,))
    adv, ret = gae(r, v, d, last_v, gamma=0.9, lam=0.8)

    adv_naive = np.zeros((T, E))
    vs = np.concatenate([np.asarray(v), np.asarray(last_v)[None]], 0)
    dn = np.asarray(d, np.float32)
    rn = np.asarray(r)
    a_next = np.zeros(E)
    for t in reversed(range(T)):
        delta = rn[t] + 0.9 * vs[t + 1] * (1 - dn[t]) - vs[t]
        a_next = delta + 0.9 * 0.8 * (1 - dn[t]) * a_next
        adv_naive[t] = a_next
    np.testing.assert_allclose(np.asarray(adv), adv_naive, rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ret), adv_naive + np.asarray(v),
                               rtol=1e-5, atol=1e-5)


def _paper_space(n_b=5, n_c=2, p_max=0.5):
    from repro.rl.actionspace import (ContinuousHead, DiscreteHead,
                                      HybridActionSpace)
    return HybridActionSpace(
        (DiscreteHead("split", n_b), DiscreteHead("channel", n_c)),
        (ContinuousHead("power", 1e-4, p_max),))


def test_hybrid_logprob_consistent_with_sampling():
    """Monte-Carlo: average exp(logp) over categorical support sums to 1."""
    key = jax.random.PRNGKey(0)
    space = _paper_space()
    a = nets.init_actor(key, 8, space)
    obs = jax.random.normal(jax.random.PRNGKey(1), (8,))
    masks = {"split": jnp.array([True, True, False, True, True])}
    dist = nets.actor_forward(a, space, obs, masks)
    # masked action has ~zero probability
    pb = jax.nn.softmax(dist["split"])
    assert float(pb[2]) < 1e-6
    assert np.isclose(float(pb.sum()), 1.0, atol=1e-5)
    # log-prob factorizes over heads
    act = space.sample(jax.random.PRNGKey(2), dist)
    lp = space.log_prob(dist, act)
    mu, ls = dist["power"]["mu"], dist["power"]["log_std"]
    lp_manual = (jax.nn.log_softmax(dist["split"])[act["split"]]
                 + jax.nn.log_softmax(dist["channel"])[act["channel"]]
                 - 0.5 * ((act["power"] - mu) ** 2 / jnp.exp(2 * ls)
                          + 2 * ls + jnp.log(2 * jnp.pi)))
    assert np.isclose(float(lp), float(lp_manual), atol=1e-5)


def test_power_head_bounds_in_one_place():
    """The continuous head owns its bounds: execute() squashes into
    (0, p_max] and clip() clamps arbitrary physical values into
    [low, high] — the paths the policy and hand-written baselines share."""
    space = _paper_space()
    u = jnp.linspace(-10, 10, 50)
    p = space.execute({"split": 0, "channel": 0, "power": u})["power"]
    assert bool(jnp.all(p > 0)) and bool(jnp.all(p <= 0.5))
    raw = jnp.array([-1.0, 0.0, 0.2, 9.0])
    clipped = space.clip({"split": 0, "channel": 0, "power": raw})["power"]
    assert bool(jnp.all(clipped >= 1e-4)) and bool(jnp.all(clipped <= 0.5))
    np.testing.assert_allclose(np.asarray(clipped)[2], 0.2)


def test_update_clamps_batch_to_population():
    """M < cfg.batch must clamp the minibatch instead of letting
    jax.random.choice(..., replace=False) over-draw the population."""
    from repro.optim import adamw_init
    from repro.rl.mahppo import MAHPPOConfig, init_agent, make_train_fns
    plan = cnn_split_table(make_resnet18(101), 224)
    env = MECEnv(make_env_params(plan, n_ue=2, n_channels=2))
    cfg = MAHPPOConfig(iterations=1, horizon=16, n_envs=2, reuse=2,
                       batch=256)               # M = 16 << batch
    key = jax.random.PRNGKey(0)
    agent = init_agent(key, env)
    opt = adamw_init(agent)
    states = jax.vmap(env.reset)(jax.random.split(key, cfg.n_envs))
    iteration = make_train_fns(env, cfg)
    agent, opt, key, states, metrics = iteration(agent, opt, key, states)
    assert np.isfinite(float(metrics["reward_mean"]))
    assert np.isfinite(float(metrics["actor_loss"]))


def test_horizon_must_divide_evenly_across_envs():
    """horizon % n_envs != 0 used to silently drop the remainder frames
    (T = horizon // n_envs scan steps); the config now refuses it with
    an actionable message instead."""
    from repro.rl.mahppo import MAHPPOConfig
    with pytest.raises(ValueError, match="horizon"):
        MAHPPOConfig(horizon=100, n_envs=8)
    # exact multiples still construct fine
    assert MAHPPOConfig(horizon=96, n_envs=8).horizon == 96


def test_evaluate_policy_completion_weighted_math():
    """evaluate_policy's completion-weighted t_task/e_task against a
    hand-computed single-UE scenario: an obs-independent actor (all weights
    zero, biases pin the action) makes every frame identical, so the
    weighted means must equal the per-task overhead of that one action."""
    from repro.env.channel import channel_gain, uplink_rates
    from repro.rl.mahppo import evaluate_policy
    plan = cnn_split_table(make_resnet18(101), 224)
    env = MECEnv(make_env_params(plan, n_ue=1, n_channels=2,
                                 lam_tasks=500.0))   # queue never drains
    b_star, c_star, u_star = 1, 0, 0.7
    actor = nets.init_actor(jax.random.PRNGKey(0), env.obs_dim,
                            env.action_space)
    actor = jax.tree_util.tree_map(jnp.zeros_like, actor)
    # zeroed trunk => h = 0 => heads output exactly their final bias
    actor["heads"]["split"][-1]["b"] = jnp.zeros(
        (env.n_actions_b,)).at[b_star].set(5.0)
    actor["heads"]["channel"][-1]["b"] = jnp.zeros(
        (env.n_channels,)).at[c_star].set(5.0)
    actor["heads"]["power"][-1]["b"] = jnp.array([u_star, -1.0])
    agent = {"actors": jax.tree_util.tree_map(lambda x: x[None], actor)}

    res = evaluate_policy(env, agent, frames=4)

    # hand-computed Eq. 7/8 overhead of (b*, c*, sigmoid(u*) p_max) at the
    # eval-mode distance d=50 with no interference (single UE)
    p_tx = float(jax.nn.sigmoid(u_star) * env.params.p_max)
    g = channel_gain(jnp.array([50.0]), env.params.pathloss)
    r = float(jnp.maximum(uplink_rates(
        jnp.array([p_tx]), jnp.array([c_star]), g, jnp.array([True]),
        omega=env.params.omega, sigma=env.params.sigma)[0], 1.0))
    l_b = float(env.params.l_new[0, b_star])
    n_b = float(env.params.n_new[0, b_star])
    t_expect = l_b + n_b / r
    e_expect = l_b * float(env.params.p_compute[0]) + (n_b / r) * p_tx
    assert res["t_task"] == pytest.approx(t_expect, rel=1e-5)
    assert res["e_task"] == pytest.approx(e_expect, rel=1e-5)
    # each frame completes floor(t0/t_task) whole tasks plus the carry-over
    assert res["completed"] == pytest.approx(
        float(env.params.t0) / t_expect, abs=1.0)


@pytest.mark.slow
def test_mahppo_improves_reward():
    from repro.rl.mahppo import MAHPPOConfig, train_mahppo
    plan = cnn_split_table(make_resnet18(101), 224)
    env = MECEnv(make_env_params(plan, n_ue=3, n_channels=2))
    cfg = MAHPPOConfig(iterations=12, horizon=512, n_envs=4, reuse=4)
    agent, hist = train_mahppo(env, cfg, seed=0)
    first = np.mean([h["reward_mean"] for h in hist[:3]])
    last = np.mean([h["reward_mean"] for h in hist[-3:]])
    assert last > first  # rewards are negative; closer to 0 is better
