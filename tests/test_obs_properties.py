"""Properties of the per-UE featurized observation (`observe_per_ue`) and
the entity-set observation (`observe_entities`).

Two layers, mirroring tests/test_churn_properties.py:
 * seeded tests that always run (no hypothesis needed), and
 * hypothesis-driven variants over arbitrary states/permutations/masks
   when hypothesis is installed (CI installs it).

The contracts the weight-shared policy relies on:
 1. permutation EQUIVARIANCE: reordering the fleet (tables, profiles, and
    state) reorders the feature rows and changes nothing else — the
    policy is a set function over UEs.
 2. standby UEs get ZEROED own-features and a zero activity flag, but
    their static descriptors stay and the fleet aggregates are computed
    over the ACTIVE members only (identical in every row).
 3. the feature dimension is a constant: invariant to fleet size N, edge
    pool size E, and the widest action count B_max.

And the ones the entity-set route scorer adds:
 4. SERVER-permutation equivariance: reordering the pool permutes the
    server rows and the edge columns, leaves the UE rows bitwise intact,
    and permutes the scorer's route-logit columns while leaving every
    other head's distribution (numerically) unchanged.
 5. entity dimensions are constants independent of N, E, and B_max.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.configs import get_config
from repro.core import overhead as oh
from repro.core.cnn import make_resnet18
from repro.core.fleets import make_edge_pool
from repro.core.split import build_fleet, cnn_split_table, \
    transformer_split_table
from repro.env.mecenv import (MECEnv, OBS_UE_ACT, OBS_UE_DIM, OBS_UE_OWN,
                              make_env_params)

_STATIC_LO = OBS_UE_OWN + OBS_UE_ACT            # device+pool block start
_FLEET_LO = OBS_UE_DIM - 4                      # mean-field block start


@pytest.fixture(scope="module")
def plans():
    cnn = cnn_split_table(make_resnet18(101), 224)
    cnn_iot = cnn_split_table(make_resnet18(101), 224, dev=oh.IOT_SOC)
    tf_small = transformer_split_table(get_config("qwen3-1.7b"),
                                       ue_dev=oh.PHONE_NPU, n_points=2)
    return [(cnn, oh.JETSON_NANO), (tf_small, oh.PHONE_NPU),
            (cnn_iot, oh.IOT_SOC)]


def _env(plans, order, **kw):
    picked = [plans[i] for i in order]
    fleet = build_fleet([p for p, _ in picked], [d for _, d in picked])
    return MECEnv(make_env_params(fleet, n_channels=2, **kw))


def _rand_state(env, seed, active=None):
    rng = np.random.RandomState(seed)
    n = env.params.n_ue
    s = env.reset(jax.random.PRNGKey(seed))
    return s._replace(
        k=jnp.asarray(rng.uniform(0, 300, n), jnp.float32),
        l=jnp.asarray(rng.uniform(0, 0.5, n), jnp.float32),
        n=jnp.asarray(rng.uniform(0, 2e6, n), jnp.float32),
        d=jnp.asarray(rng.uniform(1, 100, n), jnp.float32),
        active=jnp.asarray(np.ones(n, bool) if active is None
                           else np.asarray(active)))


def _perm_check(plans, perm, seed):
    """observe_per_ue(permuted fleet, permuted state) ==
    permuted observe_per_ue(fleet, state): bitwise on the per-UE blocks;
    the mean-field aggregates are only close-to-equal, since f32 summation
    order legitimately changes under the permutation (last-ulp effects)."""
    env = _env(plans, [0, 1, 2])
    env_p = _env(plans, perm)
    s = _rand_state(env, seed)
    idx = np.asarray(perm)
    s_p = s._replace(k=s.k[idx], l=s.l[idx], n=s.n[idx], d=s.d[idx],
                     active=s.active[idx])
    f = np.asarray(env.observe_per_ue(s))
    f_p = np.asarray(env_p.observe_per_ue(s_p))
    np.testing.assert_array_equal(f_p[:, :_FLEET_LO], f[idx, :_FLEET_LO])
    np.testing.assert_allclose(f_p[:, _FLEET_LO:], f[idx, _FLEET_LO:],
                               rtol=1e-6, atol=1e-7)


def _standby_check(plans, mask, seed):
    """Inactive rows: zeroed own block + zero flag, static block intact,
    fleet aggregates over active members only and equal in every row."""
    env = _env(plans, [0, 1, 2], churn_rate=0.2, leave_rate=0.1)
    mask = np.asarray(mask, bool)
    s = _rand_state(env, seed, active=mask)
    f = np.asarray(env.observe_per_ue(s))
    f_all = np.asarray(env.observe_per_ue(
        s._replace(active=jnp.ones(3, bool))))
    assert np.all(f[~mask, :OBS_UE_OWN] == 0.0)
    assert np.all(f[~mask, OBS_UE_OWN] == 0.0)          # activity flag
    assert np.all(f[mask, OBS_UE_OWN] == 1.0)
    # static descriptors don't depend on membership
    np.testing.assert_array_equal(f[:, _STATIC_LO:_FLEET_LO],
                                  f_all[:, _STATIC_LO:_FLEET_LO])
    # aggregates: identical across rows, computed over active UEs only
    agg = f[:, _FLEET_LO:]
    np.testing.assert_array_equal(agg, np.broadcast_to(agg[0], agg.shape))
    n_act = max(mask.sum(), 1)
    k = np.asarray(s.k, np.float64)
    d = np.asarray(s.d, np.float64)
    lam = float(env.params.lam_tasks)
    np.testing.assert_allclose(agg[0, 0], mask.sum() / 3, rtol=1e-6)
    np.testing.assert_allclose(
        agg[0, 1], (k * mask).sum() / (n_act * max(lam, 1.0)), rtol=1e-5)
    np.testing.assert_allclose(
        agg[0, 2], (d * mask).sum() / (n_act * 100.0), rtol=1e-5)


def _server_perm_check(plans, perm, seed):
    """observe_entities(permuted pool, state) == column/row-permuted
    observe_entities(pool, state): UE rows bitwise intact, server rows
    and edge columns permuted; route logits permute their columns while
    the other heads' distributions stay (numerically) put."""
    from repro.core.fleets import EdgePool, make_edge_pool
    from repro.rl import nets
    from repro.rl.mahppo import init_agent
    pool = make_edge_pool(3)
    pool_p = EdgePool(tuple(pool.servers[i] for i in perm))
    env = _env(plans, [0, 1, 2], pool=pool)
    env_p = _env(plans, [0, 1, 2], pool=pool_p)
    s = _rand_state(env, seed)
    idx = np.asarray(perm)
    f = jax.tree_util.tree_map(np.asarray, env.observe_entities(s))
    f_p = jax.tree_util.tree_map(np.asarray, env_p.observe_entities(s))
    np.testing.assert_array_equal(f_p["ue"], f["ue"])
    np.testing.assert_array_equal(f_p["server"], f["server"][idx])
    np.testing.assert_array_equal(f_p["edge"], f["edge"][:, idx])
    # the same scorer parameters on both: route columns permute, the
    # other heads see an identical (attention-pooled) context
    agent = init_agent(jax.random.PRNGKey(0), env, entity_policy=True)
    space = env.action_space
    masks = space.broadcast_masks(env.action_masks(), 3)
    d = nets.entity_actor_forward(agent["entity_actor"], space,
                                  env.observe_entities(s), masks)
    d_p = nets.entity_actor_forward(agent["entity_actor"], space,
                                    env_p.observe_entities(s), masks)
    np.testing.assert_array_equal(np.asarray(d_p["route"]),
                                  np.asarray(d["route"])[:, idx])
    for head in ("split", "channel"):
        np.testing.assert_allclose(np.asarray(d_p[head]),
                                   np.asarray(d[head]), rtol=1e-5,
                                   atol=1e-6)
    np.testing.assert_allclose(np.asarray(d_p["power"]["mu"]),
                               np.asarray(d["power"]["mu"]), rtol=1e-5,
                               atol=1e-6)


def test_permutation_equivariant_seeded(plans):
    for perm in ([1, 0, 2], [2, 1, 0], [1, 2, 0]):
        for seed in (0, 7):
            _perm_check(plans, perm, seed)


def test_server_permutation_equivariant_seeded(plans):
    for perm in ([1, 0, 2], [2, 1, 0], [1, 2, 0]):
        for seed in (0, 7):
            _server_perm_check(plans, perm, seed)


def test_entity_dims_invariant_to_n_e_and_tables(plans):
    from repro.core.fleets import make_edge_pool
    from repro.env.mecenv import OBS_ENT_EDGE, OBS_ENT_SRV, OBS_ENT_UE
    for order in ([0], [0, 1, 2], [1, 1, 2, 0, 2, 1]):
        for n_servers in (1, 2, 3):
            pool = make_edge_pool(n_servers) if n_servers > 1 else None
            env = _env(plans, order, pool=pool)
            obs = env.observe_entities(env.reset(jax.random.PRNGKey(0)))
            assert obs["ue"].shape == (len(order), OBS_ENT_UE)
            assert obs["server"].shape == (n_servers, OBS_ENT_SRV)
            assert obs["edge"].shape == (len(order), n_servers,
                                         OBS_ENT_EDGE)
            assert env.entity_dims == {"ue": OBS_ENT_UE,
                                       "server": OBS_ENT_SRV,
                                       "edge": OBS_ENT_EDGE}


def test_standby_rows_zeroed_seeded(plans):
    for mask in ([True, False, True], [False, False, True],
                 [False, False, False]):
        for seed in (3, 11):
            _standby_check(plans, mask, seed)


def test_feature_dim_invariant_to_n_e_and_tables(plans):
    """One constant feature dimension across fleet sizes, pool sizes, and
    action-table widths — the transfer precondition."""
    dims = set()
    for order in ([0], [0, 1, 2], [1, 1, 2, 0, 2, 1]):
        for n_servers in (1, 2, 3):
            pool = make_edge_pool(n_servers) if n_servers > 1 else None
            env = _env(plans, order, pool=pool)
            s = env.reset(jax.random.PRNGKey(0))
            f = env.observe_per_ue(s)
            assert f.shape == (len(order), env.ue_feat_dim)
            dims.add(int(f.shape[1]))
    # churn env too: same rows, no appended churn features
    env = _env(plans, [0, 1, 2], churn_rate=0.3, leave_rate=0.2)
    dims.add(int(env.observe_per_ue(
        env.reset(jax.random.PRNGKey(0))).shape[1]))
    assert dims == {OBS_UE_DIM}


if given is not None:
    # keyword-form @given so the module-scoped `plans` fixture still
    # resolves through pytest (positional strategies would shadow it)
    @settings(max_examples=15, deadline=None)
    @given(perm=st.permutations([0, 1, 2]), seed=st.integers(0, 2**31 - 1))
    def test_permutation_equivariant_property(plans, perm, seed):
        _perm_check(plans, list(perm), seed)

    @settings(max_examples=15, deadline=None)
    @given(mask=st.lists(st.booleans(), min_size=3, max_size=3),
           seed=st.integers(0, 2**31 - 1))
    def test_standby_rows_zeroed_property(plans, mask, seed):
        _standby_check(plans, mask, seed)

    @settings(max_examples=10, deadline=None)
    @given(perm=st.permutations([0, 1, 2]),
           seed=st.integers(0, 2**31 - 1))
    def test_server_permutation_equivariant_property(plans, perm, seed):
        _server_perm_check(plans, list(perm), seed)
