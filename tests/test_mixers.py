"""Mixer-level correctness: SSD vs naive recurrence, RG-LRU scan vs
step-by-step, MoE dispatch properties, flash attention vs naive softmax."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig


def _ssm_cfg(chunk):
    return ModelConfig(
        name="t", family="ssm", n_layers=1, d_model=64, n_heads=2,
        n_kv_heads=1, d_ff=0, vocab_size=32, block_pattern=("mamba2",),
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=32,
                      chunk=chunk),
        param_dtype="float32", compute_dtype="float32")


def test_ssd_chunked_equals_naive_recurrence():
    """The chunked SSD algorithm must equal the step-by-step SSM."""
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(0)
    b, l, h, p, n = 2, 37, 4, 8, 16
    xh = jax.random.normal(key, (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, l, h)))
    a_log = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,))) * dt * 0.5
    B = jax.random.normal(jax.random.PRNGKey(3), (b, l, n))
    C = jax.random.normal(jax.random.PRNGKey(4), (b, l, n))

    y, hlast = ssd_chunked(xh, dt, a_log, B, C, chunk=8)

    # naive recurrence
    hs = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(l):
        a = jnp.exp(a_log[:, t])                     # (b,h)
        hs = hs * a[:, :, None, None] + jnp.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], B[:, t], xh[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", C[:, t], hs))
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_naive),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hlast), np.asarray(hs),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_ssd_chunk_size_invariance(chunk):
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(5)
    b, l, h, p, n = 1, 33, 2, 4, 8
    xh = jax.random.normal(key, (b, l, h, p))
    dt = jnp.ones((b, l, h)) * 0.5
    a_log = -0.3 * dt
    B = jax.random.normal(jax.random.PRNGKey(6), (b, l, n))
    C = jax.random.normal(jax.random.PRNGKey(7), (b, l, n))
    y_ref, h_ref = ssd_chunked(xh, dt, a_log, B, C, chunk=l)
    y, h = ssd_chunked(xh, dt, a_log, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_mamba_resume_state():
    """apply_mamba(x) == apply_mamba(x1) then resume apply_mamba(x2)."""
    from repro.models.ssm import apply_mamba, init_mamba
    cfg = _ssm_cfg(chunk=8)
    p = init_mamba(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, cfg.d_model)) * 0.3
    y_all, _ = apply_mamba(p, x, cfg)
    y1, st = apply_mamba(p, x[:, :11], cfg)
    y2, _ = apply_mamba(p, x[:, 11:], cfg, state=st)
    np.testing.assert_allclose(np.asarray(y_all[:, 11:]), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_rglru_scan_equals_stepwise():
    from repro.models.rglru import apply_rglru, decode_rglru, init_rglru
    cfg = ModelConfig(name="t", family="hybrid", n_layers=1, d_model=32,
                      n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=32,
                      block_pattern=("rec",), param_dtype="float32",
                      compute_dtype="float32")
    p = init_rglru(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 9, cfg.d_model)) * 0.5
    y_scan, st_final = apply_rglru(p, x, cfg)
    st = {"conv": jnp.zeros((2, 3, cfg.d_model)),
          "h": jnp.zeros((2, cfg.d_model))}
    outs = []
    for t in range(x.shape[1]):
        o, st = decode_rglru(p, x[:, t:t + 1], cfg, st)
        outs.append(o)
    y_step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_step),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st_final["h"]), np.asarray(st["h"]),
                               rtol=1e-4, atol=1e-4)


def test_moe_no_drop_equals_dense_expert_sum():
    """With capacity high enough for zero drops, MoE output equals the
    explicit per-token expert mixture."""
    from repro.models.moe import apply_moe, init_moe
    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=1, d_ff=64, vocab_size=32, block_pattern=("moe",),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=16,
                      capacity_factor=4.0),
        param_dtype="float32", compute_dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 32)) * 0.5
    out, aux = apply_moe(p, x, cfg)

    xf = x.reshape(-1, 32)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, 2)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xf)
    for e in range(4):
        h = jax.nn.silu(xf @ p["wg"][e]) * (xf @ p["wi"][e])
        y = h @ p["wo"][e]
        w = jnp.where(top_e == e, top_p, 0.0).sum(-1)
        ref += y * w[:, None]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, 32)),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_bounded():
    """Dropped tokens pass through (residual-only): output for dropped
    tokens is exactly the shared-expert (or zero) contribution."""
    from repro.models.moe import apply_moe, init_moe
    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=16, n_heads=2,
        n_kv_heads=1, d_ff=32, vocab_size=32, block_pattern=("moe",),
        moe=MoEConfig(n_experts=2, top_k=1, d_expert=8,
                      capacity_factor=0.01),  # capacity 1: most tokens drop
        param_dtype="float32", compute_dtype="float32")
    p = init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16))
    out, _ = apply_moe(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))
    # at most n_experts * capacity tokens got non-zero output
    nonzero = jnp.sum(jnp.any(out != 0, axis=-1))
    assert int(nonzero) <= 2  # 2 experts x capacity 1


def test_flash_attention_vs_naive():
    from repro.models.attention import flash_attention
    b, sq, sk, hkv, g, d = 2, 16, 48, 2, 3, 16
    q = jax.random.normal(jax.random.PRNGKey(0), (b, sq, hkv * g, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, sk, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, sk, hkv, d))
    qpos = jnp.broadcast_to(jnp.arange(sq) + 32, (b, sq))
    kpos = jnp.broadcast_to(jnp.arange(sk), (b, sk))
    out = flash_attention(q, k, v, q_positions=qpos, k_positions=kpos,
                          causal=True, chunk=16)
    # naive
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kk) * (d ** -0.5)
    mask = kpos[:, None, None, :] <= qpos[:, None, :, None]
    s = jnp.where(mask, s, -1e30)
    ref = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_qblock_invariance():
    from repro.models.attention import flash_attention
    b, sq, hkv, g, d = 1, 300, 2, 2, 16
    q = jax.random.normal(jax.random.PRNGKey(3), (b, sq, hkv * g, d))
    k = jax.random.normal(jax.random.PRNGKey(4), (b, sq, hkv, d))
    v = jax.random.normal(jax.random.PRNGKey(5), (b, sq, hkv, d))
    pos = jnp.broadcast_to(jnp.arange(sq), (b, sq))
    o1 = flash_attention(q, k, v, q_positions=pos, k_positions=pos,
                         causal=True, chunk=64, q_block=4096)
    o2 = flash_attention(q, k, v, q_positions=pos, k_positions=pos,
                         causal=True, chunk=64, q_block=128)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-5, atol=2e-5)
