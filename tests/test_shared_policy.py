"""Fleet-generalist shared policy (PR 4).

Three layers of guarantees:

1. The DEFAULT per-UE-actors path and the shared path are pinned against
   the goldens in tests/goldens/goldens.json (captured in-repo by
   scripts/capture_goldens.py at the PR-7 carry-fix recapture): the init
   key stream via tolerance-based per-leaf fingerprints (raw-byte shas of
   orthogonal init are LAPACK-build-dependent — the PR-6 cross-machine
   failures), and the full iteration (sample draws, log-probs, minibatch
   selection, optimizer math) via exact post-iteration shas, metrics
   bytes, and the final collection key.
2. The shared mode trains/evaluates end-to-end on static, churn, and
   multi-server envs; per-actor feasibility masks still bind.
3. A hand-computed 2-UE scenario where ONE shared parameter set must act
   differently per UE — via its feasibility mask on one head and purely
   via its feature row on another — guards the mask/feature broadcasting.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import golden_cases as gc
from repro.configs import get_config
from repro.core import overhead as oh
from repro.core.cnn import make_resnet18
from repro.core.fleets import make_edge_pool, make_mixed_fleet
from repro.core.split import build_fleet, cnn_split_table, \
    transformer_split_table
from repro.env.channel import channel_gain, uplink_rates
from repro.env.mecenv import (MECEnv, OBS_UE_ACT, OBS_UE_OWN,
                              make_env_params)
from repro.optim import adamw_init
from repro.rl import nets
from repro.rl.mahppo import (MAHPPOConfig, evaluate_policy, init_agent,
                             make_train_fns, train_mahppo)


_tree_sha = gc.tree_sha


@pytest.fixture(scope="module")
def mixed_fleet():
    cnn = cnn_split_table(make_resnet18(101), 224)
    cnn_iot = cnn_split_table(make_resnet18(101), 224, dev=oh.IOT_SOC)
    tf_small = transformer_split_table(get_config("qwen3-1.7b"),
                                       ue_dev=oh.PHONE_NPU, n_points=2)
    return build_fleet([cnn, tf_small, cnn_iot],
                       [oh.JETSON_NANO, oh.PHONE_NPU, oh.IOT_SOC])


# Training goldens (tests/goldens/goldens.json, recaptured by
# scripts/capture_goldens.py at the PR-7 carry fix) for init_agent + one
# jitted iteration on the 3-UE mixed fleet, with
# MAHPPOConfig(horizon=64, n_envs=2, reuse=2, batch=32), PRNGKey(0):
# a tolerance-based per-leaf init fingerprint (machine-robust across
# LAPACK builds) plus EXACT post-iteration sha, metrics bytes, and key.
_GOLD_TRAIN = gc.load_goldens()["training"]


def _check_train_golden(case):
    got, init_tree = gc.train_capture(case, with_init_tree=True)
    g = _GOLD_TRAIN[case]
    assert gc.fingerprint_close(got["init_fp"], g["init_fp"]), \
        f"{case}: init key stream / param layout drifted"
    assert got["post_sha"] == g["post_sha"], case
    assert got["metrics"] == g["metrics"], case
    assert got["key"] == g["key"], case
    return init_tree


def _env_for(name, fleet):
    if name == "pool":
        return MECEnv(make_env_params(fleet, n_channels=2,
                                      pool=make_edge_pool(2)))
    if name == "churn":
        return MECEnv(make_env_params(fleet, n_channels=2,
                                      churn_rate=0.3, leave_rate=0.2))
    return MECEnv(make_env_params(fleet, n_channels=2))


@pytest.mark.parametrize("name", ["mixed", "pool", "churn"])
def test_per_ue_actors_path_bitwise_unchanged_from_pr3(mixed_fleet, name):
    """shared_policy=False must be the captured per-UE code path EXACTLY:
    same init key stream (tolerance fingerprint), same sample draws,
    log-probs/updates, and final collection key (exact bytes). The
    fixture env and the manifest env must agree structurally too."""
    env = _env_for(name, mixed_fleet)
    init_tree = _check_train_golden(f"per_ue.{name}")
    # the fixture env IS the manifest env: the same init on it matches
    agent = init_agent(jax.random.PRNGKey(0), env)
    assert _tree_sha(agent) == _tree_sha(init_tree)


@pytest.mark.parametrize("name", ["mixed", "pool", "churn"])
def test_shared_policy_path_bitwise_unchanged_from_pr4(mixed_fleet, name):
    """shared_policy=True must be the captured shared code path EXACTLY
    through the entity-set refactor: same init key stream (tolerance
    fingerprint), same sample draws, log-probs/updates, and final
    collection key (exact bytes)."""
    env = _env_for(name, mixed_fleet)
    init_tree = _check_train_golden(f"shared.{name}")
    agent = init_agent(jax.random.PRNGKey(0), env, shared_policy=True)
    assert _tree_sha(agent) == _tree_sha(init_tree)


@pytest.mark.parametrize("name", ["mixed", "pool", "churn"])
def test_shared_policy_trains_on_every_env_kind(mixed_fleet, name):
    """One jitted shared-policy iteration end-to-end; the agent is a
    single actor (no leading fleet axis) and metrics are finite."""
    env = _env_for(name, mixed_fleet)
    cfg = MAHPPOConfig(iterations=1, horizon=64, n_envs=2, reuse=1,
                       batch=32, shared_policy=True)
    key = jax.random.PRNGKey(0)
    agent = init_agent(key, env, shared_policy=True)
    assert "actor" in agent and "actors" not in agent
    # one parameter set: trunk input is the per-UE feature row, 2-D weight
    assert agent["actor"]["trunk"][0]["w"].shape == (env.ue_feat_dim, 256)
    opt = adamw_init(agent)
    states = jax.vmap(env.reset)(jax.random.split(key, cfg.n_envs))
    iteration = make_train_fns(env, cfg)
    agent, opt, key, states, metrics = iteration(agent, opt, key, states)
    assert np.isfinite(float(metrics["reward_mean"]))
    res = evaluate_policy(env, agent, frames=8)
    assert np.isfinite(res["t_task"]) and np.isfinite(res["reward"])


def test_shared_sampling_respects_per_actor_masks(mixed_fleet):
    """The weight-shared actor still draws only feasible actions per UE:
    UE1's padded split slots (3, 4) are never sampled even though the same
    parameters happily sample them for the unconstrained UEs."""
    env = MECEnv(make_env_params(mixed_fleet, n_channels=2))
    space = env.action_space
    actor = nets.init_actor(jax.random.PRNGKey(0), env.ue_feat_dim, space)
    feats = env.observe_per_ue(env.reset(jax.random.PRNGKey(1)))
    masks = space.broadcast_masks(env.action_masks(), env.params.n_ue)
    dist = nets.shared_actor_forward(actor, space, feats, masks)
    mask = np.asarray(env.action_masks()["split"])
    for seed in range(200):
        keys = jax.random.split(jax.random.PRNGKey(seed), env.params.n_ue)
        a = jax.vmap(space.sample)(keys, dist, masks)
        for ue, b in enumerate(np.asarray(a["split"])):
            assert mask[ue, int(b)], (ue, int(b))


def test_param_count_constant_in_fleet_size():
    """The whole point of the shared policy: O(1) parameters in N (per-UE
    actors are O(N)), and the feature dimension is N/E-invariant so the
    SAME agent evaluates on a bigger fleet zero-shot."""
    counts = {}
    for n in (2, 4, 8):
        env = MECEnv(make_env_params(make_mixed_fleet(n_ue=n),
                                     n_channels=2))
        sh = init_agent(jax.random.PRNGKey(0), env, shared_policy=True)
        pu = init_agent(jax.random.PRNGKey(0), env)
        counts[n] = (nets.param_count(sh), nets.param_count(pu))
    (s2, p2), (s4, p4), (s8, p8) = counts[2], counts[4], counts[8]
    assert s2 == s4 == s8                       # shared: constant in N
    assert p8 > p4 > p2                         # per-UE: grows with N
    assert s8 < p8


def test_shared_agent_transfers_across_fleet_size_and_pool():
    """An agent initialized for the 4-UE pool env evaluates UNMODIFIED on
    an 8-UE fleet and on a different 2-server layout (shapes line up
    because the feature dim is N/E-independent; route head needs equal E)."""
    pool = make_edge_pool(2)
    env4 = MECEnv(make_env_params(make_mixed_fleet(n_ue=4), n_channels=2,
                                  pool=pool))
    agent = init_agent(jax.random.PRNGKey(0), env4, shared_policy=True)
    env8 = MECEnv(make_env_params(make_mixed_fleet(n_ue=8), n_channels=2,
                                  pool=pool))
    # same E (the route head's width must match) but a different LAYOUT:
    # the GPU tier near the cell center, the v5e far and bandwidth-starved
    from repro.core.fleets import EdgePool
    alt = EdgePool((oh.ServerProfile.from_device(oh.EDGE_GPU),
                    oh.ServerProfile.from_device(oh.TPU_V5E,
                                                 dist_scale=1.6,
                                                 bw_scale=0.7)))
    env_alt = MECEnv(make_env_params(make_mixed_fleet(n_ue=4),
                                     n_channels=2, pool=alt))
    for env in (env8, env_alt):
        res = evaluate_policy(env, agent, frames=4)
        assert np.isfinite(res["t_task"]) and np.isfinite(res["e_task"])


def test_evaluate_policy_shared_mode_hand_computed():
    """2-UE fleet, ONE shared parameter set, hand-built weights:

    * the split head's logits are pure bias — UE0 takes slot 3, but UE1's
      feasibility mask forbids slots 3/4 so its mode falls to slot 1: the
      mask alone differentiates the action.
    * the channel head reads the feasible-fraction FEATURE through a
      saturated tanh threshold — UE0 (all-feasible CNN table) goes to
      channel 0, UE1 (padded transformer table) to channel 1: the feature
      row alone differentiates the action.

    With both UEs on different channels there is no interference and every
    frame is identical, so evaluate_policy's completion-weighted
    t_task/e_task must equal the hand-computed Eq. 7/8 overheads."""
    cnn = cnn_split_table(make_resnet18(101), 224)
    tf_small = transformer_split_table(get_config("qwen3-1.7b"),
                                       ue_dev=oh.PHONE_NPU, n_points=2)
    fleet = build_fleet([cnn, tf_small], [oh.JETSON_NANO, oh.PHONE_NPU])
    env = MECEnv(make_env_params(fleet, n_channels=2, lam_tasks=500.0))
    space = env.action_space
    feas = np.asarray(env.params.feasible)
    assert feas[0, 3] and not feas[1, 3] and not feas[1, 4]

    K, u_star = 100.0, 0.7
    j_feas = OBS_UE_OWN + OBS_UE_ACT + 2     # feasible-fraction feature
    feats = np.asarray(env.observe_per_ue(
        env.reset(jax.random.PRNGKey(0), eval_mode=True)))
    assert feats[0, j_feas] == 1.0
    assert 0.0 < feats[1, j_feas] < 0.8      # 4 of 6 slots feasible

    actor = nets.init_actor(jax.random.PRNGKey(0), env.ue_feat_dim, space)
    actor = jax.tree_util.tree_map(jnp.zeros_like, actor)
    # trunk: h[0] = tanh(K * tanh(K * (feas_frac - 0.8))) = ±1 exactly
    # (f32 tanh saturates); every other trunk unit stays 0
    actor["trunk"][0]["w"] = actor["trunk"][0]["w"].at[j_feas, 0].set(K)
    actor["trunk"][0]["b"] = actor["trunk"][0]["b"].at[0].set(-0.8 * K)
    actor["trunk"][1]["w"] = actor["trunk"][1]["w"].at[0, 0].set(K)
    # split: pure bias — 5.0 on slot 3 (UE1-infeasible), 4.0 on slot 1
    actor["heads"]["split"][-1]["b"] = jnp.zeros(
        (env.n_actions_b,)).at[3].set(5.0).at[1].set(4.0)
    # channel: z = tanh(±K) = ±1 -> logits (±5, ∓5)
    actor["heads"]["channel"][0]["w"] = \
        actor["heads"]["channel"][0]["w"].at[0, 0].set(K)
    actor["heads"]["channel"][-1]["w"] = \
        actor["heads"]["channel"][-1]["w"].at[0, 0].set(5.0).at[0, 1].set(-5.0)
    actor["heads"]["power"][-1]["b"] = jnp.array([u_star, -1.0])

    # the shared actor's modes differ per UE: mask-driven on split,
    # feature-driven on channel
    masks = space.broadcast_masks(env.action_masks(), 2)
    dist = nets.shared_actor_forward(
        actor, space, jnp.asarray(feats), masks)
    a_star = jax.vmap(space.mode)(dist, masks)
    np.testing.assert_array_equal(np.asarray(a_star["split"]), [3, 1])
    np.testing.assert_array_equal(np.asarray(a_star["channel"]), [0, 1])

    res = evaluate_policy(env, {"actor": actor}, frames=4)

    # hand-computed Eq. 7/8: both UEs at d=50, different channels => each
    # sees a clean channel at p_tx = sigmoid(u*) * p_max
    prm = env.params
    p_tx = float(jax.nn.sigmoid(u_star) * prm.p_max)
    g = channel_gain(jnp.full((2,), 50.0), prm.pathloss)
    r = np.asarray(jnp.maximum(uplink_rates(
        jnp.full((2,), p_tx), jnp.asarray([0, 1]), g,
        jnp.asarray([True, True]), omega=prm.omega, sigma=prm.sigma), 1.0))
    l_b = np.asarray([prm.l_new[0, 3], prm.l_new[1, 1]])
    n_b = np.asarray([prm.n_new[0, 3], prm.n_new[1, 1]])
    t = l_b + n_b / r
    e = l_b * np.asarray(prm.p_compute) + (n_b / r) * p_tx
    w = float(prm.t0) / t
    assert res["t_task"] == pytest.approx(float((t * w).sum() / w.sum()),
                                          rel=1e-5)
    assert res["e_task"] == pytest.approx(float((e * w).sum() / w.sum()),
                                          rel=1e-5)
