"""End-to-end system behaviour: training loop descends, checkpoint
round-trips, split tables are coherent, HLO analysis, optimizers, sharding
rules on a small host mesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import INPUT_SHAPES, reduced
from repro.data.synthetic import TokenPipelineConfig, token_batch_stream
from repro.launch.steps import make_train_step
from repro.models import init_params


def _tiny_dense_cfg():
    return reduced(get_config("qwen3-1.7b"), n_layers=2, d_model=128,
                   vocab=256)


def test_train_loop_loss_decreases():
    cfg = _tiny_dense_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    train_step, opt_init = make_train_step(cfg, base_lr=3e-3, warmup=5,
                                           total=60)
    opt = opt_init(params)
    stream = token_batch_stream(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=32, batch=8))
    step = jax.jit(train_step)
    losses = []
    for i in range(40):
        batch = next(stream)
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]
    assert all(np.isfinite(losses))


def test_adafactor_descends():
    cfg = _tiny_dense_cfg().replace(optimizer="adafactor")
    params = init_params(cfg, jax.random.PRNGKey(0))
    train_step, opt_init = make_train_step(cfg, base_lr=3e-3, warmup=5,
                                           total=60)
    opt = opt_init(params)
    stream = token_batch_stream(TokenPipelineConfig(
        vocab_size=cfg.vocab_size, seq_len=32, batch=8))
    step = jax.jit(train_step)
    losses = []
    for _ in range(30):
        params, opt, metrics = step(params, opt, next(stream))
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_checkpoint_roundtrip(tmp_path):
    from repro.ckpt import load_checkpoint, save_checkpoint
    cfg = _tiny_dense_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, params, step=7, extra={"arch": cfg.name})
    restored, meta = load_checkpoint(path, params)
    assert meta["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_split_tables_all_archs():
    from repro.core.split import transformer_split_table
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        plan = transformer_split_table(cfg)
        n = plan.n_actions
        assert n == 6  # 4 points + raw-offload + full-local
        assert plan.t_local[0] == 0.0
        assert plan.f_bits[-1] == 0.0
        assert np.all(np.diff(plan.t_local[1:-1]) >= -1e-9), arch
        assert plan.feasible[0], arch  # raw offload always feasible
        if arch in ("kimi-k2-1t-a32b", "llama-3.2-vision-90b"):
            assert not plan.feasible[-1], f"{arch} can't run fully on a UE"


def test_hloanalysis_weighted_trip_counts():
    from repro.launch.hloanalysis import analyze

    def scanned(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jnp.ones((128, 128))
    ws = jnp.ones((6, 128, 128))
    text = jax.jit(scanned).lower(x, ws).compile().as_text()
    res = analyze(text)
    assert res["hlo_dot_flops"] == pytest.approx(2 * 128**3 * 6, rel=1e-6)


def test_input_specs_cover_all_shapes():
    from repro.launch.steps import input_specs
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES:
            specs = input_specs(cfg, shape)
            leaves = jax.tree_util.tree_leaves(specs)
            assert leaves, (arch, shape)
            for leaf in leaves:
                assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_sharding_rules_small_mesh():
    """Param sharding specs build on a small host mesh and every spec
    divides its dim."""
    from jax.sharding import NamedSharding
    from repro.launch.mesh import make_host_mesh
    from repro.models import sharding as shd
    from repro.launch.steps import params_spec
    mesh = make_host_mesh(model_axis=1)
    cfg = get_config("qwen2-7b")
    pstruct = params_spec(cfg)
    shardings = shd.params_shardings(mesh, pstruct, cfg)
    for leaf, sh in zip(jax.tree_util.tree_leaves(pstruct),
                        jax.tree_util.tree_leaves(
                            shardings,
                            is_leaf=lambda x: isinstance(x, NamedSharding))):
        ss = sh.shard_shape(leaf.shape)  # raises if indivisible
        assert len(ss) == len(leaf.shape)


def test_dryrun_single_combo_host_mesh():
    """A reduced arch x shape lowers + compiles on the host mesh (the full
    512-device run lives in launch/dryrun.py artifacts)."""
    from repro.launch.mesh import make_host_mesh
    from repro.models import sharding as shd
    mesh = make_host_mesh(model_axis=1)
    cfg = _tiny_dense_cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    train_step, opt_init = make_train_step(cfg)
    opt = opt_init(params)
    batch = {"tokens": jnp.ones((4, 16), jnp.int32),
             "labels": jnp.ones((4, 16), jnp.int32)}
    psh = shd.params_shardings(mesh, params, cfg)
    bsh = shd.batch_shardings(mesh, batch)
    fn = jax.jit(train_step, in_shardings=(psh, None, bsh))
    compiled = fn.lower(params, opt, batch).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):       # older jax: one dict per program
        ca = ca[0]
    assert ca["flops"] > 0


def test_data_pipeline_learnable_structure():
    """Markov stream has non-uniform transitions (cross-entropy of the true
    process is well below log(V))."""
    stream = token_batch_stream(TokenPipelineConfig(vocab_size=64, seq_len=64,
                                                    batch=4, n_modes=4))
    b = next(stream)
    assert b["tokens"].shape == (4, 64)
    # consecutive-token pairs repeat far more than uniform chance
    toks = np.asarray(b["tokens"]).reshape(-1)
    pairs = set(zip(toks[:-1], toks[1:]))
    assert len(pairs) < 0.5 * (len(toks) - 1)
