"""Expert-parallel shard_map MoE must agree with the pure-GSPMD global
dispatch. Runs in a subprocess with 8 forced host devices (the main test
process keeps 1 device — see conftest note)."""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig
from repro.models import meshctx
from repro.models.moe import (_apply_moe_global, apply_moe_ep,
                              apply_moe_ep_decode, init_moe)

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = ModelConfig(
    name="t", family="moe", n_layers=1, d_model=32, n_heads=2, n_kv_heads=1,
    d_ff=64, vocab_size=32, block_pattern=("moe",),
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=16, capacity_factor=8.0,
                  n_shared_experts=1),
    param_dtype="float32", compute_dtype="float32", fsdp=True)
p = init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32)) * 0.5

ref, aux_ref = _apply_moe_global(p, x, cfg)
with meshctx.use_mesh(mesh):
    out_ep, aux_ep = jax.jit(lambda p, x: apply_moe_ep(p, x, cfg, mesh))(p, x)
    out_dec, aux_dec = jax.jit(
        lambda p, x: apply_moe_ep_decode(p, x, cfg, mesh))(p, x)

for name, out in (("ep", out_ep), ("ep_decode", out_dec)):
    err = float(jnp.max(jnp.abs(out - ref)))
    rel = err / (float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 2e-3, f"{name} mismatch rel={rel}"
    print(name, "ok", rel)
print("ALL_OK")
"""


@pytest.mark.slow
def test_ep_matches_global_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    res = subprocess.run([sys.executable, "-c", _SCRIPT], cwd=os.getcwd(),
                         env=env, capture_output=True, text=True,
                         timeout=600)
    assert "ALL_OK" in res.stdout, res.stdout + "\n" + res.stderr
