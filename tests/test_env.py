"""MEC environment invariants (paper §3-4) + Theorem 1 empirical check."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# only test_step_invariants needs hypothesis; the other env invariants must
# still run where it isn't installed
try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

import golden_cases as gc
from repro.core.cnn import make_resnet18
from repro.core.split import cnn_split_table
from repro.env.channel import channel_gain, uplink_rates
from repro.env.mecenv import MECEnv, make_env_params


@pytest.fixture(scope="module")
def env():
    plan = cnn_split_table(make_resnet18(101), 224)
    return MECEnv(make_env_params(plan, n_ue=5, n_channels=2))


def test_trajectory_matches_golden():
    """40 random-action frames on the 5-UE homogeneous env reproduce the
    goldens.json capture (PR-7 exact-carry recapture) byte-for-byte:
    reward stream, final (k, l, n, d), PRNG key, and membership mask."""
    got = gc.trajectory_golden("env5")
    assert got == gc.load_goldens()["trajectories"]["env5"]


def test_env_params_scalar_fields_are_jnp(env):
    """EnvParams churn fields must match their annotated array types on
    EVERY construction path: via make_env_params AND via a bare
    EnvParams(...) that leaves the defaults in place."""
    from repro.env.mecenv import EnvParams
    for prm in (env.params,
                EnvParams(*env.params[:EnvParams._fields.index(
                    "churn_rate")])):
        assert isinstance(prm.churn_rate, jnp.ndarray), type(prm.churn_rate)
        assert isinstance(prm.leave_rate, jnp.ndarray), type(prm.leave_rate)
        assert prm.churn_rate.dtype == jnp.float32
    # _replace keeps them arrays too (the common tweak path in tests)
    prm2 = env.params._replace(churn_rate=jnp.float32(0.1))
    assert isinstance(prm2.churn_rate, jnp.ndarray)


def test_reset_shapes(env):
    s = env.reset(jax.random.PRNGKey(0))
    assert s.k.shape == (5,)
    assert env.observe(s).shape == (env.obs_dim,)
    assert bool(jnp.all(s.k >= 0))


def test_rate_interference_monotone():
    """More interferers on my channel => lower rate (Eq. 5)."""
    g = channel_gain(jnp.array([50.0, 50.0, 50.0]))
    omega = jnp.array([1e6, 1e6])
    sigma = jnp.array([1e-9, 1e-9])
    p = jnp.array([0.3, 0.3, 0.3])
    c_alone = jnp.array([0, 1, 1])
    c_crowd = jnp.array([0, 0, 0])
    r_alone = uplink_rates(p, c_alone, g, jnp.array([True] * 3),
                           omega=omega, sigma=sigma)
    r_crowd = uplink_rates(p, c_crowd, g, jnp.array([True] * 3),
                           omega=omega, sigma=sigma)
    assert float(r_alone[0]) > float(r_crowd[0])
    # non-transmitting UEs don't interfere
    r_quiet = uplink_rates(p, c_crowd, g, jnp.array([True, False, False]),
                           omega=omega, sigma=sigma)
    assert float(r_quiet[0]) == pytest.approx(float(r_alone[0]), rel=1e-6)


if given is not None:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(0, 5), st.integers(0, 1),
           st.floats(0.01, 0.5))
    def test_step_invariants(seed, b, c, p):
        plan = cnn_split_table(make_resnet18(101), 224)
        env = MECEnv(make_env_params(plan, n_ue=3, n_channels=2))
        s = env.reset(jax.random.PRNGKey(seed))
        n = env.params.n_ue
        bb = jnp.full((n,), b, jnp.int32)
        cc = jnp.full((n,), c, jnp.int32)
        pp = jnp.full((n,), p)
        s2, reward, done, info = env.step(s, {"split": bb, "channel": cc,
                                              "power": pp})
        # tasks never increase (unless auto-reset fired)
        if not bool(done):
            assert bool(jnp.all(s2.k <= s.k))
            assert bool(jnp.all(s2.k >= 0))
        assert float(info["energy"]) >= 0
        assert float(info["completed"]) >= 0
        assert float(reward) <= 0  # reward is negative overhead
        assert bool(jnp.all(s2.l >= -1e-6))
        assert bool(jnp.all(s2.n >= 0))


def test_local_policy_completes_all_tasks(env):
    """Running b=B+1 long enough finishes the episode (done=True seen)."""
    s = env.reset(jax.random.PRNGKey(1), eval_mode=True)
    n = env.params.n_ue
    b = jnp.full((n,), env.n_actions_b - 1, jnp.int32)
    c = jnp.zeros((n,), jnp.int32)
    p = jnp.full((n,), 0.05)
    total_completed = 0.0
    done_seen = False
    for _ in range(40):  # 200 tasks x 63ms / 0.5s ~ 26 frames
        s, r, done, info = env.step(s, {"split": b, "channel": c,
                                        "power": p})
        total_completed += float(info["completed"])
        if bool(done):
            done_seen = True
            break
    assert done_seen
    assert total_completed == pytest.approx(200 * n, abs=1)


def test_offload_faster_than_local_when_alone(env):
    """A single offloading UE at moderate distance beats local (the paper's
    core premise given the compressor)."""
    plan = cnn_split_table(make_resnet18(101), 224)
    env1 = MECEnv(make_env_params(plan, n_ue=1, n_channels=2))
    s = env1.reset(jax.random.PRNGKey(0), eval_mode=True)
    # split b=1 with decent power
    s1, r_off, _, i_off = env1.step(s, {"split": jnp.array([1]),
                                        "channel": jnp.array([0]),
                                        "power": jnp.array([0.3])})
    s = env1.reset(jax.random.PRNGKey(0), eval_mode=True)
    s2, r_loc, _, i_loc = env1.step(
        s, {"split": jnp.array([env1.n_actions_b - 1]),
            "channel": jnp.array([0]), "power": jnp.array([0.3])})
    assert float(i_off["completed"]) > float(i_loc["completed"])


def test_theorem1_p2_ordering_implies_p1():
    """Theorem 1 (empirical): among random policies, better P2 objective
    (our per-frame reward sum) implies better P1 (makespan + beta*energy)
    for small beta."""
    plan = cnn_split_table(make_resnet18(101), 224)
    env = MECEnv(make_env_params(plan, n_ue=3, n_channels=2, beta=0.01))
    results = []
    for seed in range(6):
        key = jax.random.PRNGKey(100 + seed)
        s = env.reset(jax.random.PRNGKey(0), eval_mode=True)
        f2 = 0.0
        frames = 0
        energy = 0.0
        done = False
        kb, kc, kp = jax.random.split(key, 3)
        b = jax.random.randint(kb, (3,), 0, env.n_actions_b)
        c = jax.random.randint(kc, (3,), 0, 2)
        p = jax.random.uniform(kp, (3,), minval=0.05, maxval=0.5)
        for _ in range(200):
            s, r, done, info = env.step(s, {"split": b, "channel": c,
                                            "power": p})
            f2 -= float(r)
            energy += float(info["energy"])
            frames += 1
            if bool(done):
                break
        if not bool(done):
            continue
        f1 = frames * 0.5 + 0.01 * energy  # makespan + beta*energy
        results.append((f2, f1))
    assert len(results) >= 3
    results.sort()
    f1s = [f1 for _, f1 in results]
    # rank correlation: best-P2 policy should not be the worst-P1 policy
    assert f1s[0] <= f1s[-1] + 1e-6
    rho = np.corrcoef([f2 for f2, _ in results], f1s)[0, 1]
    assert rho > 0.0
