"""int8 KV-cache (paper Eq. 1 applied to the serving cache)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced
from repro.models import apply_model, decode_step, init_params, prefill
from repro.models.cache import dequantize_kv, quantize_kv


def test_quantize_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 7, 3, 16))
    codes, scale = quantize_kv(x, 8)
    assert codes.dtype == jnp.int8
    xr = dequantize_kv(codes, scale, jnp.float32)
    # error bounded by half a step of the per-(token, head) scale
    err = jnp.abs(xr - x)
    bound = scale[..., None] * 0.5 + 1e-6
    assert bool(jnp.all(err <= bound))


def test_kv8_decode_close_to_full():
    cfg = reduced(get_config("qwen2-7b")).replace(kv_quant_bits=8)
    params = init_params(cfg, jax.random.PRNGKey(0))
    b, s = 2, 21
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0,
                              cfg.vocab_size)
    full, _, _ = apply_model(params, cfg, toks, mode="train")
    _, cache = prefill(params, cfg, toks[:, :s], attn_len=s + 1)
    dec, cache = decode_step(params, cfg, cache, toks[:, s:s + 1],
                             jnp.int32(s))
    ref = full[:, s]
    rel = float(jnp.max(jnp.abs(ref - dec))) / (
        float(jnp.max(jnp.abs(ref))) + 1e-9)
    assert rel < 0.05, rel
    # cache stores int8 codes
    leaves = jax.tree_util.tree_leaves(cache)
    assert any(l.dtype == jnp.int8 for l in leaves)


def test_kv8_multi_step_stable():
    cfg = reduced(get_config("qwen3-1.7b")).replace(kv_quant_bits=8)
    params = init_params(cfg, jax.random.PRNGKey(1))
    b, s = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s + 4), 0,
                              cfg.vocab_size)
    full, _, _ = apply_model(params, cfg, toks, mode="train")
    _, cache = prefill(params, cfg, toks[:, :s], attn_len=s + 4)
    for i in range(4):
        dec, cache = decode_step(params, cfg, cache, toks[:, s + i:s + i + 1],
                                 jnp.int32(s + i))
        ref = full[:, s + i]
        rel = float(jnp.max(jnp.abs(ref - dec))) / (
            float(jnp.max(jnp.abs(ref))) + 1e-9)
        assert rel < 0.08, (i, rel)
