"""Hypothesis property tests for the paper's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.compressor import (compression_rate, dequantize, quantize)
from repro.core.jalad import byte_entropy_bits, jalad_compress_size_bits


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 8),
       st.floats(-50.0, 0.0), st.floats(0.5, 50.0), st.integers(0, 2**31 - 1))
def test_quant_roundtrip_bounded(bits, lo, span, seed):
    """|dequant(quant(x)) - x| <= step/2 for x within [min, max] (Eq. 1-2)."""
    hi = lo + span
    x = jax.random.uniform(jax.random.PRNGKey(seed), (64,),
                           minval=lo, maxval=hi)
    q, mn, mx = quantize(x, bits)
    d = dequantize(q, bits, mn, mx)
    step = float(mx - mn) / ((1 << bits) - 1)
    assert float(jnp.max(jnp.abs(d - x))) <= step / 2 + 1e-5


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 512), st.integers(1, 64), st.integers(1, 16))
def test_compression_rate_formula(ch, chp, bits):
    """Eq. 3: R = (ch*32)/(ch'*c_q); monotone in each factor."""
    r = compression_rate(ch, chp, bits)
    assert np.isclose(r, ch * 32.0 / (chp * bits))
    assert compression_rate(ch * 2, chp, bits) == 2 * r


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_entropy_bounds(seed):
    """0 <= H <= bits; uniform data ~ bits, constant data ~ 0."""
    codes = jax.random.randint(jax.random.PRNGKey(seed), (4096,), 0, 256,
                               dtype=jnp.int32).astype(jnp.uint8)
    h = float(byte_entropy_bits(codes, 8))
    assert 0.0 <= h <= 8.0 + 1e-6
    const = jnp.zeros((4096,), jnp.uint8)
    assert float(byte_entropy_bits(const, 8)) < 1e-6


def test_jalad_size_le_raw():
    """Entropy-coded size never exceeds the plain 8-bit size."""
    x = jax.random.normal(jax.random.PRNGKey(0), (32, 32)) ** 3  # peaky
    size_bits, rate = jalad_compress_size_bits(x, 8)
    assert float(size_bits) <= x.size * 8 + 1e-3
    assert float(rate) >= 4.0  # always at least 32/8


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_ae_roundtrip_identity_when_square(seed):
    """A square 'bottleneck' initialized to identity reconstructs exactly
    (sanity for the encode/decode plumbing)."""
    from repro.core.compressor import decode as ae_dec, encode as ae_enc
    d = 16
    ae = {"enc": jnp.eye(d), "dec": jnp.eye(d)}
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 5, d))
    np.testing.assert_allclose(np.asarray(ae_dec(ae, ae_enc(ae, x))),
                               np.asarray(x), rtol=1e-6, atol=1e-6)
