"""Dynamic-fleet (UE churn) properties.

Two layers, mirroring tests/test_env.py:
 * seeded tests that always run (no hypothesis needed), and
 * hypothesis-driven variants over arbitrary action/churn sequences when
   hypothesis is installed (CI installs it; see .github/workflows/ci.yml).

The core invariants:
 1. task-ledger conservation per frame:
        sum(k') == sum(k) - completed - dropped + spawned
    (with zero churn this collapses to completed + remaining == initial)
 2. inactive UEs are INERT: they accrue no energy, cause no interference,
    complete no tasks, and never change the active UEs' dynamics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    given = None

from repro.core.cnn import make_resnet18
from repro.core.split import cnn_split_table
from repro.env.mecenv import EnvState, MECEnv, make_env_params


def _dyn_env(churn=0.3, leave=0.2, n_ue=4, lam=15.0):
    plan = cnn_split_table(make_resnet18(101), 224)
    return MECEnv(make_env_params(plan, n_ue=n_ue, n_channels=2,
                                  churn_rate=churn, leave_rate=leave,
                                  lam_tasks=lam))


def _ledger_rollout(env, seed, frames=200):
    """Step with random feasible actions; check the per-frame task ledger
    and the inactive ⇒ empty-queue invariant."""
    n = env.params.n_ue
    s = env.reset(jax.random.PRNGKey(seed))
    rng = np.random.RandomState(seed % 2**31)
    initial = float(s.k.sum())
    completed = dropped = spawned = 0.0
    done = False
    for _ in range(frames):
        k_pre = float(s.k.sum())
        b = jnp.asarray(rng.randint(0, env.n_actions_b, n), jnp.int32)
        c = jnp.asarray(rng.randint(0, env.n_channels, n), jnp.int32)
        p = jnp.asarray(rng.uniform(0.05, 0.5, n), jnp.float32)
        s, r, done, info = env.step(s, {"split": b, "channel": c,
                                        "power": p})
        assert float(info["energy"]) >= 0.0
        assert float(info["completed"]) >= 0.0
        assert float(info["dropped"]) >= 0.0
        assert float(info["spawned"]) >= 0.0
        completed += float(info["completed"])
        dropped += float(info["dropped"])
        spawned += float(info["spawned"])
        if bool(done):
            break
        expect = (k_pre - float(info["completed"]) - float(info["dropped"])
                  + float(info["spawned"]))
        assert float(s.k.sum()) == pytest.approx(expect, abs=1e-3)
        # standby slots carry no queue, no in-flight work
        act = np.asarray(s.active)
        assert np.all(np.asarray(s.k)[~act] == 0.0)
        assert np.all(np.asarray(s.l)[~act] == 0.0)
        assert np.all(np.asarray(s.n)[~act] == 0.0)
    assert bool(done), "episode should terminate under any policy"
    # episode ledger: everything spawned was completed or dropped (the
    # final frame's leftovers count as completed-at-done per env contract)
    assert completed + dropped == pytest.approx(initial + spawned, abs=2.0)


def test_ledger_conservation_seeded():
    for seed in (0, 7, 123):
        _ledger_rollout(_dyn_env(), seed)


def test_zero_churn_reduces_to_static_conservation():
    """churn=leave=0 through the SAME entry point: completed + remaining
    == initial, and the env reports itself static (4N obs, no churn)."""
    env = _dyn_env(churn=0.0, leave=0.0, lam=20.0)
    assert not env.dynamic
    assert env.obs_dim == 4 * env.params.n_ue
    s = env.reset(jax.random.PRNGKey(5))
    initial = float(s.k.sum())
    rng = np.random.RandomState(5)
    completed = 0.0
    done = False
    for _ in range(400):
        n = env.params.n_ue
        b = jnp.asarray(rng.randint(0, env.n_actions_b, n), jnp.int32)
        c = jnp.asarray(rng.randint(0, env.n_channels, n), jnp.int32)
        p = jnp.asarray(rng.uniform(0.05, 0.5, n), jnp.float32)
        s, r, done, info = env.step(s, {"split": b, "channel": c,
                                        "power": p})
        assert float(info["spawned"]) == 0.0
        assert float(info["dropped"]) == 0.0
        completed += float(info["completed"])
        if bool(done):
            break
    assert bool(done)
    assert completed == pytest.approx(initial, abs=1.0)


def _inert_check(seed):
    """An inactive UE with a (hand-planted) non-empty queue changes NOTHING:
    same reward/energy/completions/rates as the same state with that queue
    zeroed — i.e. zero energy accrual and zero interference from standby."""
    env = _dyn_env(churn=0.0, leave=0.1)   # dynamic, but no joins: the
    assert env.dynamic                     # planted UE stays inactive
    rng = np.random.RandomState(seed)
    n = env.params.n_ue
    s = env.reset(jax.random.PRNGKey(seed))
    idx = rng.randint(0, n)
    active = np.ones((n,), bool)
    active[idx] = False
    loaded = np.asarray(s.k).copy()
    loaded[idx] = 50.0                     # pending queue on a standby slot
    n_bits = np.zeros((n,), np.float32)
    n_bits[idx] = 1e5                      # half-offloaded in-flight task
    sa = s._replace(active=jnp.asarray(active), k=jnp.asarray(loaded),
                    n=jnp.asarray(n_bits))
    zeroed = loaded.copy()
    zeroed[idx] = 0.0
    sb = s._replace(active=jnp.asarray(active), k=jnp.asarray(zeroed),
                    n=jnp.zeros((n,), jnp.float32))
    # everyone (incl. the standby slot) "tries" to offload at high power
    b = jnp.asarray(rng.randint(0, env.n_actions_b - 1, n), jnp.int32)
    c = jnp.zeros((n,), jnp.int32)         # all on one channel: worst case
    p = jnp.full((n,), 0.5)
    s2a, ra, da, ia = env.step(sa, {"split": b, "channel": c, "power": p})
    s2b, rb, db, ib = env.step(sb, {"split": b, "channel": c, "power": p})
    assert np.asarray(ra).tobytes() == np.asarray(rb).tobytes()
    assert float(ia["energy"]) == float(ib["energy"])
    assert float(ia["completed"]) == float(ib["completed"])
    assert float(ia["rate_mean"]) == float(ib["rate_mean"])
    assert float(ia["offloads"]) == float(ib["offloads"])
    # the active UEs' next states agree exactly (unless B's episode ended:
    # A's planted queue keeps A alive while B auto-resets)
    if not bool(db):
        for field in ("k", "l", "n", "d"):
            va = np.asarray(getattr(s2a, field))[active]
            vb = np.asarray(getattr(s2b, field))[active]
            np.testing.assert_array_equal(va, vb)


def test_inactive_ues_are_inert_seeded():
    for seed in (1, 2, 42):
        _inert_check(seed)


def test_heuristics_respect_active_mask():
    """greedy/oracle with an `active` mask: standby UEs don't interfere
    (active UEs' overhead can only improve) and only active UEs are
    scored; the oracle pins standby splits to full-local."""
    from repro.rl.heuristics import greedy_eval, oracle_static_eval
    env = _dyn_env(churn=0.2, leave=0.1, n_ue=4)
    active = np.array([True, False, True, False])
    gr_all = greedy_eval(env)
    gr_act = greedy_eval(env, active=active)
    # same per-UE table argmins, but fewer transmitters => no worse latency
    assert gr_act["b"] == gr_all["b"]
    assert gr_act["t_task"] <= gr_all["t_task"] + 1e-9
    orc = oracle_static_eval(env, active=active)
    b_local = env.n_actions_b - 1
    assert orc["b"][1] == b_local and orc["b"][3] == b_local
    assert np.isfinite(orc["overhead"])
    assert orc["overhead"] <= gr_act["overhead"] + 1e-9


def test_membership_mask_invariants():
    """Joins only from standby, leaves only from active; re-joining UEs get
    a fresh queue and distance; auto-reset restores the full fleet."""
    env = _dyn_env(churn=0.5, leave=0.4, lam=30.0)
    s = env.reset(jax.random.PRNGKey(11))
    step = jax.jit(env.step)
    n = env.params.n_ue
    saw_join = saw_leave = False
    for i in range(300):
        act_pre = np.asarray(s.active)
        b = jnp.full((n,), 1, jnp.int32)
        s, r, done, info = step(s, {"split": b,
                                    "channel": jnp.zeros((n,), jnp.int32),
                                    "power": jnp.full((n,), 0.3)})
        act_post = np.asarray(s.active)
        if bool(done):
            assert act_post.all()          # fresh episode: full fleet
            continue
        joined = act_post & ~act_pre
        left = act_pre & ~act_post
        saw_join |= bool(joined.any())
        saw_leave |= bool(left.any())
        # a joiner starts clean: fresh queue, no in-flight work
        assert np.all(np.asarray(s.l)[joined] == 0.0)
        assert np.all(np.asarray(s.n)[joined] == 0.0)
        d = np.asarray(s.d)
        assert np.all((d >= float(env.params.d_low) - 1e-6)
                      & (d <= float(env.params.d_high) + 1e-6))
    assert saw_join and saw_leave, "churn rates this high must churn"


if given is not None:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1),
           st.floats(0.05, 1.0), st.floats(0.05, 0.5))
    def test_ledger_conservation_property(seed, churn, leave):
        """Frame ledger holds for ARBITRARY churn parameters and action
        sequences (actions drawn from the seed inside the rollout)."""
        _ledger_rollout(_dyn_env(churn=churn, leave=leave), seed, frames=150)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2**31 - 1))
    def test_inactive_inert_property(seed):
        _inert_check(seed)
