"""Train-big/serve-small distillation (rl.distill + the trunk serving
path): fidelity of the flat-trunk student vs its entity teacher,
int8-vs-f32 parity of the quantized serving form, and the
TrunkDispatcher deployment bridge.

One module-scoped pipeline run (small teacher -> DAgger distill ->
int8 quantize) feeds every test: the budgets are test-sized, so the
fidelity gate is the ISSUE's OR-form — mode agreement >= 0.9 OR the
student's evaluated overhead within 1.05x of the teacher's. An
undertrained teacher has near-uniform logits on some heads (argmax of
noise), where per-head agreement is meaningless but matching the label
distribution still reproduces the teacher's OVERHEAD — which is the
quantity the deployment cares about. bench_policy_latency gates the
same ratio at real budgets.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fleets import make_edge_pool, make_mixed_fleet
from repro.env.mecenv import MECEnv, make_env_params
from repro.rl import nets
from repro.rl.distill import (DistillConfig, action_agreement,
                              distill_entity_policy, quantize_flat_trunk)
from repro.rl.mahppo import MAHPPOConfig, evaluate_policy, train_mahppo
from repro.stream.adapter import TrunkDispatcher
from repro.stream.events import StreamParams, StreamSim


def _pool_env(n_ue=6, n_servers=2):
    return MECEnv(make_env_params(make_mixed_fleet(n_ue=n_ue),
                                  n_channels=2,
                                  pool=make_edge_pool(n_servers)))


@pytest.fixture(scope="module")
def pipeline():
    """Teacher -> student -> int8, shared by every test below."""
    env = _pool_env()
    teacher, _ = train_mahppo(
        env, MAHPPOConfig(iterations=8, horizon=256, n_envs=4, reuse=4,
                          entity_policy=True, lr=3e-4), seed=0)
    student, hist = distill_entity_policy(
        env, teacher,
        DistillConfig(iterations=2, frames=32, n_envs=4, label_samples=4,
                      epochs=100), seed=0)
    return env, teacher, student, quantize_flat_trunk(student), hist


def _overhead(env, agent, frames=32):
    ev = evaluate_policy(env, agent, frames=frames)
    return float(ev["t_task"] + float(env.params.beta) * ev["e_task"])


# ----------------------------------------------------------- fidelity
def test_student_matches_teacher(pipeline):
    """The ISSUE gate: held-out mode agreement >= 0.9 OR evaluated
    overhead within 1.05x of the teacher (the branch that binds at test
    budgets — see the module docstring)."""
    env, teacher, student, _, _ = pipeline
    agree = action_agreement(env, teacher, student, states=256, seed=42)
    ratio = _overhead(env, {"flat_trunk": student}) / _overhead(env, teacher)
    assert agree["all"] >= 0.9 or ratio <= 1.05, (agree, ratio)
    # the continuous head must track regardless: mean squashed-power gap
    # under a tenth of the head's range
    assert agree["power_gap"] < 0.1 * float(
        env.action_space.head("power").high
        - env.action_space.head("power").low)


def test_distill_history_aggregates(pipeline):
    """DAgger bookkeeping: the dataset grows every round, losses are
    finite, agreement is a fraction."""
    _, _, _, _, hist = pipeline
    sizes = [h["states"] for h in hist]
    assert sizes == sorted(sizes) and sizes[0] < sizes[-1]
    for h in hist:
        assert np.isfinite(h["loss"])
        assert 0.0 <= h["agreement"] <= 1.0


def test_student_param_budget(pipeline):
    """Serve-small arithmetic: the student carries <= 25% of the
    teacher's parameters, and int8 quantization shrinks its serving
    bytes by ~4x (weight codes 1 byte, biases still f32)."""
    _, teacher, student, qstudent, _ = pipeline
    n_t = nets.param_count(teacher["entity_actor"])
    n_s = nets.param_count(student)
    assert n_s <= 0.25 * n_t
    b_f32 = nets.param_bytes(student)
    b_int8 = nets.param_bytes(qstudent)
    assert b_int8 < 0.5 * b_f32


# ------------------------------------------------------ int8 serving path
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quantized_trunk_parity(pipeline, dtype):
    """int8-vs-f32 on live observation rows (f32 and bf16): bounded head
    logit error and near-perfect deterministic-mode agreement. The
    logit bound is what makes the mode bound robust — int8 weight error
    perturbs logits by O(step * activation), not O(1)."""
    env, _, student, qstudent, _ = pipeline
    space = env.action_space
    key = jax.random.PRNGKey(7)
    masks = space.broadcast_masks(env.action_masks(env.reset(key)),
                                  env.params.n_ue)
    rows, modes_f, modes_q = [], [], []
    err = 0.0
    for i in range(8):
        key, k = jax.random.split(key)
        r = env.observe_per_ue(env.reset(k)).astype(dtype)
        df = nets.flat_trunk_forward(student, space, r.astype(jnp.float32),
                                     masks)
        dq = nets.flat_trunk_forward(qstudent, space, r, masks)
        for h in space.discrete:
            # compare only feasible logits: masked slots are -1e9 twice
            m = masks.get(h.name)
            d = jnp.abs(df[h.name] - dq[h.name])
            err = max(err, float(jnp.max(jnp.where(m, d, 0.0)
                                         if m is not None else d)))
        modes_f.append(jax.vmap(space.mode)(df, masks))
        modes_q.append(jax.vmap(space.mode)(dq, masks))
    tol = 0.05 if dtype == jnp.float32 else 0.25
    assert err <= tol, err
    match = [np.mean([np.mean(np.asarray(a[h.name] == b[h.name]))
                      for h in space.discrete])
             for a, b in zip(modes_f, modes_q)]
    assert np.mean(match) >= (0.95 if dtype == jnp.float32 else 0.85)


def test_quantize_roundtrip_error_bound(pipeline):
    """Per-layer min-max weight codes reconstruct within half a step."""
    _, _, student, qstudent, _ = pipeline
    from repro.kernels import ops as kops
    for layer, ql in zip(student["layers"], qstudent["qlayers"]):
        w = np.asarray(layer["w"])
        d = np.asarray(kops.dequantize(ql["codes"], ql["mn"], ql["mx"],
                                       bits=qstudent["bits"]))
        step = (float(ql["mx"]) - float(ql["mn"])) / 255.0
        assert np.max(np.abs(d - w)) <= step / 2 + 1e-6


# ----------------------------------------------------- deployment bridge
@pytest.mark.parametrize("quantized", [False, True])
def test_trunk_dispatcher_masks_bind(pipeline, quantized):
    """The dispatcher NEVER emits an infeasible split: every dispatched
    action over a full stream run satisfies the UE's own table
    feasibility row. The demo fleet's tables are all-feasible, so the
    test serves a deliberately RESTRICTED copy of the env (several
    splits forbidden per UE, full-local kept) with the unchanged trunk —
    the weights were never trained against these masks, so only the
    dispatch-time masking can keep the actions legal."""
    env, _, student, qstudent, _ = pipeline
    feas = np.asarray(env.params.feasible).copy()
    feas[::2, 0] = False        # forbid raw offload on even UEs
    feas[1::2, 1:3] = False     # and two shallow splits on odd ones
    assert feas[:, -1].all()    # full-local stays, actions stay feasible
    renv = MECEnv(env.params._replace(feasible=jnp.asarray(feas)))
    disp = TrunkDispatcher(renv, qstudent if quantized else student, seed=0)
    calls = []

    def recording(core, ue):
        a = disp(core, ue)
        calls.append((ue, dict(a)))
        return a

    rep = StreamSim(renv, recording, StreamParams(rate=6.0, horizon=4.0),
                    seed=3).run()
    assert rep["completed"] > 0 and len(calls) > 0
    for ue, a in calls:
        assert feas[ue, a["split"]], (ue, a)
        assert 0 <= a["channel"] < renv.n_channels
        assert 0 <= a.get("route", 0) < renv.n_servers


def test_trunk_forward_masks_pin_logits(pipeline):
    """Mask mechanics under both weight forms: infeasible split logits
    sit at the -1e9 floor, feasible ones stay finite (the demo masks are
    all-True, so feed a restrictive one directly)."""
    env, _, student, qstudent, _ = pipeline
    space = env.action_space
    s = env.reset(jax.random.PRNGKey(0))
    masks = space.broadcast_masks(env.action_masks(s), env.params.n_ue)
    split = np.asarray(masks["split"]).copy()
    split[:, 0] = False
    split[::2, 2] = False
    masks = dict(masks, split=jnp.asarray(split))
    for trunk in (student, qstudent):
        dist = nets.flat_trunk_forward(trunk, space, env.observe_per_ue(s),
                                       masks)
        logits = np.asarray(dist["split"])
        assert (~split).sum() > 0
        assert (logits[~split] <= -1e8).all()
        assert np.abs(logits[split]).max() < 1e6


def test_trunk_dispatcher_validates_params(pipeline):
    env, teacher, _, _, _ = pipeline
    with pytest.raises(ValueError, match="flat-trunk"):
        TrunkDispatcher(env, teacher)       # entity params, not a trunk


def test_trunk_deterministic_stream_is_reproducible(pipeline):
    env, _, _, qstudent, _ = pipeline
    sp = StreamParams(rate=5.0, horizon=3.0)
    reps = [StreamSim(env, TrunkDispatcher(env, qstudent,
                                           deterministic=True, seed=1),
                      sp, seed=11).run() for _ in range(2)]
    assert reps[0] == reps[1]


# ------------------------------------------------------------ guard rails
def test_distill_rejects_non_entity_teacher():
    env = _pool_env(n_ue=4)
    with pytest.raises(ValueError, match="entity"):
        distill_entity_policy(env, {"actors": []})


def test_distill_rejects_dynamic_env():
    env = MECEnv(make_env_params(make_mixed_fleet(n_ue=4), n_channels=2,
                                 pool=make_edge_pool(2), churn_rate=0.1))
    assert env.dynamic
    with pytest.raises(ValueError, match="dynamic"):
        distill_entity_policy(env, {"entity_actor": {}})
