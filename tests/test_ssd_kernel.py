"""SSD intra-chunk Pallas kernel vs oracle, and vs the model's ssd_chunked."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("q,h,p,n", [(16, 2, 8, 8), (32, 4, 16, 8),
                                     (64, 2, 32, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_intra_matches_ref(q, h, p, n, dtype):
    b, nc = 2, 2
    key = jax.random.PRNGKey(0)
    xh = jax.random.normal(key, (b, nc, q, h, p)).astype(dtype)
    dt = jax.nn.softplus(
        jax.random.normal(jax.random.PRNGKey(1), (b, nc, q, h)))
    la = -jnp.cumsum(dt * 0.3, axis=2)
    B = jax.random.normal(jax.random.PRNGKey(2), (b, nc, q, n)).astype(dtype)
    C = jax.random.normal(jax.random.PRNGKey(3), (b, nc, q, n)).astype(dtype)
    y1 = ops.ssd_intra(xh, dt, la, B, C)
    y2 = ref.ssd_intra_ref(xh.astype(jnp.float32), dt, la,
                           B.astype(jnp.float32), C.astype(jnp.float32))
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=tol, atol=tol)


def test_ssd_intra_consistent_with_model():
    """Kernel + inter-chunk recurrence reproduces models/ssm.ssd_chunked."""
    from repro.models.ssm import ssd_chunked
    b, l, h, p, n, chunk = 1, 48, 2, 8, 8, 16
    key = jax.random.PRNGKey(4)
    xh = jax.random.normal(key, (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(5), (b, l, h)))
    a_log = -0.4 * dt
    B = jax.random.normal(jax.random.PRNGKey(6), (b, l, n))
    C = jax.random.normal(jax.random.PRNGKey(7), (b, l, n))
    y_model, _ = ssd_chunked(xh, dt, a_log, B, C, chunk)

    nc = l // chunk
    xc = xh.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    lac = jnp.cumsum(a_log.reshape(b, nc, chunk, h), axis=2)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)
    y_intra = ops.ssd_intra(xc, dtc, lac, Bc, Cc)
    # reconstruct inter part with the model's math
    last = lac[:, :, -1:, :]
    st = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                    jnp.exp(last - lac) * dtc, Bc, xc)
    dec = jnp.exp(lac[:, :, -1, :])

    def step(hprev, inp):
        d, s = inp
        return hprev * d[:, :, None, None] + s, hprev

    h0 = jnp.zeros((b, h, p, n))
    _, hstart = jax.lax.scan(step, h0, (dec.transpose(1, 0, 2),
                                        st.transpose(1, 0, 2, 3, 4)))
    hstart = hstart.transpose(1, 0, 2, 3, 4)
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp", jnp.exp(lac), Cc, hstart)
    y = (y_intra + y_inter).reshape(b, l, h, p)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_model),
                               rtol=1e-4, atol=1e-4)
