"""Golden-case manifest shared by the test suite and
``scripts/capture_goldens.py``.

Every hex/sha256 golden in the repo — trajectory rollouts
(test_fleet / test_env / test_multi_server), observation feature blocks
(test_fleet), and training init/iteration captures
(test_shared_policy / test_entity_policy) — is DEFINED here once: this
module knows how to build each case's env, drive it, and reduce the
result to comparable values. The committed values live in
``tests/goldens/goldens.json``; the capture script regenerates that file
(or ``--check``s it against the live simulator) from this manifest, so a
golden recapture is one command and one commit, never a hand-edit of
hex blobs.

Two comparison regimes:

* EXACT (hex/sha strings): env trajectories, observation blocks,
  post-iteration agent shas, metrics bytes, PRNG keys. These are pure
  jnp/XLA elementwise math — deterministic on a given machine and
  recapturable in-repo via the script when the simulator legitimately
  changes.
* TOLERANCE (float fingerprints): freshly-initialized agent parameters.
  ``jax.random.orthogonal`` lowers to LAPACK QR, whose last-ulp numerics
  differ across BLAS builds, so raw-byte shas of init params are
  machine-dependent (the 6 cross-machine test_shared_policy failures of
  PR 6). Each leaf is reduced to [sum, sum(|x|), sum(x * cos(i))] in
  float64: a changed init KEY STREAM moves these by O(1) while a
  different LAPACK moves them by O(n * ulp), so the check pins the key
  schedule and stays machine-robust.
"""
from __future__ import annotations

import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", False)

GOLDEN_PATH = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                           "goldens", "goldens.json")

# init-fingerprint comparison tolerances (see module docstring): sums over
# a 256x128 orthogonal leaf differ by ~1e-3 across BLAS builds and by O(1)
# across key streams, so these bounds separate the two by >2 orders.
FP_RTOL = 1e-4
FP_ATOL = 0.05


def load_goldens(path=GOLDEN_PATH):
    with open(path) as f:
        return json.load(f)


def save_goldens(goldens, path=GOLDEN_PATH):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(goldens, f, indent=1, sort_keys=True)
        f.write("\n")


def _hex(arr, dtype=np.float32):
    return np.asarray(arr, dtype).tobytes().hex()


def tree_sha(tree):
    h = hashlib.sha256()
    for path, leaf in sorted(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            key=lambda kv: jax.tree_util.keystr(kv[0])):
        h.update(jax.tree_util.keystr(path).encode())
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


def tree_fingerprint(tree):
    """Per-leaf tolerance-comparable reduction {keystr: [s, sa, sw]}."""
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        x = np.asarray(leaf, np.float64).ravel()
        w = np.cos(np.arange(x.size, dtype=np.float64))
        out[jax.tree_util.keystr(path)] = [
            float(x.sum()), float(np.abs(x).sum()), float((x * w).sum())]
    return out


def fingerprint_close(got, want, rtol=FP_RTOL, atol=FP_ATOL):
    """True when two fingerprints match leaf-for-leaf within tolerance."""
    if sorted(got) != sorted(want):
        return False
    return all(np.allclose(got[k], want[k], rtol=rtol, atol=atol)
               for k in got)


# ------------------------------------------------------------------ envs
@functools.lru_cache(maxsize=None)
def mixed_fleet():
    """The canonical 3-UE mixed fleet (CNN + padded transformer + IoT CNN)
    used by the fleet/shared-policy/entity test suites."""
    from repro.configs import get_config
    from repro.core import overhead as oh
    from repro.core.cnn import make_resnet18
    from repro.core.split import (build_fleet, cnn_split_table,
                                  transformer_split_table)
    cnn = cnn_split_table(make_resnet18(101), 224)
    cnn_iot = cnn_split_table(make_resnet18(101), 224, dev=oh.IOT_SOC)
    tf_small = transformer_split_table(get_config("qwen3-1.7b"),
                                       ue_dev=oh.PHONE_NPU, n_points=2)
    return build_fleet([cnn, tf_small, cnn_iot],
                       [oh.JETSON_NANO, oh.PHONE_NPU, oh.IOT_SOC])


@functools.lru_cache(maxsize=None)
def cnn_plan():
    from repro.core.cnn import make_resnet18
    from repro.core.split import cnn_split_table
    return cnn_split_table(make_resnet18(101), 224)


def build_env(name):
    """One env per golden case name. Trajectory/observation/training cases
    share these builders so the manifest has a single source of truth."""
    from repro.core.fleets import make_edge_pool
    from repro.env.mecenv import MECEnv, make_env_params
    if name == "homo":
        return MECEnv(make_env_params(cnn_plan(), n_ue=3, n_channels=2))
    if name == "mixed":
        return MECEnv(make_env_params(mixed_fleet(), n_channels=2))
    if name == "churn":
        return MECEnv(make_env_params(cnn_plan(), n_ue=3, n_channels=2,
                                      churn_rate=0.4, leave_rate=0.2,
                                      lam_tasks=30.0))
    if name == "env5":
        return MECEnv(make_env_params(cnn_plan(), n_ue=5, n_channels=2))
    if name == "pool2":
        return MECEnv(make_env_params(mixed_fleet(), n_channels=2,
                                      pool=make_edge_pool(2)))
    if name == "pool2_homo4":
        return MECEnv(make_env_params(cnn_plan(), n_ue=4, n_channels=2,
                                      pool=make_edge_pool(2)))
    if name == "pool3":
        return MECEnv(make_env_params(mixed_fleet(), n_channels=2,
                                      pool=make_edge_pool(3)))
    if name == "train_mixed":
        return MECEnv(make_env_params(mixed_fleet(), n_channels=2))
    if name == "train_pool":
        return MECEnv(make_env_params(mixed_fleet(), n_channels=2,
                                      pool=make_edge_pool(2)))
    if name == "train_churn":
        return MECEnv(make_env_params(mixed_fleet(), n_channels=2,
                                      churn_rate=0.3, leave_rate=0.2))
    raise KeyError(name)


# ----------------------------------------------------------- trajectories
TRAJECTORY_CASES = ("homo", "mixed", "churn", "env5", "pool2_homo4")


def golden_rollout(env, steps=40, seed=3):
    """The fixed random-action rollout behind every trajectory golden:
    per-UE feasible split draws, random channel/power, and (multi-server
    envs only) random route draws — one extra rng consumption per frame,
    after power, so single-server streams are unchanged by the head."""
    n_ue = env.params.n_ue
    s = env.reset(jax.random.PRNGKey(seed))
    rng = np.random.RandomState(0)
    feas = np.asarray(env.params.feasible)
    valid = [np.where(feas[ue])[0] for ue in range(n_ue)]
    rewards = []
    for _ in range(steps):
        acts = {"split": jnp.asarray([rng.choice(v) for v in valid],
                                     jnp.int32),
                "channel": jnp.asarray(rng.randint(0, env.n_channels, n_ue),
                                       jnp.int32),
                "power": jnp.asarray(rng.uniform(0.05, 0.5, n_ue),
                                     jnp.float32)}
        if env.multi_server:
            acts["route"] = jnp.asarray(
                rng.randint(0, env.n_servers, n_ue), jnp.int32)
        s, r, d, _ = env.step(s, acts)
        rewards.append(np.float32(r))
    return np.asarray(rewards, np.float32), s


def trajectory_golden(name):
    rewards, s = golden_rollout(build_env(name))
    return {"rewards": _hex(rewards),
            "k": _hex(s.k), "l": _hex(s.l), "n": _hex(s.n), "d": _hex(s.d),
            "key": _hex(s.key, np.uint32),
            "active": _hex(s.active, np.uint8)}


# ----------------------------------------------------------- observations
OBS_PER_UE_CASES = ("homo", "mixed", "churn_standby", "pool2")
OBS_ENTITY_CASES = ("homo", "pool2", "pool3")


def obs_state(name):
    """(env, state) for an observation golden; ``churn_standby`` plants a
    standby UE to pin the zeroed-row semantics."""
    if name == "churn_standby":
        env = build_env("churn")
        s = env.reset(jax.random.PRNGKey(3))
        return env, s._replace(active=jnp.asarray([True, False, True]))
    env = build_env(name)
    return env, env.reset(jax.random.PRNGKey(3))


def obs_per_ue_golden(name):
    env, s = obs_state(name)
    return _hex(env.observe_per_ue(s))


def obs_entities_golden(name):
    env, s = obs_state(name)
    obs = env.observe_entities(s)
    return {block: _hex(obs[block]) for block in ("ue", "server", "edge")}


# --------------------------------------------------------------- training
TRAIN_CASES = (
    "per_ue.mixed", "per_ue.pool", "per_ue.churn",
    "shared.mixed", "shared.pool", "shared.churn",
    "entity.pool", "entity.churn",
)


def train_env(case):
    return build_env("train_" + case.split(".", 1)[1])


def train_capture(case, *, with_init_tree=False):
    """init fingerprint + one jitted iteration's exact agent sha, metrics
    bytes, and final key — the per-mode training golden. The config
    matches the PR-3/4 capture configs exactly."""
    from repro.optim import adamw_init
    from repro.rl.mahppo import MAHPPOConfig, init_agent, make_train_fns
    mode = case.split(".", 1)[0]
    env = train_env(case)
    cfg = MAHPPOConfig(iterations=1, horizon=64, n_envs=2, reuse=2,
                       batch=32, shared_policy=(mode == "shared"),
                       entity_policy=(mode == "entity"))
    key = jax.random.PRNGKey(0)
    agent = init_agent(key, env, shared_policy=cfg.shared_policy,
                       entity_policy=cfg.entity_policy)
    init_tree = agent
    opt = adamw_init(agent)
    states = jax.vmap(env.reset)(jax.random.split(key, cfg.n_envs))
    iteration = make_train_fns(env, cfg)
    agent, opt, key, states, metrics = iteration(agent, opt, key, states)
    out = {"init_fp": tree_fingerprint(init_tree),
           "post_sha": tree_sha(agent),
           "metrics": {k: _hex(v) for k, v in sorted(metrics.items())},
           "key": _hex(key, np.uint32)}
    if with_init_tree:
        return out, init_tree
    return out


# ------------------------------------------------------------ aggregation
def compute_all(only=None):
    """Recompute every golden from the live simulator. ``only``: optional
    iterable of section names to restrict to."""
    sections = {
        "trajectories": lambda: {n: trajectory_golden(n)
                                 for n in TRAJECTORY_CASES},
        "observe_per_ue": lambda: {n: obs_per_ue_golden(n)
                                   for n in OBS_PER_UE_CASES},
        "observe_entities": lambda: {n: obs_entities_golden(n)
                                     for n in OBS_ENTITY_CASES},
        "training": lambda: {c: train_capture(c) for c in TRAIN_CASES},
    }
    out = {"schema": 1}
    for name, fn in sections.items():
        if only is None or name in only:
            out[name] = fn()
    return out


def diff_goldens(got, want):
    """Human-readable drift list between a freshly-computed golden tree and
    the committed one. Training ``init_fp`` entries compare with the BLAS
    tolerance; everything else compares exactly."""
    drift = []

    def walk(g, w, path):
        if isinstance(w, dict) and isinstance(g, dict):
            for k in sorted(set(g) | set(w)):
                if k not in g:
                    drift.append(f"{path}.{k}: missing from recompute")
                elif k not in w:
                    drift.append(f"{path}.{k}: not in committed goldens")
                elif k == "init_fp":
                    if not fingerprint_close(g[k], w[k]):
                        drift.append(f"{path}.init_fp: outside tolerance")
                else:
                    walk(g[k], w[k], f"{path}.{k}")
        elif g != w:
            drift.append(f"{path}: {w!r} -> {g!r}")

    walk(got, want, "goldens")
    return drift
