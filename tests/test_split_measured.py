"""Measured split tables (compiled-HLO CNN costs, LLM-decode KV-payload
tables), context-rung fleets, and the quantizer round-trip bound."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import overhead as oh
from repro.core.compressor import dequantize, quantize
from repro.core.cnn import make_resnet18
from repro.core.fleets import LLM_CTX_RUNGS, make_llm_mixed_fleet
from repro.core.split import (llm_decode_split_table,
                              measured_cnn_module_costs,
                              measured_cnn_split_table, measured_split_table)
from repro.models.cache import entry_payload_bits


# ------------------------------------------------------ LLM decode tables
def test_llm_decode_table_invariants():
    cfg = get_config("qwen3-1.7b")
    plan = llm_decode_split_table(cfg, 256, gen_tokens=8, kv_bits=8)
    assert plan.name == "qwen3-1.7b-decode-ctx256"
    assert plan.n_actions == len(plan.points) + 2
    # _finalize contract: free raw offload, monotone UE compute, silent local
    assert plan.t_local[0] == 0.0
    assert np.all(np.diff(plan.t_local[1:-1]) >= -1e-9)
    assert plan.f_bits[-1] == 0.0
    # a 1.7b stack fits a phone NPU at every split depth
    assert plan.feasible.all()
    # KV cache dominates the boundary payload and accumulates with depth
    assert np.all(np.diff(plan.f_bits[1:-1]) > 0)
    # full-local covers prefill + decode: strictly more compute than the
    # deepest split's prefill-only share
    assert plan.t_local[-1] > plan.t_local[-2]


def test_llm_payload_monotone_in_context():
    """f_bits at every split point is a nondecreasing function of context
    length — the property that makes long-context offloading expensive."""
    cfg = get_config("qwen3-1.7b")
    plans = [llm_decode_split_table(cfg, c, gen_tokens=8, kv_bits=8)
             for c in (256, 1024, 4096)]
    for a, b in zip(plans, plans[1:]):
        assert np.all(b.f_bits[:-1] > a.f_bits[:-1])
        # and so is the prefill compute at each split
        assert np.all(b.t_local[1:] > a.t_local[1:])


def test_llm_table_memory_gate():
    """A 9B recurrent stack does NOT fit a phone NPU at deep splits: the
    per-layer param-bytes feasibility gate must trip, while raw offload
    (b=0, no UE-side layers) stays feasible. Also exercises the rec /
    sliding-window payload branches of entry_payload_bits."""
    cfg = get_config("recurrentgemma-9b")
    plan = llm_decode_split_table(cfg, 1024, gen_tokens=8)
    assert bool(plan.feasible[0])
    assert not plan.feasible.all()
    assert not bool(plan.feasible[-1])     # 9B params >> 8 GB phone


def test_entry_payload_bits_window_cap_and_rec_state():
    cfg = get_config("recurrentgemma-9b")
    btypes = cfg.block_types()
    assert "rec" in btypes and "lattn" in btypes
    # rec state is O(1) in context
    assert entry_payload_bits(cfg, "rec", 1, 64) \
        == entry_payload_bits(cfg, "rec", 1, 4096)
    # sliding-window KV grows until the window fills, then caps
    w = cfg.window
    small = entry_payload_bits(cfg, "lattn", 1, w // 4)
    at_w = entry_payload_bits(cfg, "lattn", 1, w)
    beyond = entry_payload_bits(cfg, "lattn", 1, 4 * w)
    assert small < at_w == beyond
    with pytest.raises(ValueError):
        entry_payload_bits(cfg, "lattn", 1, 0)


def test_entry_payload_bits_kv_quant():
    """int8 codes + f32 per-(slot, head) scales vs bf16: quantized cache
    payload must be strictly smaller, and match the hand sum."""
    cfg = get_config("qwen3-1.7b")
    full = entry_payload_bits(cfg, "attn", 1, 512)
    cfg8 = cfg.replace(kv_quant_bits=8)
    quant = entry_payload_bits(cfg8, "attn", 1, 512)
    assert quant < full
    hkv, dh, lc = cfg.n_kv_heads, cfg.head_dim, 512
    expect = (2 * lc * hkv * dh * 8        # int8 k+v codes
              + 2 * lc * hkv * 32          # f32 scales
              + lc * 32)                   # int32 pos
    assert quant == expect


def test_measured_split_table_dispatch():
    cfg = get_config("qwen3-1.7b")
    plan = measured_split_table(cfg, ctx_len=256, gen_tokens=8)
    assert plan.name.endswith("-decode-ctx256")


# -------------------------------------------------- measured CNN tables
@pytest.fixture(scope="module")
def tiny_cnn():
    return make_resnet18(10, width=0.25)


@pytest.fixture(scope="module")
def tiny_costs(tiny_cnn):
    return measured_cnn_module_costs(tiny_cnn, 32)


def test_measured_cnn_costs_vs_walker(tiny_cnn, tiny_costs):
    """XLA's compiled cost analysis vs the hand-derived conv walker: the
    walker ignores BN/elementwise and XLA folds/pads, so only loose
    cumulative agreement is expected — same order of magnitude, every
    module nonzero."""
    assert len(tiny_costs) == tiny_cnn.n_modules
    meas = np.array([c["flops"] for c in tiny_costs], float)
    walk = np.array(tiny_cnn.module_flops(32), float)
    assert (meas > 0).all() and (np.array(
        [c["bytes_accessed"] for c in tiny_costs]) > 0).all()
    ratio = meas.sum() / walk.sum()
    assert 0.25 < ratio < 4.0


def test_measured_cnn_split_table(tiny_cnn, tiny_costs):
    plan = measured_cnn_split_table(tiny_cnn, 32, module_costs=tiny_costs)
    assert plan.name.endswith("-measured")
    assert plan.t_local[0] == 0.0
    assert np.all(np.diff(plan.t_local[1:-1]) >= -1e-9)
    assert plan.f_bits[-1] == 0.0
    assert plan.feasible.all()
    # CNN payloads SHRINK with depth past the early blow-up — the last
    # split point ships far fewer bits than raw input
    assert plan.f_bits[len(plan.points)] < plan.f_bits[0]


def test_measured_cnn_rd_override(tiny_cnn, tiny_costs):
    """Measured rate-distortion rows replace the paper's ae_ratio
    constants: f_bits must reflect each row's (ch_prime, bits)."""
    model, costs = tiny_cnn, tiny_costs
    shapes = model.feature_shapes(32)
    rd = [{"ch_prime": 2, "bits": 6} for _ in model.split_after]
    plan = measured_cnn_split_table(model, 32, module_costs=costs, rd=rd)
    for pi, k in enumerate(model.split_after):
        _, h, w = shapes[k]
        assert plan.f_bits[pi + 1] == 2 * h * w * 6
    with pytest.raises(ValueError):
        measured_cnn_split_table(model, 32, module_costs=costs, rd=rd[:-1])


# ------------------------------------------------------ mixed fleets
def test_make_llm_mixed_fleet():
    fleet = make_llm_mixed_fleet(n_cnn=2, gen_tokens=8)
    n = 2 + len(LLM_CTX_RUNGS)
    assert fleet.t_local.shape[0] == n
    assert fleet.names[:2] == ["resnet18", "resnet18"]
    assert [f"qwen3-1.7b-decode-ctx{c}" for c in LLM_CTX_RUNGS] \
        == fleet.names[2:]
    # full-local lives in the LAST padded slot for every UE: feasible,
    # zero payload, and the longest rung is the slowest local run
    assert fleet.feasible[:, -1].all()
    assert np.all(fleet.f_bits[:, -1] == 0.0)
    llm_local = fleet.t_local[2:, -1]
    assert np.all(np.diff(llm_local) > 0)


# ------------------------------------------------------ quantizer bound
@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_roundtrip_error_bound(bits):
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3.0
    codes, minv, maxv = quantize(x, bits)
    back = dequantize(codes, bits, minv, maxv)
    step = (maxv - minv) / ((1 << bits) - 1)
    # round-to-nearest on a uniform grid: error <= half a step everywhere
    # (bound stated as one full step to absorb float32 rounding)
    assert float(jnp.max(jnp.abs(back - x))) <= float(step)
