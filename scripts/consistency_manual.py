"""Decode path must agree with full-sequence forward: prefill s tokens, decode
token s, compare logits against full forward over s+1 tokens."""
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.models import apply_model, decode_step, init_params, prefill

if __name__ == "__main__":
    archs = sys.argv[1:] or ARCH_IDS
    key = jax.random.PRNGKey(1)
    for arch in archs:
        cfg = reduced(get_config(arch))
        params = init_params(cfg, key)
        b, s = 2, 33
        toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0,
                                  cfg.vocab_size)
        aux = None
        if cfg.n_aux_tokens:
            aux = jax.random.normal(
                jax.random.PRNGKey(3), (b, cfg.n_aux_tokens, cfg.d_model)) * 0.1
        full_logits, _, _ = apply_model(params, cfg, toks, aux_embeds=aux,
                                        mode="train")
        _, cache = prefill(params, cfg, toks[:, :s], attn_len=s + 1,
                           aux_embeds=aux)
        dec_logits, _ = decode_step(params, cfg, cache, toks[:, s:s + 1],
                                    jnp.int32(s))
        err = float(jnp.max(jnp.abs(full_logits[:, s] - dec_logits)))
        rel = err / (float(jnp.max(jnp.abs(full_logits[:, s]))) + 1e-9)
        print(f"{arch:24s} max_abs_err={err:.3e} rel={rel:.3e} "
              f"{'OK' if rel < 2e-3 else 'FAIL'}")
