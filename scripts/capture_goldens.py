#!/usr/bin/env python
"""Regenerate — or ``--check`` — every hex/sha256 golden in the test
suite from the committed manifest in ``tests/golden_cases.py``.

Default mode recomputes all goldens from the live simulator and rewrites
``tests/goldens/goldens.json``; the diff of that file IS the recapture
event, reviewable case-by-case in one commit. ``--check`` recomputes and
compares instead (init fingerprints with the documented BLAS tolerance,
everything else exactly), exiting nonzero on any drift — CI runs this so
a simulator change can never silently coexist with stale goldens.

Usage:
    PYTHONPATH=src python scripts/capture_goldens.py [--check]
        [--only SECTION ...]

Sections: trajectories observe_per_ue observe_entities training
"""
import argparse
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))
sys.path.insert(0, os.path.join(_REPO, "tests"))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--check", action="store_true",
                    help="compare against the committed goldens instead "
                         "of rewriting them; exit 1 on drift")
    ap.add_argument("--only", nargs="*", default=None,
                    metavar="SECTION",
                    help="restrict to these golden sections")
    args = ap.parse_args(argv)

    import golden_cases as gc

    got = gc.compute_all(only=args.only)
    if not args.check:
        if args.only is not None:
            # partial capture: splice into the committed file
            merged = gc.load_goldens() if os.path.exists(gc.GOLDEN_PATH) \
                else {"schema": 1}
            merged.update(got)
            got = merged
        gc.save_goldens(got)
        n = sum(len(v) for k, v in got.items() if isinstance(v, dict))
        print(f"captured {n} goldens -> {gc.GOLDEN_PATH}")
        return 0

    want = gc.load_goldens()
    if args.only is not None:
        want = {k: v for k, v in want.items()
                if k == "schema" or k in args.only}
        want["schema"] = got["schema"]
    drift = gc.diff_goldens(got, want)
    if drift:
        print(f"{len(drift)} golden(s) drifted from the simulator:")
        for line in drift:
            print(f"  {line}")
        print("If the simulator change is intentional, recapture with: "
              "PYTHONPATH=src python scripts/capture_goldens.py")
        return 1
    print("all goldens match the live simulator")
    return 0


if __name__ == "__main__":
    sys.exit(main())
