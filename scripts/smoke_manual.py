"""Manual smoke: every arch, reduced config, train loss + prefill + decode."""
import sys

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import reduced
from repro.models import decode_step, init_params, loss_fn, prefill

if __name__ == "__main__":
    archs = sys.argv[1:] or ARCH_IDS
    key = jax.random.PRNGKey(0)
    for arch in archs:
        cfg = reduced(get_config(arch))
        params = init_params(cfg, key)
        n = sum(x.size for x in jax.tree_util.tree_leaves(params))
        b, s = 2, 32
        batch = {"tokens": jnp.ones((b, s), jnp.int32),
                 "labels": jnp.ones((b, s), jnp.int32)}
        if cfg.n_aux_tokens:
            batch["aux_embeds"] = jnp.ones((b, cfg.n_aux_tokens, cfg.d_model),
                                           jnp.float32) * 0.01
        loss, metrics = loss_fn(params, cfg, batch)
        # serving path
        logits, cache = prefill(params, cfg, batch["tokens"],
                                attn_len=s + 4,
                                aux_embeds=batch.get("aux_embeds"))
        tok = jnp.ones((b, 1), jnp.int32)
        lg2, cache = decode_step(params, cfg, cache, tok, jnp.int32(s))
        ok = bool(jnp.isfinite(loss)) and bool(jnp.all(jnp.isfinite(lg2)))
        print(f"{arch:24s} params={n:>10d} loss={float(loss):8.4f} "
              f"decode_logits={lg2.shape} finite={ok}")
